#!/usr/bin/env python
"""Kernel-regression smoke: re-measure every Pallas kernel and diff it
against the committed baseline (``results/bench_kernels.json``).

The gate (per kernel row, matched by name):

* the kernel must still exist — a probe row vanishing from the fresh run
  (or a fresh row missing from the baseline) fails, so the baseline file
  can never silently drift out of sync with ``probe_kernels``;
* ``fallback_delta`` (reference-path seconds / kernel seconds; > 1 means
  the Pallas kernel beats the jnp fallback) must not regress more than
  the allowed factor vs baseline.  On a real TPU the bar is 0.8 (the
  ISSUE's "no >20% regression"); on interpret-mode hosts Pallas timing is
  emulation noise, so the bar is a loose 0.1 plus best-of-3 retries —
  enough to catch a kernel that suddenly lowers to garbage, loose enough
  to survive CI jitter;
* "never slower than the jnp fallback" (delta >= 1.0 after the same
  regression slack) is enforced ONLY where the probe would actually pick
  the kernel (``default_impl == "pallas"``, i.e. a TPU host) — interpret
  mode is a correctness vehicle, not a perf target;
* rows measured under a different ``default_impl`` than the baseline
  (e.g. a baseline refreshed on TPU, smoke running on CPU) skip the
  ratio check with a note — cross-machine-class deltas are not
  comparable.

``--refresh`` rewrites the baseline from a fresh ``bench_kernels.run()``
(the same writer CI dashboards read), then re-checks against it.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

BASELINE = os.path.join("results", "bench_kernels.json")

# allowed fallback_delta ratio (current / baseline) before we call it a
# regression, per the impl class the measurement ran under.  Interpret-
# mode Pallas timing jitters ~4x run-to-run on shared CPU hosts, so its
# bar is an order of magnitude — a lowering that turns into garbage is
# 100-1000x, which this still catches.
REGRESSION_FACTOR = {"pallas": 0.8, "interpret": 0.1}

# noisy-host retries: re-measure and keep the per-kernel BEST delta
# before declaring a regression (a true regression survives retries;
# scheduler jitter does not)
MAX_ATTEMPTS = 3


def _load_baseline(path: str) -> dict:
    with open(path) as f:
        return json.load(f)["kernels"]


def _fresh(path: str | None = None) -> dict:
    if path is not None:
        # full bench pass: CSV rows + rewrite the JSON baseline.  The
        # benchmarks package lives at the repo root (next to scripts/),
        # which is not on sys.path when this runs as a plain script.
        sys.path.insert(0, os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        from benchmarks.bench_kernels import run
        run(json_out=path)
        return _load_baseline(path)
    from repro.profiler.probes import probe_kernels
    return probe_kernels(quick=True)


def check(baseline: dict, current: dict) -> list[str]:
    failures: list[str] = []
    missing = sorted(set(baseline) - set(current))
    extra = sorted(set(current) - set(baseline))
    if missing:
        failures.append(f"kernels gone from the probe: {missing}")
    if extra:
        failures.append(
            f"kernels missing from the baseline: {extra} — refresh it "
            "with `python scripts/kernel_smoke.py --refresh` and commit")
    for name in sorted(set(baseline) & set(current)):
        base, cur = baseline[name], current[name]
        b_delta, c_delta = base["fallback_delta"], cur["fallback_delta"]
        impl = cur.get("default_impl", "interpret")
        if impl != base.get("default_impl", "interpret"):
            print(f"  ~ {name}: baseline impl "
                  f"{base.get('default_impl')!r} != current {impl!r} — "
                  "cross-machine-class, ratio check skipped")
            continue
        factor = REGRESSION_FACTOR.get(impl, 0.25)
        floor = factor * b_delta
        status = "ok"
        if c_delta < floor:
            status = "REGRESSED"
            failures.append(
                f"{name}: fallback_delta {c_delta:.3f} < {factor} x "
                f"baseline {b_delta:.3f} (floor {floor:.3f})")
        if impl == "pallas" and c_delta < factor * 1.0:
            status = "BELOW-FALLBACK"
            failures.append(
                f"{name}: Pallas path ({c_delta:.3f}x) fell below the jnp "
                "fallback on a TPU host — the dispatcher would be faster "
                "never picking it")
        print(f"  {'!' if status != 'ok' else '-'} {name}: "
              f"delta {b_delta:.3f} -> {c_delta:.3f} "
              f"[{impl}, floor {floor:.3f}] {status}")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--refresh", action="store_true",
                    help="rewrite the baseline from a fresh bench run, "
                         "then check against it")
    args = ap.parse_args()
    if args.refresh:
        print(f"# refreshing baseline -> {args.baseline}")
        current = _fresh(args.baseline)
    else:
        current = _fresh()
    if not os.path.exists(args.baseline):
        print(f"kernel-smoke: no baseline at {args.baseline}; run "
              "`python scripts/kernel_smoke.py --refresh` and commit it",
              file=sys.stderr)
        return 2
    baseline = _load_baseline(args.baseline)
    print(f"# kernel-smoke: {len(current)} kernels vs {args.baseline}")
    failures = check(baseline, current)
    for attempt in range(2, MAX_ATTEMPTS + 1):
        if not failures:
            break
        print(f"# retrying noisy measurement (attempt {attempt}/"
              f"{MAX_ATTEMPTS}, keeping best-of deltas)")
        for name, row in _fresh().items():
            if name in current and \
                    row["fallback_delta"] > current[name]["fallback_delta"]:
                current[name] = row
        failures = check(baseline, current)
    if failures:
        print("\nkernel-smoke FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  * {f}", file=sys.stderr)
        return 1
    print(f"# kernel-smoke OK ({len(current)} kernels)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
