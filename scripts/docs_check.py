#!/usr/bin/env python
"""Docs lint (``make docs-check``): keep the doc set from rotting.

Checks, over ``docs/*.md`` + ``README.md``:

1. every relative markdown link ``[text](path)`` points at a file that
   exists (http/https/mailto links are skipped);
2. every ``#fragment`` on a relative link to a markdown file names a real
   heading in the target (GitHub-style slugs), including same-file
   ``(#fragment)`` links;
3. every wiki-style cross-reference ``[[name]]`` resolves to
   ``docs/<name>.md``;
4. every fenced ```` ```python ```` block at least compiles
   (``compile(..., "exec")``) — snippets with typos or stale syntax fail
   here instead of in a reader's shell.

Exit code 0 and a one-line summary when clean; one line per problem and
exit code 1 otherwise.  No dependencies beyond the stdlib.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
WIKI_RE = re.compile(r"\[\[([^\]#|]+)(?:#[^\]|]*)?(?:\|[^\]]*)?\]\]")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
FENCE_RE = re.compile(r"^```(\w*)[^\n]*\n(.*?)^```", re.MULTILINE | re.DOTALL)


def doc_files() -> list[Path]:
    files = sorted((ROOT / "docs").glob("*.md"))
    readme = ROOT / "README.md"
    if readme.exists():
        files.append(readme)
    return files


def slugify(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, drop punctuation (inline code
    ticks included), each whitespace char becomes one hyphen."""
    heading = heading.strip().lower().replace("`", "")
    heading = re.sub(r"[^\w\s-]", "", heading)
    return re.sub(r"\s", "-", heading)


def anchors_of(path: Path, cache: dict) -> set:
    if path not in cache:
        text = path.read_text(encoding="utf-8")
        cache[path] = {slugify(h) for h in HEADING_RE.findall(text)}
    return cache[path]


def strip_fences(text: str) -> str:
    """Remove fenced code blocks so links inside them are not checked."""
    return re.sub(r"^```.*?^```", "", text, flags=re.MULTILINE | re.DOTALL)


def check_links(path: Path, text: str, cache: dict) -> list[str]:
    problems = []
    for target in LINK_RE.findall(strip_fences(text)):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        ref, _, frag = target.partition("#")
        dest = path if not ref else (path.parent / ref).resolve()
        if not dest.exists():
            problems.append(f"{path.relative_to(ROOT)}: broken link "
                            f"({target}) — {ref} does not exist")
            continue
        if frag and dest.suffix == ".md":
            if slugify(frag) not in anchors_of(dest, cache):
                problems.append(
                    f"{path.relative_to(ROOT)}: broken anchor ({target}) — "
                    f"no heading slugs to #{frag} in "
                    f"{dest.relative_to(ROOT)}")
    for name in WIKI_RE.findall(strip_fences(text)):
        dest = ROOT / "docs" / f"{name.strip()}.md"
        if not dest.exists():
            problems.append(f"{path.relative_to(ROOT)}: broken wiki ref "
                            f"[[{name}]] — docs/{name.strip()}.md "
                            "does not exist")
    return problems


def check_python_blocks(path: Path, text: str) -> list[str]:
    problems = []
    for i, (lang, code) in enumerate(FENCE_RE.findall(text)):
        if lang != "python":
            continue
        try:
            compile(code, f"{path.name}:block{i}", "exec")
        except SyntaxError as e:
            problems.append(f"{path.relative_to(ROOT)}: python block {i} "
                            f"does not compile — {e.msg} (line {e.lineno})")
    return problems


def main() -> int:
    problems: list[str] = []
    cache: dict = {}
    files = doc_files()
    n_blocks = 0
    for path in files:
        text = path.read_text(encoding="utf-8")
        problems += check_links(path, text, cache)
        problems += check_python_blocks(path, text)
        n_blocks += sum(1 for lang, _ in FENCE_RE.findall(text)
                        if lang == "python")
    for p in problems:
        print(p, file=sys.stderr)
    if problems:
        print(f"docs-check: {len(problems)} problem(s) in "
              f"{len(files)} file(s)", file=sys.stderr)
        return 1
    print(f"docs-check: {len(files)} files clean "
          f"({n_blocks} python blocks compiled)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
