# Tier-1 verify and friends in one command each.
#
#   make test        - full tier-1 suite (the driver's acceptance gate)
#   make test-fast   - quick signal: skips the slow subprocess/system suites
#   make bench-smoke - serving + kernel benchmark smoke (prints CSV + JSON)

PY ?= python
PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test test-fast bench-smoke

test:
	$(PY) -m pytest -x -q

test-fast:
	$(PY) -m pytest -x -q --ignore=tests/test_system.py \
	    --ignore=tests/test_moe_shardmap.py \
	    --ignore=tests/test_orchestrator.py

bench-smoke:
	$(PY) -m benchmarks.bench_serving --smoke
	$(PY) -m benchmarks.run kernels
