# Tier-1 verify and friends in one command each.
#
#   make test        - full tier-1 suite (the driver's acceptance gate)
#   make test-fast   - quick signal: skips the slow subprocess/system suites
#   make bench-smoke - serving + kernel benchmark smoke (prints CSV + JSON)
#   make plan-smoke  - session plan dry-run: emit + round-trip a Plan JSON
#   make paged-smoke - paged vs slot-pool serving under one KV budget
#   make backend-smoke - both decode backends per supporting family + the
#                        copy-on-write prefix-share workload (self-asserting:
#                        token identity, block-reuse ratio > 1, and strictly
#                        more admitted concurrency than unshared paging)
#   make spec-smoke  - speculative decode vs plain decode on both inner
#                      backends (self-asserting: token identity, accept
#                      rate, target steps strictly < generated tokens)
#   make http-smoke  - live HTTP/SSE front-end (self-asserting: streamed
#                      tokens byte-identical to offline decode, mid-decode
#                      /v1/cancel frees lane+KV within one tick, open-loop
#                      Poisson run reports TTFT/TPOT/goodput percentiles)
#   make slo-smoke   - SLO scheduler A/B over live HTTP (self-asserting:
#                      same seeded trace under fifo and slo policies; slo
#                      preempts+resumes a paged request, strictly higher
#                      deadline goodput, completions token-identical to
#                      offline sequential decode)
#   make tier-smoke  - tiered memory: shard-resident weight packing serves
#                      strictly more concurrently-resident models than
#                      whole-model promotion under one ledger budget, and
#                      host-DRAM KV demotion admits strictly more live
#                      requests under byte-scarce preemption — both
#                      token-identical, ledger drained to baseline
#   make profile-smoke - machine profiler: capped quick probes, persist
#                      MachineFacts JSON, then plan the same job with and
#                      without the profile (self-asserting: provenance
#                      differs, executed tokens byte-identical)
#   make kernel-smoke - kernel regression gate: re-measure every Pallas
#                      kernel and diff fallback_delta vs the committed
#                      results/bench_kernels.json baseline (fails on >20%
#                      TPU regression, or a Pallas path slower than its
#                      jnp fallback; kernel-baseline refreshes the file)
#   make docs-check  - docs lint: relative links + [[refs]] resolve and
#                      fenced python blocks compile (docs/*.md, README.md)
#   make examples-smoke - run all four examples/*.py on their tiny configs

PY ?= python
PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test test-fast bench-smoke plan-smoke paged-smoke backend-smoke \
    spec-smoke http-smoke slo-smoke tier-smoke profile-smoke kernel-smoke \
    kernel-baseline docs-check examples-smoke

test:
	$(PY) -m pytest -x -q

test-fast:
	$(PY) -m pytest -x -q --ignore=tests/test_system.py \
	    --ignore=tests/test_moe_shardmap.py \
	    --ignore=tests/test_orchestrator.py

bench-smoke:
	$(PY) -m benchmarks.bench_serving --smoke
	$(PY) -m benchmarks.run kernels

plan-smoke:
	$(PY) -m repro.launch.dryrun --plan --arch qwen3-0.6b,bert-large-1b \
	    --smoke --budget-mb 18 --out results/plan_smoke.json

paged-smoke:
	$(PY) -m benchmarks.bench_serving --paged

backend-smoke:
	$(PY) -m benchmarks.bench_serving --backend-smoke

spec-smoke:
	$(PY) -m benchmarks.bench_serving --spec

http-smoke:
	$(PY) -m benchmarks.bench_load --smoke

slo-smoke:
	$(PY) -m benchmarks.bench_load --slo-smoke

tier-smoke:
	$(PY) -m benchmarks.bench_serving --tiered

profile-smoke:
	$(PY) -m repro.profiler --smoke

kernel-smoke:
	$(PY) scripts/kernel_smoke.py

kernel-baseline:
	$(PY) scripts/kernel_smoke.py --refresh

docs-check:
	$(PY) scripts/docs_check.py

examples-smoke:
	$(PY) examples/quickstart.py
	$(PY) examples/large_model_single_device.py
	$(PY) examples/model_selection.py
	$(PY) examples/serve_batched.py
