import jax
import jax.numpy as jnp
import pytest

# NOTE: no xla_force_host_platform_device_count here — smoke tests and
# benches must see the real single device; only launch/dryrun.py forces 512.


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def make_loader(cfg, batch=2, seq=64, seed=0):
    """Model-family-aware synthetic loader (audio/vlm need embeds)."""
    from repro.models import api

    class L:
        def __iter__(self):
            def gen():
                i = 0
                while True:
                    k = jax.random.fold_in(jax.random.PRNGKey(seed), i)
                    yield api.make_dummy_batch(cfg, batch, seq, key=k)
                    i += 1
            return gen()

    return L()
