import jax
import jax.numpy as jnp
import pytest

# NOTE: no xla_force_host_platform_device_count here — smoke tests and
# benches must see the real single device; only launch/dryrun.py forces 512.


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True, scope="module")
def _bound_jit_memory():
    """Drop compiled-executable caches between test modules.

    The whole tier-1 suite runs in ONE process, and every module compiles
    its own engines/kernels; the accumulated XLA:CPU JIT state eventually
    segfaults a *later, unrelated* compile (deterministically, ~300 tests
    in).  Modules don't share jitted callables — fixtures are module-
    scoped and cross-module helpers recompile transparently — so clearing
    at module teardown bounds JIT memory without changing any test."""
    yield
    jax.clear_caches()


def make_loader(cfg, batch=2, seq=64, seed=0):
    """Model-family-aware synthetic loader (audio/vlm need embeds)."""
    from repro.models import api

    class L:
        def __iter__(self):
            def gen():
                i = 0
                while True:
                    k = jax.random.fold_in(jax.random.PRNGKey(seed), i)
                    yield api.make_dummy_batch(cfg, batch, seq, key=k)
                    i += 1
            return gen()

    return L()
