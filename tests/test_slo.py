"""SLO-aware scheduling (repro.serving.slo): EDF + priority tiers +
starvation aging, paged preemption, overload shedding.

What this locks down:

* **Preempt/resume token identity** — a paged request descheduled by the
  policy and resumed later produces exactly the tokens an uninterrupted
  (or FIFO) run produces: the KV blocks never move, the resume feeds the
  last generated token, and its KV row was never written pre-preemption.
* **No leaks across the preempt lifecycle** — ledger bytes and block
  refcounts return to baseline whether a preempted request resumes and
  completes or is cancelled while parked.
* **No starvation** — aging is unbounded below, so a low-priority
  request eventually outranks any stream of fresh high-priority
  arrivals; but aging never picks preemption victims (no thrash).
* **Shed order** — soft overload degrades the spec draft (token-identical
  plain decode) before anything is refused; hard overload rejects the
  lowest-priority waiting tier and refuses same-tier submissions with
  ``OverloadedError`` (HTTP 429) while higher tiers still land.
* **EDF beats FIFO** on deadline attainment for one fixed seeded trace
  under a fake clock (the scheduling claim, timed deterministically).
"""

import functools

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.spilling import DeviceMemory
from repro.models import api
from repro.serving import (InferenceEngine, MultiModelServer,
                           OverloadedError, SLO, Status)
from repro.serving.request import Request
from repro.serving.slo import (FIFOPolicy, SLOPolicy, make_policy,
                               validate_slo)

MAX_SEQ = 48


@functools.lru_cache(maxsize=None)
def _dense():
    cfg = get_config("qwen3-0.6b", smoke=True)
    return cfg, api.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def dense():
    return _dense()


def _prompt(cfg, seed, plen=8):
    rng = np.random.RandomState(seed)
    return rng.randint(0, cfg.vocab_size, plen).astype(np.int32)


class Tick:
    """Settable clock: every engine timestamp is deterministic."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _paged(cfg, params, *, capacity=2, policy="slo", ledger=None,
           clock=None, n_blocks=32):
    kw = {"clock": clock} if clock is not None else {}
    return InferenceEngine(cfg, params, capacity=capacity, max_seq=MAX_SEQ,
                           backend="paged", block_size=8, n_blocks=n_blocks,
                           ledger=ledger, policy=policy, **kw)


def _sequential(cfg, params, prompts_gens):
    """Reference: each prompt decoded alone — the token-identity oracle."""
    out = []
    eng = _paged(cfg, params, capacity=1, policy="fifo")
    for prompt, gen in prompts_gens:
        r = eng.submit(prompt, gen)
        eng.run()
        out.append(r.generated)
    return out


def _fake_req(seed, *, priority="normal", deadline_ms=None, arrival=0.0,
              seq=0, generated=0):
    r = Request(prompt=np.arange(4, dtype=np.int32), max_new_tokens=8,
                request_id=f"fake-{seed}",
                slo=SLO(deadline_ms=deadline_ms, priority=priority))
    r.arrival_time = arrival
    r.arrival_seq = seq
    r.generated = list(range(generated))
    return r


# ---------------------------------------------------------------------------
# policy unit tests (no JAX work)
# ---------------------------------------------------------------------------

def test_validate_slo_actionable_errors():
    with pytest.raises(ValueError, match="deadline_ms=-1"):
        validate_slo(-1, "normal", None)
    with pytest.raises(ValueError, match="max_ttft_ms"):
        validate_slo(None, "normal", float("nan"))
    with pytest.raises(ValueError, match="known priorities"):
        validate_slo(None, "urgent", None)


def test_slo_policy_degrades_to_fifo_without_slos():
    """No deadlines, all-normal: the EDF rank ties everywhere and the
    arrival-seq tie-break reproduces exact FIFO order — why "slo" is a
    safe engine default."""
    pol = SLOPolicy()
    reqs = [_fake_req(i, seq=i) for i in range(5)]
    assert pol.order(list(reversed(reqs)), now=1.0) == reqs
    assert [pol.rank(r, 1.0)[2] for r in reqs] == [0, 1, 2, 3, 4]


def test_edf_within_tier_and_tiers_dominate():
    now = 0.0
    tight = _fake_req(1, deadline_ms=1000, seq=1)
    loose = _fake_req(2, deadline_ms=9000, seq=0)
    low_tight = _fake_req(3, deadline_ms=10, priority="low", seq=2)
    pol = SLOPolicy(aging_s=0)
    assert pol.order([low_tight, loose, tight], now) == [tight, loose,
                                                         low_tight]


def test_aging_is_unbounded_no_starvation():
    """A low-priority request left waiting outranks ANY fresh
    high-priority arrival once it has aged past every tier gap."""
    pol = SLOPolicy(aging_s=5.0)
    old_low = _fake_req(1, priority="low", arrival=0.0, seq=0)
    now = 50.0
    fresh_high = [_fake_req(i, priority="high", deadline_ms=100.0,
                            arrival=now, seq=i) for i in range(1, 4)]
    assert pol.order(fresh_high + [old_low], now)[0] is old_low
    # tier is unbounded below: however many tiers exist, enough waiting
    # always wins (2 - 10 promotions = -8 < high's 0)
    assert pol._tier(old_low, now) == 2 - 10


def test_aging_never_picks_preemption_victims():
    """Aging moves QUEUE order only: an aged-equal head must not evict a
    running request (equals preempting equals = thrash loop)."""
    pol = SLOPolicy(aging_s=1.0)
    head = _fake_req(1, priority="low", arrival=0.0, seq=0)     # aged way up
    running = _fake_req(2, priority="high", deadline_ms=500.0,
                        arrival=99.0, seq=1, generated=6)
    assert pol._tier(head, 100.0) < pol._tier(running, 100.0)   # order: head
    assert pol.pick_victim(head, [running], 100.0) is None      # victim: no


def test_victim_needs_min_tokens_since_resume():
    pol = SLOPolicy()
    head = _fake_req(1, priority="high", deadline_ms=100.0, seq=5)
    fresh = _fake_req(2, priority="low", seq=0, generated=1)    # < floor
    assert pol.pick_victim(head, [fresh], 0.0) is None
    fresh.generated = [0, 1, 2]
    assert pol.pick_victim(head, [fresh], 0.0) is fresh
    fresh.resume_generated = 2          # just resumed: floor counts anew
    assert pol.pick_victim(head, [fresh], 0.0) is None


def test_shed_tier_is_relative():
    pol = SLOPolicy()
    assert pol.shed_tier([]) is None
    assert pol.shed_tier([_fake_req(1), _fake_req(2, priority="low")]) == 2
    # an all-normal workload still sheds (its own tier) instead of
    # livelocking behind a threshold nobody is "low" enough to trip
    assert pol.shed_tier([_fake_req(1), _fake_req(2)]) == 1


def test_make_policy_and_fifo_noops():
    assert isinstance(make_policy("fifo"), FIFOPolicy)
    assert make_policy("slo", aging_s=7.0).aging_s == 7.0
    with pytest.raises(ValueError, match="unknown admission policy"):
        make_policy("edf")
    fifo = FIFOPolicy()
    head = _fake_req(1, priority="high", deadline_ms=1.0, seq=9)
    assert fifo.pick_victim(head, [_fake_req(2, generated=9)], 0.0) is None
    assert fifo.pressure(1e9) == 0


def test_submit_rejects_nonsense_slo(dense):
    cfg, params = dense
    eng = _paged(cfg, params)
    with pytest.raises(ValueError, match="deadline_ms"):
        eng.submit(_prompt(cfg, 1), 4, deadline_ms=-5)
    with pytest.raises(ValueError, match="known priorities"):
        eng.submit(_prompt(cfg, 1), 4, priority="urgent")


# ---------------------------------------------------------------------------
# preemption lifecycle on the paged backend
# ---------------------------------------------------------------------------

def _run_preempt_scenario(cfg, params, ledger):
    """Two low-priority longs saturate both lanes; a high-priority short
    with a deadline preempts one.  Returns (engine, longs, short)."""
    eng = _paged(cfg, params, capacity=2, ledger=ledger)
    longs = [eng.submit(_prompt(cfg, i), 16, priority="low")
             for i in (1, 2)]
    for _ in range(3):
        eng.step()              # both running, >= preempt_min_tokens each
    assert all(r.status is Status.RUNNING for r in longs)
    short = eng.submit(_prompt(cfg, 3), 4, priority="high",
                       deadline_ms=60_000.0)
    eng.step()                  # preempt fires and re-uses the lane
    return eng, longs, short


def test_preempt_resume_token_identity(dense):
    cfg, params = dense
    ledger = DeviceMemory(-1, budget_bytes=10**9)
    eng, longs, short = _run_preempt_scenario(cfg, params, ledger)
    assert eng.n_preempted >= 1
    victim = next(r for r in longs if r.status is Status.PREEMPTED)
    assert victim.slot is None and victim.preemptions == 1
    assert eng.backend.summary()["preempted_held"] == 1
    eng.run()
    assert eng.n_resumed >= 1
    assert all(r.status is Status.FINISHED for r in longs + [short])
    ref = _sequential(cfg, params,
                      [(_prompt(cfg, 1), 16), (_prompt(cfg, 2), 16),
                       (_prompt(cfg, 3), 4)])
    assert [longs[0].generated, longs[1].generated, short.generated] == ref
    assert short.metrics()["deadline_met"] is True
    # every reservation handed back: bytes, blocks, refcounts
    assert eng.budget.reserved_bytes == 0
    assert ledger.kv_reserved_bytes == 0
    assert eng.pool.n_free == eng.pool.n_allocatable
    assert eng.pool.refcounts() == {}


def test_cancel_while_preempted_settles_everything(dense):
    cfg, params = dense
    ledger = DeviceMemory(-1, budget_bytes=10**9)
    eng, longs, short = _run_preempt_scenario(cfg, params, ledger)
    victim = next(r for r in longs if r.status is Status.PREEMPTED)
    # parked: blocks still refcounted, bytes still charged
    assert eng.pool.refcounts() != {}
    assert eng.budget.reserved_bytes > 0
    assert eng.cancel(victim.request_id)
    eng.run()
    assert victim.status is Status.CANCELLED
    assert victim in list(eng.completed)
    assert eng.n_resumed == 0           # cancelled before any resume
    assert eng.budget.reserved_bytes == 0
    assert ledger.kv_reserved_bytes == 0
    assert eng.pool.n_free == eng.pool.n_allocatable
    assert eng.pool.refcounts() == {}


def test_slot_backend_declines_preemption(dense):
    cfg, params = dense
    eng = InferenceEngine(cfg, params, capacity=1, max_seq=MAX_SEQ,
                          backend="slot", policy="slo")
    long = eng.submit(_prompt(cfg, 1), 12, priority="low")
    for _ in range(3):
        eng.step()
    eng.submit(_prompt(cfg, 2), 2, priority="high", deadline_ms=60_000.0)
    eng.step()
    # capability declined: the long keeps its lane, with a recorded reason
    assert long.status is Status.RUNNING
    assert eng.n_preempted == 0
    assert eng.backend.preemptible is False
    assert "paged" in eng.backend.preempt_reason
    eng.run()


# ---------------------------------------------------------------------------
# overload shedding, in declared order
# ---------------------------------------------------------------------------

def test_hard_overload_sheds_lowest_tier_and_429s(dense):
    cfg, params = dense
    # preempt=False isolates shedding (a high arrival would otherwise
    # legitimately evict the running normal and muddy the assertions)
    eng = _paged(cfg, params, capacity=1,
                 policy=SLOPolicy(hard_overload_s=50.0, preempt=False))
    running = eng.submit(_prompt(cfg, 1), 24)
    eng.step()
    assert running.status is Status.RUNNING
    high = eng.submit(_prompt(cfg, 2), 4, priority="high")
    normal = eng.submit(_prompt(cfg, 3), 4)
    lows = [eng.submit(_prompt(cfg, s), 4, priority="low") for s in (4, 5)]
    eng._tok_s_ema = 10.0               # 10 "seconds" per queued token
    eng.step()
    # only the lowest waiting tier is shed; high/normal stay queued
    assert all(r.status is Status.REJECTED for r in lows)
    assert eng.n_shed == 2
    assert high.status is Status.QUEUED
    assert normal.status is Status.QUEUED
    assert all(r in list(eng.completed) for r in lows)
    assert "hard overload" in lows[0].shed_reason
    assert lows[0].metrics()["status"] == "rejected"
    # submit-time door: same-or-lower tier refused with structured 429
    with pytest.raises(OverloadedError) as ei:
        eng.submit(_prompt(cfg, 6), 4, priority="low")
    assert ei.value.payload["priority"] == "low"
    assert ei.value.payload["model"] == eng.model_name
    assert eng.n_shed == 3
    # strictly higher-priority traffic still lands under hard overload
    accepted = eng.submit(_prompt(cfg, 7), 4, priority="high")
    assert accepted.status is Status.QUEUED
    eng._tok_s_ema = None               # pressure clears; drain normally
    eng.run()
    assert high.status is Status.FINISHED
    assert accepted.status is Status.FINISHED


def test_soft_overload_degrades_spec_draft_before_shedding(dense):
    cfg, params = dense
    eng = InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                          backend="spec", draft_cfg=cfg, draft_params=params,
                          draft_k=2,
                          policy=SLOPolicy(soft_overload_s=0.0))
    reqs = [eng.submit(_prompt(cfg, s), 6) for s in (1, 2)]
    eng.run()
    # soft pressure: drafts were dropped (compute-only), nothing refused
    assert eng.backend.degraded_rounds > 0
    assert eng.backend.summary()["draft_steps"] == 0
    assert eng.n_shed == 0
    # degraded spec decode is still token-identical to plain decode
    slot = InferenceEngine(cfg, params, capacity=1, max_seq=MAX_SEQ,
                           backend="slot")
    for r, seed in zip(reqs, (1, 2)):
        ref = slot.submit(_prompt(cfg, seed), 6)
        slot.run()
        assert r.generated == ref.generated


# ---------------------------------------------------------------------------
# EDF beats FIFO on a fixed seeded trace (fake clock: deterministic)
# ---------------------------------------------------------------------------

def _traced_run(cfg, params, policy):
    clock = Tick()
    eng = _paged(cfg, params, capacity=1, policy=policy, clock=clock,
                 n_blocks=16)
    long = eng.submit(_prompt(cfg, 0), 12, priority="low")
    eng.step()                          # long admitted, 2 tokens in
    clock.t = 1.0
    shorts = [eng.submit(_prompt(cfg, s), 2, priority="high",
                         deadline_ms=6000.0) for s in (1, 2, 3)]
    while eng.has_work():
        eng.step()
        clock.t += 1.0                  # one fake second per tick
    return eng, long, shorts


def test_edf_beats_fifo_on_deadline_attainment(dense):
    cfg, params = dense
    fifo_eng, fifo_long, fifo_shorts = _traced_run(cfg, params, "fifo")
    slo_eng, slo_long, slo_shorts = _traced_run(cfg, params, "slo")
    attained = {
        "fifo": sum(r.metrics()["deadline_met"] for r in fifo_shorts),
        "slo": sum(r.metrics()["deadline_met"] for r in slo_shorts)}
    # FIFO drains the 12-token long first: every 6-fake-second deadline
    # blows.  EDF preempts it and the shorts land inside their budgets.
    assert attained["fifo"] == 0
    assert attained["slo"] == len(slo_shorts)
    assert slo_eng.n_preempted >= 1 and slo_eng.n_resumed >= 1
    assert fifo_eng.n_preempted == 0
    assert slo_long.preemptions >= 1
    # identity across policies — preempt/resume changed WHEN tokens were
    # computed, never WHICH tokens
    assert slo_long.generated == fifo_long.generated
    for a, b in zip(slo_shorts, fifo_shorts):
        assert a.generated == b.generated


# ---------------------------------------------------------------------------
# multi-model routing: deterministic ties + SLO urgency pre-pass
# ---------------------------------------------------------------------------

def test_lrtf_tie_break_is_deterministic(dense):
    cfg, params = dense

    def mk():
        return InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                               backend="slot")
    # adversarial dict order: "b" inserted first must not win the tie
    srv = MultiModelServer({"b": mk(), "a": mk()})
    srv.engines["a"].submit(_prompt(cfg, 1), 4)
    srv.engines["b"].submit(_prompt(cfg, 1), 4)     # identical work
    assert srv.step() == "a"


def test_slo_routing_prefers_urgent_engine(dense):
    cfg, params = dense

    def mk():
        return InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                               backend="slot")
    srv = MultiModelServer({"bulk": mk(), "urgent": mk()}, scheduler="slo")
    srv.engines["bulk"].submit(_prompt(cfg, 1), 20)         # LRTF's pick
    srv.engines["urgent"].submit(_prompt(cfg, 2), 2, deadline_ms=1.0)
    assert srv.step() == "urgent"       # slack < margin wins over work
    # without deadline pressure the router IS lrtf: bulk has more work
    srv.engines["urgent"].cancel_all_queued()
    srv.step()
    assert srv.schedule_trace[-1] == "bulk"
