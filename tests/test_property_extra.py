"""Additional hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import layers as nn
from repro.training.losses import softmax_xent


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(2, 32), st.integers(2, 16))
def test_xent_nonnegative_and_bounded(b, s, v):
    key = jax.random.PRNGKey(b * 1000 + s * 10 + v)
    logits = jax.random.normal(key, (b, s, v)) * 3
    labels = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0, v)
    loss = float(softmax_xent(logits, labels))
    assert 0.0 <= loss
    # xent <= logsumexp spread bound
    assert loss <= float(2 * 3 * np.sqrt(v) + np.log(v)) + 10


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 100))
def test_xent_perfect_prediction_goes_to_zero(seed):
    labels = jax.random.randint(jax.random.PRNGKey(seed), (2, 8), 0, 16)
    logits = 100.0 * jax.nn.one_hot(labels, 16)
    assert float(softmax_xent(logits, labels)) < 1e-3


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 2), st.sampled_from([16, 32]), st.sampled_from([4, 8]),
       st.sampled_from([16, 32]))
def test_attention_permutation_equivariance_over_batch(b, s, h, hd):
    """Permuting the batch permutes the output (no cross-batch leakage)."""
    key = jax.random.PRNGKey(s + h)
    q = jax.random.normal(key, (2, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, s, h, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, s, h, hd))
    out = nn.sdpa(q, k, v, causal=True)
    out_swapped = nn.sdpa(q[::-1], k[::-1], v[::-1], causal=True)
    np.testing.assert_allclose(out[::-1], out_swapped, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 30))
def test_causal_attention_prefix_stability(prefix):
    """Outputs at position < prefix don't depend on later tokens."""
    s = 32
    key = jax.random.PRNGKey(prefix)
    q = jax.random.normal(key, (1, s, 2, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, s, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, s, 2, 16))
    full = nn.sdpa(q, k, v, causal=True)
    # perturb the suffix of k/v
    k2 = k.at[:, prefix:].add(10.0)
    v2 = v.at[:, prefix:].add(10.0)
    out2 = nn.sdpa(q, k2, v2, causal=True)
    np.testing.assert_allclose(full[:, :prefix], out2[:, :prefix],
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 64))
def test_rope_norm_preserving(pos):
    """RoPE is a rotation: it preserves vector norms."""
    x = jax.random.normal(jax.random.PRNGKey(pos), (1, 1, 1, 64))
    r = nn.apply_rope(x, jnp.array([[pos]]))
    np.testing.assert_allclose(float(jnp.linalg.norm(r)),
                               float(jnp.linalg.norm(x)), rtol=1e-5)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 4), st.integers(1, 8))
def test_moe_capacity_never_negative_frac(e_pow, k):
    from repro.configs import get_config
    from repro.models.moe import expert_capacity
    cfg = get_config("mixtral-8x22b", smoke=True).replace(
        n_experts=2 ** e_pow, top_k=min(k, 2 ** e_pow))
    c = expert_capacity(cfg, 128)
    assert c >= 8 and c % 8 == 0
