"""HTTP + SSE online serving front-end (repro.serving.server).

Load-bearing properties: SSE chunk framing carries exactly the tokens the
engine decodes (byte-identical to a non-streaming completion AND to
offline decode), a client that disconnects mid-stream has its request
cancelled and its lane freed within a tick, and per-request engine
metrics match externally-measured timings under a frozen clock.
"""

import http.client
import json
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serving import (HydraHTTPServer, InferenceEngine,
                           MultiModelServer, Status, TokenStream,
                           encode_prompt)

MAX_SEQ = 64


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("qwen3-0.6b", smoke=True)
    return cfg, api.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def served(dense):
    """One live HTTP server over two engines (same params): ``m`` streams
    and has a route alias, ``locked`` is served with streaming disabled."""
    cfg, params = dense
    eng = InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                          model_name="m")
    locked = InferenceEngine(cfg, params, capacity=1, max_seq=MAX_SEQ,
                             model_name="locked")
    srv = HydraHTTPServer(
        MultiModelServer({"m": eng, "locked": locked}),
        model_options={"m": {"stream": True, "endpoint": "alias-m"},
                       "locked": {"stream": False}})
    with srv:
        yield srv, cfg, params, eng


def _prompt(cfg, seed, plen=8):
    rng = np.random.RandomState(seed)
    return rng.randint(0, cfg.vocab_size, plen).astype(np.int32)


def _post(srv, path, body):
    host, port = srv.address
    conn = http.client.HTTPConnection(host, port, timeout=120)
    try:
        conn.request("POST", path, json.dumps(body),
                     {"Content-Type": "application/json"})
        resp = conn.getresponse()
        return resp.status, json.loads(resp.read().decode())
    finally:
        conn.close()


def _stream_lines(srv, path, body, *, close_after=None):
    """POST an SSE request; returns the raw ``data:`` payload list (or a
    truncated one when ``close_after`` token chunks, closing the socket)."""
    host, port = srv.address
    conn = http.client.HTTPConnection(host, port, timeout=120)
    payloads, n_tokens = [], 0
    try:
        conn.request("POST", path, json.dumps(body),
                     {"Content-Type": "application/json"})
        resp = conn.getresponse()
        assert resp.status == 200
        assert resp.getheader("Content-Type") == "text/event-stream"
        while True:
            line = resp.readline()
            if not line:
                break
            line = line.rstrip(b"\n")
            if not line or line.startswith(b":"):
                continue
            assert line.startswith(b"data: ")      # SSE framing
            data = line[len(b"data: "):]
            if data == b"[DONE]":
                payloads.append("[DONE]")
                break
            event = json.loads(data)
            payloads.append(event)
            if "token_id" in event["choices"][0]:
                n_tokens += 1
                if close_after is not None and n_tokens >= close_after:
                    return payloads
    finally:
        conn.close()
    return payloads


# ---------------------------------------------------------------------------
# wire surface
# ---------------------------------------------------------------------------

def test_health_models_and_errors(served):
    srv, cfg, _, _ = served
    host, port = srv.address
    conn = http.client.HTTPConnection(host, port, timeout=30)
    conn.request("GET", "/v1/models")
    models = json.loads(conn.getresponse().read().decode())
    conn.close()
    assert {m["id"] for m in models["data"]} == {"m", "locked"}

    status, err = _post(srv, "/v1/completions",
                        {"model": "nope", "prompt": [1, 2], "max_tokens": 2})
    assert status == 404 and "unknown model" in err["error"]["message"]
    status, err = _post(srv, "/v1/completions",
                        {"model": "m", "prompt": [], "max_tokens": 2})
    assert status == 400
    status, err = _post(srv, "/v1/completions",      # exceeds max_seq
                        {"model": "m", "prompt": [1] * 8, "max_tokens": 500})
    assert status == 400 and "max_seq" in err["error"]["message"]
    status, err = _post(srv, "/v1/completions",
                        {"model": "locked", "prompt": [1, 2, 3],
                         "max_tokens": 2, "stream": True})
    assert status == 400 and "stream" in err["error"]["message"]


def test_sse_stream_token_identical_to_non_streaming_and_offline(served):
    from test_serving import _reference
    srv, cfg, params, _ = served
    prompt = _prompt(cfg, 11)
    gen = 6
    body = {"model": "m", "prompt": prompt.tolist(), "max_tokens": gen}

    status, full = _post(srv, "/v1/completions", body)
    assert status == 200
    full_ids = full["choices"][0]["token_ids"]

    events = _stream_lines(srv, "/v1/completions", dict(body, stream=True))
    assert events[-1] == "[DONE]"
    final = events[-2]
    chunks = [e for e in events[:-2]]
    sse_ids = [e["choices"][0]["token_id"] for e in chunks]
    # framing: every chunk is one token with its printable piece
    assert all(e["object"] == "text_completion" for e in chunks)
    assert [e["choices"][0]["text"] for e in chunks] == \
        [f" {t}" for t in sse_ids]
    assert final["choices"][0]["finish_reason"] == "length"
    assert final["usage"]["completion_tokens"] == gen
    assert final["metrics"]["status"] == "finished"

    offline = _reference(cfg, params, prompt, gen)
    assert sse_ids == full_ids == offline

    # the route alias resolves to the same model, same tokens
    status, via_alias = _post(srv, "/v1/completions",
                              dict(body, model="alias-m"))
    assert status == 200
    assert via_alias["choices"][0]["token_ids"] == offline


def test_chat_endpoint_stand_in_tokenizer_round_trip(served):
    srv, cfg, _, _ = served
    text = "hello"
    ids = encode_prompt(text, cfg.vocab_size).tolist()
    status, comp = _post(srv, "/v1/completions",
                         {"model": "m", "prompt": text, "max_tokens": 4})
    assert status == 200
    events = _stream_lines(
        srv, "/v1/chat/completions",
        {"model": "m", "messages": [{"role": "user", "content": text}],
         "max_tokens": 4, "stream": True})
    chunks = [e for e in events[:-2]]
    assert all(e["object"] == "chat.completion.chunk" for e in chunks)
    assert [e["choices"][0]["delta"]["content"] for e in chunks] == \
        [f" {e['choices'][0]['token_id']}" for e in chunks]
    # chat(messages=text) and completions(prompt=text) hit the same
    # byte-level encoding, so greedy decode gives identical tokens
    assert [e["choices"][0]["token_id"] for e in chunks] == \
        comp["choices"][0]["token_ids"]
    assert comp["usage"]["prompt_tokens"] == len(ids)


def test_cancel_endpoint_mid_decode(served):
    srv, cfg, _, eng = served
    rid = "http-cancel-1"
    done = []

    import threading

    def consume():
        done.append(_stream_lines(
            srv, "/v1/completions",
            {"model": "m", "prompt": _prompt(cfg, 12).tolist(),
             "max_tokens": 40, "stream": True, "request_id": rid}))
    t = threading.Thread(target=consume, daemon=True)
    t.start()
    deadline = time.time() + 30
    while time.time() < deadline:       # wait until it is really decoding
        if any(m["request_id"] == rid
               for m in (r.metrics() for r in eng.active_requests())):
            break
        time.sleep(0.01)
    status, ack = _post(srv, "/v1/cancel", {"request_id": rid})
    assert status == 200 and ack["cancelled"]
    t.join(timeout=30)
    assert done, "stream never terminated after cancel"
    events = done[0]
    assert events[-1] == "[DONE]"
    assert events[-2]["choices"][0]["finish_reason"] == "cancelled"
    n_streamed = sum(1 for e in events[:-2]
                     if "token_id" in e["choices"][0])
    assert n_streamed < 40              # decode really stopped early
    status, ack = _post(srv, "/v1/cancel", {"request_id": rid})
    assert status == 404                # already retired: nothing to cancel


def test_disconnect_mid_stream_frees_lane_within_a_tick(served):
    srv, cfg, _, eng = served
    rid = "http-disc-1"
    free_before = eng.n_free_lanes
    events = _stream_lines(
        srv, "/v1/completions",
        {"model": "m", "prompt": _prompt(cfg, 13).tolist(),
         "max_tokens": 40, "stream": True, "request_id": rid},
        close_after=2)                  # hang up after two tokens
    assert len(events) >= 2
    deadline = time.time() + 10
    freed = False
    while time.time() < deadline:
        if eng.n_free_lanes == free_before and not any(
                r.request_id == rid for r in eng.active_requests()):
            freed = True
            break
        time.sleep(0.01)
    assert freed, "disconnected request still holds its lane"
    # the disconnect rode the SAME cancel path: status survived retirement
    rec = [m for m in eng.recent_metrics() if m["request_id"] == rid]
    assert rec and rec[0]["status"] == "cancelled"
    assert eng.budget.reserved_bytes == 0


# ---------------------------------------------------------------------------
# metrics under a frozen clock match external measurement
# ---------------------------------------------------------------------------

def test_request_metrics_match_external_measurement_frozen_clock(dense):
    cfg, params = dense
    t = [100.0]
    eng = InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                          clock=lambda: t[0])
    req = eng.submit(_prompt(cfg, 14), 3)       # arrival stamped at t=100
    t[0] = 102.0
    eng.step()              # admit + prefill + first token, all at t=102
    t[0] = 105.0
    eng.run()               # remaining decode + retirement at t=105
    m = req.metrics()
    # externally-known truth: queued 100->102, first token at 102, done 105
    assert m["queue_wait_s"] == pytest.approx(2.0)
    assert m["ttft_s"] == pytest.approx(2.0)
    assert m["e2e_s"] == pytest.approx(5.0)
    assert m["decode_s"] == pytest.approx(3.0)
    assert req.arrival_time == 100.0 and req.finish_time == 105.0


def test_token_stream_iter_and_close_semantics():
    s = TokenStream("r")
    s.put(1)
    s.put(2)
    assert s.get(timeout=0.01) == 1
    s.close(Status.FINISHED)
    s.close(Status.CANCELLED)           # idempotent: first close wins
    assert list(s) == [2]
    assert s.status is Status.FINISHED and s.closed
    with pytest.raises(StopIteration):
        s.get(timeout=0.01)
