"""Deterministic (non-hypothesis) regressions for LRTF ordering and
spilling budget accounting, so scheduler/memory behavior stays covered even
when ``hypothesis`` is absent (the property suites degrade to fewer
examples via tests/_hypothesis_compat.py)."""

import itertools

import pytest

from repro.core import scheduler as sched
from repro.core.spilling import DeviceMemory, TransferStats


def _mp(i, remaining):
    return sched.ModelProgress.from_remaining(i, remaining)


# ---------------------------------------------------------------------------
# LRTF ordering
# ---------------------------------------------------------------------------

def test_from_remaining_roundtrips_seconds():
    assert _mp(0, 12.5).remaining_time() == pytest.approx(12.5)
    assert _mp(3, 0.0).remaining_time() == 0.0


def test_lrtf_orders_by_remaining_time_under_permutation():
    times = [3.0, 11.0, 7.0, 0.5]
    for perm in itertools.permutations(range(4)):
        ms = [_mp(i, times[i]) for i in perm]
        pick = sched.sharded_lrtf(ms)
        assert ms[pick].remaining_time() == max(times)


def test_lrtf_tie_breaks_to_first_eligible():
    ms = [_mp(7, 5.0), _mp(1, 5.0), _mp(2, 5.0)]
    assert sched.sharded_lrtf(ms) == 0


def test_lrtf_and_srtf_are_opposites():
    ms = [_mp(0, 1.0), _mp(1, 9.0), _mp(2, 4.0)]
    assert sched.sharded_lrtf(ms) == 1
    assert sched.sharded_srtf(ms) == 0


def test_lrtf_full_struct_ordering():
    # Algorithm 2 struct: remaining time dominates regardless of which of
    # e/b/ce/t/cm contributes it
    long_epochs = sched.ModelProgress(0, remaining_epochs=5,
                                      minibatches_per_epoch=4,
                                      remaining_in_epoch=4,
                                      minibatch_time=1.0,
                                      remaining_in_minibatch=1.0)   # 20.0
    long_minibatch = sched.ModelProgress(1, remaining_epochs=1,
                                         minibatches_per_epoch=1,
                                         remaining_in_epoch=1,
                                         minibatch_time=19.0,
                                         remaining_in_minibatch=19.0)
    assert sched.sharded_lrtf([long_epochs, long_minibatch]) == 0
    assert sched.sharded_lrtf([long_minibatch, long_epochs]) == 1


def test_lrtf_simulated_makespan_no_worse_than_srtf():
    # the paper's Fig-7 ordering at a fixed small instance
    times = [[4.0, 4.0, 4.0], [1.0], [2.0, 2.0], [1.0, 1.0]]
    lrtf = sched.greedy_list_makespan(times, 2, scheduler=sched.sharded_lrtf)
    srtf = sched.greedy_list_makespan(times, 2, scheduler=sched.sharded_srtf)
    opt = sched.optimal_makespan(times, 2)
    assert lrtf <= srtf
    assert lrtf == pytest.approx(opt)


# ---------------------------------------------------------------------------
# spilling budget accounting
# ---------------------------------------------------------------------------

def test_device_memory_promotion_accounting():
    dm = DeviceMemory(device_id=0, budget_bytes=1000, buffer_frac=0.1)
    dm.charge_promotion(400, into_buffer=False)
    dm.charge_promotion(80, into_buffer=True)
    assert dm.resident_bytes == 400
    assert dm.buffered_bytes == 80
    assert dm.stats.promoted_bytes == 480
    assert dm.stats.n_promotions == 2


def test_device_memory_activate_buffer_moves_bytes():
    dm = DeviceMemory(0, 1000)
    dm.charge_promotion(100, into_buffer=True)
    dm.activate_buffer()
    assert dm.resident_bytes == 100
    assert dm.buffered_bytes == 0


def test_device_memory_over_budget_raises():
    dm = DeviceMemory(0, 500)
    dm.charge_promotion(400, into_buffer=False)
    with pytest.raises(RuntimeError, match="over budget"):
        dm.charge_promotion(200, into_buffer=False)


def test_device_memory_kv_reservation_shares_budget():
    # serving KV pages and promoted shards charge ONE ledger
    dm = DeviceMemory(0, 1000)
    assert dm.reserve_kv(600)
    assert not dm.reserve_kv(500)          # would overflow: refused, no raise
    dm.charge_promotion(300, into_buffer=False)
    with pytest.raises(RuntimeError, match="kv pages"):
        dm.charge_promotion(200, into_buffer=False)
    dm.release_kv(600)
    assert dm.kv_peak_bytes == 600
    with pytest.raises(RuntimeError, match="matching reserve"):
        dm.release_kv(1)


def test_device_memory_demotion_floors_at_zero():
    dm = DeviceMemory(0, 1000)
    dm.charge_promotion(300, into_buffer=False)
    dm.charge_demotion(200)
    assert dm.resident_bytes == 100
    dm.charge_demotion(500)           # over-demotion clamps, never negative
    assert dm.resident_bytes == 0
    assert dm.stats.n_demotions == 2
    assert dm.stats.demoted_bytes == 700


def test_transfer_stats_totals():
    st = TransferStats(promoted_bytes=10, demoted_bytes=20, act_bytes_moved=5)
    assert st.total_bytes() == 35


def test_budget_cycle_promote_demote_repromote():
    # a full spilling cycle stays within budget and books traffic both ways
    dm = DeviceMemory(0, 1000)
    for _ in range(3):
        dm.charge_promotion(900, into_buffer=False)
        assert dm.resident_bytes + dm.buffered_bytes <= 1000
        dm.charge_demotion(900)
    assert dm.resident_bytes == 0
    assert dm.stats.promoted_bytes == dm.stats.demoted_bytes == 2700
