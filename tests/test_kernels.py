"""Pallas kernels vs pure-jnp oracles (interpret mode), with shape/dtype
sweeps + hypothesis property checks on the SSD recurrence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


@pytest.mark.parametrize("b,sq,sk,nh,nkv,hd,causal,window,dtype", [
    (2, 128, 128, 4, 2, 64, True, None, jnp.float32),
    (1, 256, 256, 8, 8, 32, True, 64, jnp.float32),
    (2, 100, 100, 4, 1, 64, True, None, jnp.float32),   # padding path
    (1, 128, 128, 4, 2, 128, False, None, jnp.float32),
    (1, 192, 192, 2, 2, 64, True, 32, jnp.bfloat16),
    (1, 64, 64, 2, 1, 16, True, None, jnp.float32),
])
def test_flash_attention(b, sq, sk, nh, nkv, hd, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, sq, nh, hd), dtype)
    k = jax.random.normal(ks[1], (b, sk, nkv, hd), dtype)
    v = jax.random.normal(ks[2], (b, sk, nkv, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              interpret=True, block_q=64, block_k=64)
    exp = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal,
        window=window).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 256, 2, 16, 8, 64),
    (1, 128, 4, 64, 32, 32),
    (1, 64, 1, 8, 8, 64),     # single chunk
    (2, 96, 2, 32, 16, 32),
])
def test_ssd_scan_kernel(b, s, h, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    la = -jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.1
    bc = jax.random.normal(ks[2], (b, s, h, n)) * 0.3
    cc = jax.random.normal(ks[3], (b, s, h, n)) * 0.3
    y, _ = ops.ssd_scan(x, la, bc, cc, chunk=chunk, interpret=True)
    ye = ref.ssd_scan_ref(x, la, bc, cc, chunk=chunk)
    np.testing.assert_allclose(y, ye, rtol=2e-4, atol=2e-4)


def test_ssd_chunked_model_impl_matches_sequential():
    """The model-side chunked SSD (ref for the kernel) == sequential scan."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    b, s, h, p, n, chunk = 2, 192, 3, 16, 8, 64
    x = jax.random.normal(ks[0], (b, s, h, p))
    la = -jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.2
    bc = jax.random.normal(ks[2], (b, s, h, n)) * 0.3
    cc = jax.random.normal(ks[3], (b, s, h, n)) * 0.3
    y, _ = ssd_chunked(x, la, bc, cc, chunk)
    ye = ref.ssd_scan_ref(x, la, bc, cc, chunk=chunk)
    np.testing.assert_allclose(y, ye, rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4), st.sampled_from([32, 64]),
       st.sampled_from([8, 16]), st.sampled_from([8, 16]))
def test_ssd_chunk_invariance(b, h, s_chunks, p, n):
    """Property: chunked SSD output is invariant to the chunk size."""
    from repro.models.ssm import ssd_chunked
    s = 64 * s_chunks
    ks = jax.random.split(jax.random.PRNGKey(b * 100 + h), 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    la = -jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.1
    bc = jax.random.normal(ks[2], (b, s, h, n)) * 0.3
    cc = jax.random.normal(ks[3], (b, s, h, n)) * 0.3
    y32, _ = ssd_chunked(x, la, bc, cc, 32)
    y64, _ = ssd_chunked(x, la, bc, cc, 64)
    np.testing.assert_allclose(y32, y64, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("rows,d,dtype", [
    (100, 256, jnp.float32), (256, 128, jnp.bfloat16), (7, 64, jnp.float32)])
def test_rmsnorm_kernel(rows, d, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (rows, d), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (d,)) * 0.1 + 1.0
    out = ops.rms_norm(x, w, interpret=True)
    exp = ref.rms_norm_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("m,d,f", [(100, 256, 300), (64, 128, 512)])
def test_swiglu_kernel(m, d, f):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(ks[0], (m, d))
    wg = jax.random.normal(ks[1], (d, f)) * 0.05
    wu = jax.random.normal(ks[2], (d, f)) * 0.05
    wd = jax.random.normal(ks[3], (f, d)) * 0.05
    out = ops.swiglu(x, wg, wu, wd, interpret=True)
    exp = ref.swiglu_ref(x, wg, wu, wd)
    np.testing.assert_allclose(out, exp, rtol=2e-4, atol=2e-4)


def test_model_attention_pallas_path():
    """cfg.attn_impl='pallas_interpret' end-to-end through a dense layer."""
    from repro.configs import get_config
    from repro.models import api
    cfg = get_config("qwen3-0.6b", smoke=True).replace(
        attn_impl="pallas_interpret", remat=False)
    cfg_x = cfg.replace(attn_impl="xla")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = api.make_dummy_batch(cfg, 1, 128)
    lp = api.forward(cfg, params, batch)
    lx = api.forward(cfg_x, params, batch)
    # bf16 end-to-end: per-layer 2^-8 rounding compounds over the stack
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lx),
                               rtol=5e-2, atol=5e-2)
    # and the implied distributions must effectively agree
    pp = jax.nn.softmax(lp.astype(jnp.float32), axis=-1)
    px = jax.nn.softmax(lx.astype(jnp.float32), axis=-1)
    assert float(jnp.max(jnp.abs(pp - px))) < 5e-3
