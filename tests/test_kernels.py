"""Pallas kernels vs pure-jnp oracles (interpret mode), with shape/dtype
sweeps + hypothesis property checks on the SSD recurrence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


@pytest.mark.parametrize("b,sq,sk,nh,nkv,hd,causal,window,dtype", [
    (2, 128, 128, 4, 2, 64, True, None, jnp.float32),
    (1, 256, 256, 8, 8, 32, True, 64, jnp.float32),
    (2, 100, 100, 4, 1, 64, True, None, jnp.float32),   # padding path
    (1, 128, 128, 4, 2, 128, False, None, jnp.float32),
    (1, 192, 192, 2, 2, 64, True, 32, jnp.bfloat16),
    (1, 64, 64, 2, 1, 16, True, None, jnp.float32),
])
def test_flash_attention(b, sq, sk, nh, nkv, hd, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, sq, nh, hd), dtype)
    k = jax.random.normal(ks[1], (b, sk, nkv, hd), dtype)
    v = jax.random.normal(ks[2], (b, sk, nkv, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              interpret=True, block_q=64, block_k=64)
    exp = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal,
        window=window).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("n,nh,nkv,hd,bs,B,P,window,dtype", [
    (3, 8, 2, 64, 8, 4, 16, None, jnp.float32),     # GQA, multi-block
    (2, 4, 4, 32, 16, 2, 8, None, jnp.float32),     # MHA
    (4, 8, 1, 64, 8, 8, 33, None, jnp.float32),     # deep tables
    (2, 8, 2, 64, 8, 4, 16, 5, jnp.float32),        # sliding window
    (3, 4, 2, 32, 8, 3, 12, None, jnp.bfloat16),    # serving dtype
    (1, 2, 1, 16, 4, 1, 2, None, jnp.float32),      # single block
])
def test_paged_attention_kernel(n, nh, nkv, hd, bs, B, P, window, dtype):
    """Pallas paged attention (scalar-prefetched block tables) == the
    gather-based oracle, across GQA/window/partial-length shapes."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (n, nh, hd), dtype)
    kp = jax.random.normal(ks[1], (P, bs, nkv, hd), dtype)
    vp = jax.random.normal(ks[2], (P, bs, nkv, hd), dtype)
    rng = np.random.default_rng(n * 100 + B)
    # distinct physical blocks per lane, never the garbage block 0
    tables = jnp.asarray(
        (rng.permutation(P - 1)[: n * B] + 1).reshape(n, B), jnp.int32)
    # lengths cover: partial first block, exact block boundary, full table
    lengths = jnp.asarray(
        [max(1, (i * B * bs) // n) if i else bs // 2 for i in range(n)]
        [: n], jnp.int32)
    lengths = jnp.clip(lengths, 1, B * bs)
    out = ops.paged_attention(q, kp, vp, tables, lengths, window=window,
                              impl="pallas_interpret")
    exp = ref.paged_attention_ref(q, kp, vp, tables, lengths, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


def test_paged_attention_ref_matches_contiguous():
    """The oracle itself == dense softmax over the gathered contiguous
    prefix — pins the block-table indexing convention."""
    import math
    n, nh, nkv, hd, bs, B, P = 2, 4, 2, 32, 8, 3, 10
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (n, nh, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (P, bs, nkv, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (P, bs, nkv, hd), jnp.float32)
    tables = jnp.asarray([[4, 2, 7], [1, 9, 3]], jnp.int32)
    lengths = [13, 24]
    out = ref.paged_attention_ref(q, kp, vp, tables,
                                  jnp.asarray(lengths, jnp.int32))
    g = nh // nkv
    k_all = np.asarray(kp)[np.asarray(tables)].reshape(n, B * bs, nkv, hd)
    v_all = np.asarray(vp)[np.asarray(tables)].reshape(n, B * bs, nkv, hd)
    for i, L in enumerate(lengths):
        qi = np.asarray(q)[i].reshape(nkv, g, hd)
        s = np.einsum("kgh,skh->kgs", qi, k_all[i, :L]) / math.sqrt(hd)
        p = np.exp(s - s.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        o = np.einsum("kgs,skh->kgh", p, v_all[i, :L]).reshape(nh, hd)
        np.testing.assert_allclose(np.asarray(out)[i], o,
                                   rtol=1e-5, atol=1e-5)


def test_paged_attention_ignores_stale_pages():
    """Rows past a lane's length (garbage block, recycled pages) must
    contribute exactly zero weight: rewriting them cannot change logits."""
    n, nh, nkv, hd, bs, B, P = 1, 2, 1, 16, 4, 2, 6
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (n, nh, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (P, bs, nkv, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (P, bs, nkv, hd), jnp.float32)
    tables = jnp.asarray([[2, 5]], jnp.int32)
    lengths = jnp.asarray([5], jnp.int32)        # one row into block 5
    base = ref.paged_attention_ref(q, kp, vp, tables, lengths)
    # trash every row the mask should hide: block 5 rows [1:], block 0
    kp2 = kp.at[5, 1:].set(999.0).at[0].set(-999.0)
    vp2 = vp.at[5, 1:].set(999.0).at[0].set(-999.0)
    out = ref.paged_attention_ref(q, kp2, vp2, tables, lengths)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(out))


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 256, 2, 16, 8, 64),
    (1, 128, 4, 64, 32, 32),
    (1, 64, 1, 8, 8, 64),     # single chunk
    (2, 96, 2, 32, 16, 32),
])
def test_ssd_scan_kernel(b, s, h, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    la = -jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.1
    bc = jax.random.normal(ks[2], (b, s, h, n)) * 0.3
    cc = jax.random.normal(ks[3], (b, s, h, n)) * 0.3
    y, _ = ops.ssd_scan(x, la, bc, cc, chunk=chunk, interpret=True)
    ye = ref.ssd_scan_ref(x, la, bc, cc, chunk=chunk)
    np.testing.assert_allclose(y, ye, rtol=2e-4, atol=2e-4)


def test_ssd_chunked_model_impl_matches_sequential():
    """The model-side chunked SSD (ref for the kernel) == sequential scan."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    b, s, h, p, n, chunk = 2, 192, 3, 16, 8, 64
    x = jax.random.normal(ks[0], (b, s, h, p))
    la = -jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.2
    bc = jax.random.normal(ks[2], (b, s, h, n)) * 0.3
    cc = jax.random.normal(ks[3], (b, s, h, n)) * 0.3
    y, _ = ssd_chunked(x, la, bc, cc, chunk)
    ye = ref.ssd_scan_ref(x, la, bc, cc, chunk=chunk)
    np.testing.assert_allclose(y, ye, rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4), st.sampled_from([32, 64]),
       st.sampled_from([8, 16]), st.sampled_from([8, 16]))
def test_ssd_chunk_invariance(b, h, s_chunks, p, n):
    """Property: chunked SSD output is invariant to the chunk size."""
    from repro.models.ssm import ssd_chunked
    s = 64 * s_chunks
    ks = jax.random.split(jax.random.PRNGKey(b * 100 + h), 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    la = -jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.1
    bc = jax.random.normal(ks[2], (b, s, h, n)) * 0.3
    cc = jax.random.normal(ks[3], (b, s, h, n)) * 0.3
    y32, _ = ssd_chunked(x, la, bc, cc, 32)
    y64, _ = ssd_chunked(x, la, bc, cc, 64)
    np.testing.assert_allclose(y32, y64, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("rows,d,dtype", [
    (100, 256, jnp.float32), (256, 128, jnp.bfloat16), (7, 64, jnp.float32)])
def test_rmsnorm_kernel(rows, d, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (rows, d), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (d,)) * 0.1 + 1.0
    out = ops.rms_norm(x, w, interpret=True)
    exp = ref.rms_norm_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("m,d,f", [(100, 256, 300), (64, 128, 512)])
def test_swiglu_kernel(m, d, f):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(ks[0], (m, d))
    wg = jax.random.normal(ks[1], (d, f)) * 0.05
    wu = jax.random.normal(ks[2], (d, f)) * 0.05
    wd = jax.random.normal(ks[3], (f, d)) * 0.05
    out = ops.swiglu(x, wg, wu, wd, interpret=True)
    exp = ref.swiglu_ref(x, wg, wu, wd)
    np.testing.assert_allclose(out, exp, rtol=2e-4, atol=2e-4)


def test_model_attention_pallas_path():
    """cfg.attn_impl='pallas_interpret' end-to-end through a dense layer."""
    from repro.configs import get_config
    from repro.models import api
    cfg = get_config("qwen3-0.6b", smoke=True).replace(
        attn_impl="pallas_interpret", remat=False)
    cfg_x = cfg.replace(attn_impl="xla")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = api.make_dummy_batch(cfg, 1, 128)
    lp = api.forward(cfg, params, batch)
    lx = api.forward(cfg_x, params, batch)
    # bf16 end-to-end: per-layer 2^-8 rounding compounds over the stack
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lx),
                               rtol=5e-2, atol=5e-2)
    # and the implied distributions must effectively agree
    pp = jax.nn.softmax(lp.astype(jnp.float32), axis=-1)
    px = jax.nn.softmax(lx.astype(jnp.float32), axis=-1)
    assert float(jnp.max(jnp.abs(pp - px))) < 5e-3
