"""Optimizers + schedules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.optim import optimizers as opt


def test_adamw_first_step_closed_form():
    cfg = opt.OptimizerConfig(kind="adamw", lr=0.1, b1=0.9, b2=0.99,
                              eps=1e-8, weight_decay=0.0, grad_clip=0.0)
    p = {"w": jnp.array([1.0])}
    g = {"w": jnp.array([0.5])}
    s = opt.init_state(cfg, p)
    new_p, _ = opt.update(cfg, p, g, s)
    # after bias correction the first step is lr * g/|g| = lr
    np.testing.assert_allclose(new_p["w"], 1.0 - 0.1 * 0.5 / (0.5 + 1e-8),
                               rtol=1e-5)


def test_sgd_momentum():
    cfg = opt.OptimizerConfig(kind="sgd", lr=1.0, momentum=0.5,
                              weight_decay=0.0, grad_clip=0.0)
    p = {"w": jnp.zeros(())}
    s = opt.init_state(cfg, p)
    g = {"w": jnp.ones(())}
    p, s = opt.update(cfg, p, g, s)
    assert float(p["w"]) == -1.0
    p, s = opt.update(cfg, p, g, s)
    assert float(p["w"]) == -2.5     # momentum: 1 + 0.5*1 = 1.5 more


def test_lion_sign_update():
    cfg = opt.OptimizerConfig(kind="lion", lr=0.1, weight_decay=0.0,
                              grad_clip=0.0)
    p = {"w": jnp.array([0.0, 0.0])}
    s = opt.init_state(cfg, p)
    g = {"w": jnp.array([3.0, -0.01])}
    p, s = opt.update(cfg, p, g, s)
    np.testing.assert_allclose(p["w"], [-0.1, 0.1], rtol=1e-6)


def test_grad_clip_global_norm():
    g = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}   # norm 5
    clipped, norm = opt.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(5.0)
    total = jnp.sqrt(clipped["a"]**2 + clipped["b"]**2)
    assert float(total[0]) == pytest.approx(1.0, rel=1e-5)


def test_schedule_warmup_cosine():
    cfg = opt.OptimizerConfig(lr=1.0, schedule="linear_warmup_cosine",
                              warmup_steps=10, total_steps=110,
                              min_lr_ratio=0.1)
    assert float(opt.schedule_lr(cfg, 0)) == 0.0
    assert float(opt.schedule_lr(cfg, 10)) == pytest.approx(1.0)
    assert float(opt.schedule_lr(cfg, 110)) == pytest.approx(0.1, rel=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10**6))
def test_schedule_bounded(step):
    cfg = opt.OptimizerConfig(lr=2.5, schedule="linear_warmup_cosine",
                              warmup_steps=100, total_steps=1000)
    lr = float(opt.schedule_lr(cfg, step))
    assert 0.0 <= lr <= 2.5 + 1e-6


def test_per_shard_update_equals_full_update():
    """Stepping disjoint sub-trees independently == stepping the full tree
    (with clipping off) — the invariant Hydra's per-shard stepping relies on."""
    cfg = opt.OptimizerConfig(kind="adamw", lr=0.05, grad_clip=0.0)
    key = jax.random.PRNGKey(0)
    p = {"a": jax.random.normal(key, (4, 4)),
         "b": jax.random.normal(jax.random.fold_in(key, 1), (3,))}
    g = jax.tree.map(jnp.ones_like, p)
    s = opt.init_state(cfg, p)
    full_p, _ = opt.update(cfg, p, g, s)
    pa, _ = opt.update(cfg, {"a": p["a"]}, {"a": g["a"]},
                       opt.init_state(cfg, {"a": p["a"]}))
    pb, _ = opt.update(cfg, {"b": p["b"]}, {"b": g["b"]},
                       opt.init_state(cfg, {"b": p["b"]}))
    np.testing.assert_allclose(full_p["a"], pa["a"], rtol=1e-6)
    np.testing.assert_allclose(full_p["b"], pb["b"], rtol=1e-6)
