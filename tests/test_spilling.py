"""Model spilling (paper §4.2): promote/demote roundtrips, budget
enforcement, shared-grad accumulation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import partitioner as pt
from repro.core import shard_graph as sg
from repro.core.spilling import DeviceMemory, HostModelStore
from repro.models import api
from repro.optim import OptimizerConfig


def _store(arch="qwen3-0.6b", budget=20 * 10**6):
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    plan = sg.build_plan(cfg)
    host = sg.prepare_host_params(cfg, jax.tree.map(np.array, params))
    part = pt.partition(cfg, host, plan, budget_bytes=budget, batch=2, seq=64)
    store = HostModelStore(cfg, plan, params, OptimizerConfig(grad_clip=0.0),
                           part)
    return cfg, plan, part, store, params


def test_promote_demote_roundtrip_bit_exact():
    cfg, plan, part, store, params = _store()
    before = jax.tree.map(np.array, store.params)
    for shard in part.shards:
        own, shared, opt_state = store.promote_shard(shard)
        store.demote_shard(shard, own, opt_state)
    after = store.params
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_model_params_roundtrip_matches_original():
    cfg, plan, part, store, params = _store()
    rebuilt = store.model_params()
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(rebuilt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shared_grad_accumulation():
    cfg, plan, part, store, params = _store()
    ref = sg.resolve_ref(store.params, plan.shared_refs["embed"])
    g1 = jax.tree.map(lambda a: np.ones_like(np.asarray(a)), ref)
    store.accumulate_shared_grads({"embed": g1})
    store.accumulate_shared_grads({"embed": g1})
    acc = store.shared_grad_acc["embed"]
    assert float(np.asarray(jax.tree.leaves(acc)[0]).max()) == 2.0
    before = np.array(jax.tree.leaves(ref)[0])
    store.step_shared()
    after = np.asarray(jax.tree.leaves(
        sg.resolve_ref(store.params, plan.shared_refs["embed"]))[0])
    assert not np.allclose(before, after)     # params moved
    assert store.shared_grad_acc == {}        # accumulator cleared


def test_device_budget_enforced():
    dev = DeviceMemory(0, budget_bytes=1000, buffer_frac=0.1)
    dev.charge_promotion(900, into_buffer=False)
    with pytest.raises(RuntimeError, match="over budget"):
        dev.charge_promotion(200, into_buffer=True)


def test_double_buffer_regions():
    dev = DeviceMemory(0, budget_bytes=1000)
    dev.charge_promotion(300, into_buffer=True)
    assert dev.buffered_bytes == 300 and dev.resident_bytes == 0
    dev.activate_buffer()
    assert dev.buffered_bytes == 0 and dev.resident_bytes == 300
    dev.charge_demotion(300)
    assert dev.resident_bytes == 0
    assert dev.stats.n_promotions == 1 and dev.stats.n_demotions == 1


def test_transfer_bytes_accounting():
    cfg, plan, part, store, params = _store()
    for shard in part.shards:
        tb = store.shard_transfer_bytes(shard)
        assert tb > 0
        # train transfer includes optimizer state (params x >= 2)
        own_only = sum(pt.tree_bytes(p) for p in store._own_params(shard)
                       if p is not None)
        assert tb >= 2 * own_only
