"""Block recycling, fragmentation, and budget invariants of the paged KV
cache (repro.serving.paging + the paged InferenceEngine path).

The load-bearing properties:

* blocks freed by retired requests are REUSED — lifetime allocations
  exceed the peak simultaneously-used blocks whenever requests outnumber
  lanes, and the free list always returns to full after a drain;
* peak page bytes (physically allocated) never exceed reserved bytes,
  which never exceed the byte budget;
* paged outputs are token-identical to sequential per-request decode,
  for arbitrary workloads (the property hypothesis sweeps).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.spilling import DeviceMemory
from repro.models import api
from repro.serving import BlockPool, InferenceEngine, blocks_for_rows
from repro.training.train_loop import make_decode_step, make_prefill_into_cache

MAX_SEQ = 48


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("qwen3-0.6b", smoke=True)
    return cfg, api.init_params(cfg, jax.random.PRNGKey(0))


def _prompt(cfg, seed, plen):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (plen,), 0, cfg.vocab_size, jnp.int32))


@functools.lru_cache(maxsize=None)
def _ref_steps(cfg):
    return (jax.jit(make_prefill_into_cache(cfg)),
            jax.jit(make_decode_step(cfg)))


def _reference(cfg, params, prompt, gen, max_seq=MAX_SEQ):
    prefill, decode = _ref_steps(cfg)
    state = api.init_decode_state(cfg, 1, max_seq)
    logits, state = prefill(params, state, jnp.asarray(prompt)[None, :])
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out = [int(tok[0, 0])]
    for _ in range(gen - 1):
        tok, state = decode(params, state, tok)
        out.append(int(tok[0, 0]))
    return out


# ---------------------------------------------------------------------------
# BlockPool unit behavior
# ---------------------------------------------------------------------------

def test_block_pool_alloc_free_cycle(dense):
    cfg, _ = dense
    pool = BlockPool(cfg, n_blocks=5, block_size=4)
    assert pool.n_allocatable == 4 and pool.n_free == 4
    a = pool.alloc(2)
    b = pool.alloc(2)
    assert BlockPool.GARBAGE not in a + b       # block 0 never handed out
    assert pool.n_free == 0 and pool.n_used == 4
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc(1)
    pool.free(a)
    c = pool.alloc(2)
    assert sorted(c) == sorted(a)               # freed blocks are reused
    assert pool.total_allocs == 6 and pool.peak_used == 4
    pool.free(b)
    with pytest.raises(RuntimeError, match="not allocated"):
        pool.free([b[0]])                       # double free
    with pytest.raises(RuntimeError, match="not allocated"):
        pool.free([BlockPool.GARBAGE])


def test_block_pool_rejects_degenerate_shapes(dense):
    cfg, _ = dense
    with pytest.raises(ValueError):
        BlockPool(cfg, n_blocks=1, block_size=4)
    with pytest.raises(ValueError):
        BlockPool(cfg, n_blocks=4, block_size=0)


def test_blocks_for_rows():
    assert blocks_for_rows(1, 8) == 1
    assert blocks_for_rows(8, 8) == 1
    assert blocks_for_rows(9, 8) == 2


# ---------------------------------------------------------------------------
# recycling + fragmentation + budget properties
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.lists(st.integers(0, 1000), min_size=4, max_size=9),
       st.sampled_from([4, 8]),
       st.integers(2, 3))
def test_paged_recycling_budget_and_token_identity(seeds, block_size,
                                                   capacity):
    """Random workloads: blocks recycle, peaks stay bounded by the budget,
    and every request decodes token-identically to its solo reference."""
    cfg = get_config("qwen3-0.6b", smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    ledger = DeviceMemory(-1, budget_bytes=10**9)
    eng = InferenceEngine(cfg, params, capacity=capacity, max_seq=MAX_SEQ,
                          paged=True, block_size=block_size, ledger=ledger)
    work = []
    for i, seed in enumerate(seeds):
        plen = 1 + seed % 14
        gen = 1 + (seed // 17) % 7
        prompt = _prompt(cfg, 7000 + seed + i, plen)
        work.append((prompt, gen, eng.submit(prompt, gen)))
    n_free0 = eng.pool.n_allocatable
    while eng.step():
        # physically allocated pages never outrun the reservation, which
        # never outruns the ledger budget
        assert eng.pool.used_bytes() <= eng.budget.reserved_bytes
        assert eng.budget.reserved_bytes <= ledger.budget
    # drained: every block back on the free list, reservation fully released
    assert eng.pool.n_free == n_free0
    assert eng.budget.reserved_bytes == 0 and ledger.kv_reserved_bytes == 0
    if len(work) > capacity:
        # more requests than lanes forces retire->admit reuse of blocks
        assert eng.pool.total_allocs > eng.pool.peak_used
    for prompt, gen, req in work:
        assert req.generated == _reference(cfg, params, prompt, gen), \
            f"{req.request_id} diverged from solo decode"


def test_paged_tight_budget_serializes_but_serves_all(dense):
    """A budget worth ONE request's pages degrades to sequential admission
    — nothing starves, nothing overruns."""
    cfg, params = dense
    block_size = 8
    one_req = blocks_for_rows(MAX_SEQ, block_size) \
        * api.kv_block_bytes(cfg, block_size)
    eng = InferenceEngine(cfg, params, capacity=4, max_seq=MAX_SEQ,
                          paged=True, block_size=block_size,
                          kv_budget_bytes=one_req)
    reqs = [eng.submit(_prompt(cfg, 300 + i, 40), 8) for i in range(3)]
    while eng.step():
        assert len(eng.active_requests()) <= 1
        assert eng.budget.reserved_bytes <= one_req
    assert all(r.generated == _reference(cfg, params,
                                         _prompt(cfg, 300 + i, 40), 8)
               for i, r in enumerate(reqs))
    assert eng.peak_concurrency == 1


def test_paged_growth_crosses_block_boundaries(dense):
    """A request whose decode extends well past its prompt blocks grows
    page-by-page: peak blocks == blocks for its final extent, and the
    request-level metric records the growth."""
    cfg, params = dense
    eng = InferenceEngine(cfg, params, capacity=1, max_seq=MAX_SEQ,
                          paged=True, block_size=4)
    plen, gen = 3, 20                       # 3 -> 22 rows: 1 -> 6 blocks
    req = eng.submit(_prompt(cfg, 400, plen), gen)
    eng.run()
    assert req.generated == _reference(cfg, params, _prompt(cfg, 400, plen),
                                       gen)
    rows = plen + gen - 1
    assert req.peak_blocks == blocks_for_rows(rows, 4)
    assert req.metrics()["kv_peak_blocks"] == req.peak_blocks
    assert req.metrics()["kv_reserved_blocks"] == req.reserved_blocks
    assert eng.pool.peak_used == req.peak_blocks


def test_shared_ledger_arbitrates_two_engines(dense):
    """Two paged engines over ONE DeviceMemory: their combined reservation
    respects the single budget (multi-model serving on one device)."""
    cfg, params = dense
    block_size = 8
    blocks_per = blocks_for_rows(MAX_SEQ, block_size)
    block_bytes = api.kv_block_bytes(cfg, block_size)
    ledger = DeviceMemory(0, budget_bytes=2 * blocks_per * block_bytes)
    engines = [InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                               paged=True, block_size=block_size,
                               ledger=ledger, model_name=f"m{i}")
               for i in range(2)]
    for i, eng in enumerate(engines):
        for j in range(2):
            eng.submit(_prompt(cfg, 500 + 10 * i + j, 40), 6)
    while any(e.has_work() for e in engines):
        for e in engines:
            e.step()
        assert ledger.kv_reserved_bytes <= ledger.budget
    assert all(len(e.completed) == 2 for e in engines)
    assert ledger.kv_reserved_bytes == 0
    assert ledger.kv_peak_bytes <= ledger.budget


def test_submit_rejects_never_admissible_request(dense):
    """A reservation that can never fit must be rejected at submit —
    queued forever at the FIFO head would livelock admission (run() spins
    with has_work() True and nothing ever admitted)."""
    cfg, params = dense
    block_bytes = api.kv_block_bytes(cfg, 8)
    eng = InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                          paged=True, block_size=8,
                          kv_budget_bytes=2 * block_bytes)
    with pytest.raises(ValueError, match="never admit"):
        eng.submit(_prompt(cfg, 1, 20), 10)      # needs 4 blocks, budget 2
    req = eng.submit(_prompt(cfg, 1, 8), 8)      # 2 blocks: admissible
    eng.run()
    assert req.done


def test_physical_pool_capped_by_budget(dense):
    """The pages pytree must not materialize worst-case blocks a tight
    byte budget can never admit."""
    cfg, params = dense
    block_bytes = api.kv_block_bytes(cfg, 8)
    eng = InferenceEngine(cfg, params, capacity=8, max_seq=MAX_SEQ,
                          paged=True, block_size=8,
                          kv_budget_bytes=3 * block_bytes)
    assert eng.pool.n_allocatable == 3           # not capacity * max_blocks
    # an explicit n_blocks still wins (caller opted into the size)
    eng2 = InferenceEngine(cfg, params, capacity=8, max_seq=MAX_SEQ,
                           paged=True, block_size=8, n_blocks=10,
                           kv_budget_bytes=3 * block_bytes)
    assert eng2.pool.n_blocks == 10


def test_int8_kv_admits_more_under_same_budget(dense):
    """int8 KV pages under the SAME byte budget: strictly higher admitted
    concurrency than the fp pool, and token-identical outputs.  The
    budget is sized so fp can hold exactly 2 in-flight reservations (2
    blocks each) — int8 blocks are strictly smaller (1 byte + amortized
    scale per row element vs 2+ for bf16, 4 for f32), so the quantized
    pool must run strictly more lanes at once (the whole point of paying
    for quantization)."""
    cfg, params = dense
    fp_block = api.kv_block_bytes(cfg, 8)
    assert api.kv_block_bytes(cfg, 8, "int8") < fp_block
    # every request below reserves 2 blocks (prompt + gen - 1 <= 16 rows)
    budget = 4 * fp_block
    results = {}
    for kv_dtype in (None, "int8"):
        eng = InferenceEngine(cfg, params, capacity=6, max_seq=MAX_SEQ,
                              paged=True, block_size=8, kv_dtype=kv_dtype,
                              kv_budget_bytes=budget)
        reqs = [eng.submit(_prompt(cfg, 900 + i, 4 + i), 6)
                for i in range(6)]
        peak = 0
        while eng.step():
            peak = max(peak, len(eng.active_requests()))
        results[kv_dtype] = (peak, [r.generated for r in reqs])
    (fp_peak, fp_toks), (q_peak, q_toks) = results[None], results["int8"]
    assert q_peak > fp_peak, \
        f"int8 admitted {q_peak} lanes vs fp {fp_peak} under one budget"
    assert q_toks == fp_toks, "int8 KV decode diverged from fp"
