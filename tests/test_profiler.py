"""Machine profiler (repro.profiler): MachineFacts (de)serialization and
staleness gating, CostModel monotonicity + analytic byte-identity, plan
provenance round-trips, and the what-if pricing path."""

import json
import warnings

import pytest

from conftest import make_loader
from repro.api import HydraConfig, Plan, Session, TrainJob
from repro.configs import get_config
from repro.profiler import (ANALYTIC_HARDWARE, CostModel, MachineFacts,
                            StaleProfileWarning, current_fingerprint,
                            hardware_constants, load_facts)
from repro.profiler.cost import (ANALYTIC_SHARD_SECONDS_PER_WEIGHTED_BYTE,
                                 ANALYTIC_TOK_SECONDS_PER_PARAM,
                                 _monotone_grid)

BUDGET = 18 * 10**6


def _cfg():
    return get_config("qwen3-0.6b", smoke=True)


def _hc():
    return HydraConfig(n_devices=2, device_budget_bytes=BUDGET)


def _fresh_facts(**kw) -> MachineFacts:
    return MachineFacts(fingerprint=current_fingerprint(), **kw)


def _measured_facts(cfg) -> MachineFacts:
    """Synthetic fresh facts with a dense-family decode grid around cfg."""
    return _fresh_facts(decode={
        cfg.family: {
            "arch": cfg.name,
            "n_active_params": cfg.n_active_params,
            "batches": [1, 2],
            "seqs": [32, 64],
            "decode_step_s": [[1e-4, 2e-4], [3e-4, 4e-4]],
            "prefill_s_per_token": [[1e-5, 1e-5], [9e-6, 9e-6]],
        }})


# ---------------------------------------------------------------------------
# MachineFacts: round trip, schema gating, staleness
# ---------------------------------------------------------------------------

def test_facts_json_round_trip(tmp_path):
    facts = _measured_facts(_cfg())
    facts.hardware["hbm_bw"] = 123e9
    path = facts.save(str(tmp_path / "profile.json"))
    loaded = MachineFacts.load(path)
    assert loaded.to_dict() == facts.to_dict()
    assert loaded.to_json() == facts.to_json()
    # and through the gated loader (fresh fingerprint -> accepted)
    assert load_facts(path).to_dict() == facts.to_dict()


def test_facts_schema_version_rejected(tmp_path):
    d = _fresh_facts().to_dict()
    d["schema_version"] = 999
    path = tmp_path / "profile.json"
    path.write_text(json.dumps(d))
    with pytest.raises(ValueError, match="schema_version"):
        MachineFacts.load(str(path))


def test_load_facts_missing_ok(tmp_path):
    assert load_facts(str(tmp_path / "nope.json"), missing_ok=True) is None
    with pytest.raises(FileNotFoundError):
        load_facts(str(tmp_path / "nope.json"))


def test_stale_profile_warns_and_falls_back(tmp_path):
    facts = _measured_facts(_cfg())
    facts.fingerprint = dict(facts.fingerprint, device_kind="TPU v9000")
    path = facts.save(str(tmp_path / "profile.json"))
    with pytest.warns(StaleProfileWarning):
        assert load_facts(path) is None
    # ungated load for the what-if tool still reads it
    assert load_facts(path, require_fresh=False).decode
    # CostModel itself also refuses stale facts...
    with pytest.warns(StaleProfileWarning):
        cm = CostModel(MachineFacts.load(path))
    assert not cm.measured
    cfg = _cfg()
    assert cm.tok_seconds(cfg) == \
        ANALYTIC_TOK_SECONDS_PER_PARAM * cfg.n_active_params
    # ...unless the caller opts in (what-if pricing)
    cm2 = CostModel(MachineFacts.load(path), allow_stale=True)
    assert cm2.measured and cm2.has_decode_facts(cfg)


def test_hardware_constants_analytic_default_byte_identical():
    from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
    hw = hardware_constants(None)
    assert hw["source"] == "analytic"
    assert hw["peak_flops_bf16"] == PEAK_FLOPS_BF16 == 197e12
    assert hw["hbm_bw"] == HBM_BW == 819e9
    assert hw["ici_bw"] == ICI_BW == 50e9
    # facts that never overrode hardware stay analytic
    assert hardware_constants(_fresh_facts())["source"] == "analytic"
    f = _fresh_facts()
    f.hardware["hbm_bw"] = 100e9
    hw = hardware_constants(f)
    assert hw["source"] == "measured" and hw["hbm_bw"] == 100e9
    assert hw["ici_bw"] == ANALYTIC_HARDWARE["ici_bw"]


# ---------------------------------------------------------------------------
# CostModel: analytic parity + monotonicity
# ---------------------------------------------------------------------------

def test_analytic_shard_runtimes_byte_identical():
    cfg = _cfg()
    cm = CostModel(None)
    weights = [3.7e9, 1.2e8, 5.5e9]
    got = cm.shard_runtimes(cfg, weights, batch=2, seq=64)
    want = [(w * 1e-12, 2 * (w * 1e-12)) for w in weights]
    assert got == want      # same values AND same float evaluation order
    assert cm.provenance[f"partition:{cfg.name}"]["source"] == "analytic"


def test_monotone_grid_clamps_noise():
    noisy = [[2.0, 1.0], [1.5, 0.5]]
    g = _monotone_grid(noisy)
    for i in range(2):
        assert g[i][0] <= g[i][1]
        assert g[0][i] <= g[1][i]


def test_costmodel_more_tokens_never_cheaper():
    cfg = _cfg()
    cm = CostModel(_measured_facts(cfg))
    assert cm.has_decode_facts(cfg)
    # sweep across, between, and beyond the probed grid
    points = [1, 2, 3, 8]
    seqs = [16, 32, 48, 64, 200]
    prev = None
    for s in seqs:
        v = cm.decode_step_seconds(cfg, 1, s)
        if prev is not None:
            assert v >= prev
        prev = v
    for b, b2 in zip(points, points[1:]):
        for s in seqs:
            assert cm.decode_step_seconds(cfg, b2, s) >= \
                cm.decode_step_seconds(cfg, b, s)
            assert cm.prefill_seconds(cfg, b2, s) >= \
                cm.prefill_seconds(cfg, b, s)
        # prefill also monotone in seq at fixed batch
        for s, s2 in zip(seqs, seqs[1:]):
            assert cm.prefill_seconds(cfg, b, s2) >= \
                cm.prefill_seconds(cfg, b, s)
    rec = cm.provenance[f"decode_step:{cfg.name}"]
    assert rec["source"] == "measured" and rec["probe_arch"] == cfg.name


def test_transfer_seconds_monotone_and_sourced():
    cm = CostModel(None)
    a = cm.transfer_seconds(10**6)
    b = cm.transfer_seconds(10**8)
    assert b > a and cm.provenance["transfer:h2d"]["source"] == "analytic"
    facts = _fresh_facts(transfer={"h2d": [
        {"bytes": 2 ** 10, "seconds": 1e-4},
        {"bytes": 2 ** 20, "seconds": 2e-4},
    ]})
    cm = CostModel(facts)
    a = cm.transfer_seconds(10**6)
    b = cm.transfer_seconds(10**8)
    assert b > a > 0
    assert cm.provenance["transfer:h2d"]["source"] == "measured"


def test_draft_plan_picks_cheaper_draft():
    cfg = _cfg()
    cm = CostModel(None)
    choice = cm.draft_plan(cfg)
    assert 1 <= choice.draft_k <= 8
    assert choice.draft_cfg.n_active_params <= cfg.n_active_params
    rec = cm.provenance[f"draft:{cfg.name}"]
    assert rec["draft_model"] == choice.draft_cfg.name
    assert rec["expected_tok_per_s"] > 0
    # unprofiled: the fixed prior, tagged as such
    assert rec["accept_source"] == "prior"
    assert rec["accept_prior"] == 0.8
    # fixing k respects it
    assert cm.draft_plan(cfg, draft_k=3).draft_k == 3


def test_draft_plan_prefers_measured_accept_rate():
    """A profile with a per-family measured acceptance rate overrides the
    fixed ``accept_prior=0.8``, with provenance recording the probe; a
    family the probe never measured falls back to the tagged prior."""
    cfg = _cfg()
    facts = _fresh_facts(accept_rates={
        cfg.family: {"target": cfg.name, "draft": f"{cfg.name}-draft-probe",
                     "draft_k": 3, "accept_rate": 0.35, "rounds": 20}})
    cm = CostModel(facts)
    choice = cm.draft_plan(cfg, draft_k=4)
    rec = cm.provenance[f"draft:{cfg.name}"]
    assert rec["accept_source"] == "measured"
    assert rec["accept_prior"] == 0.35          # α actually used
    assert rec["accept_probe"]["rounds"] == 20
    assert rec["accept_probe"]["draft"] == f"{cfg.name}-draft-probe"
    # the measured α changes the throughput estimate vs the fixed prior:
    # E(k=4) = (1-α^5)/(1-α) is strictly smaller at α=.35 than α=.8
    prior_rec = CostModel(None).draft_plan(cfg, draft_k=4).record
    assert choice.record["expected_tok_per_s"] < \
        prior_rec["expected_tok_per_s"]
    # a low measured α also steers the optimizer toward shallower drafts
    assert cm.draft_plan(cfg).draft_k <= CostModel(None).draft_plan(cfg).draft_k
    # unmeasured family -> tagged fallback to the prior
    cm2 = CostModel(_fresh_facts())
    cm2.draft_plan(cfg)
    assert cm2.provenance[f"draft:{cfg.name}"]["accept_source"] == "prior"
    # measured rates round-trip through the profile JSON
    assert MachineFacts.from_dict(facts.to_dict()).accept_rates == \
        facts.accept_rates


# ---------------------------------------------------------------------------
# plan provenance: present, serialized, stable across plan -> JSON -> run
# ---------------------------------------------------------------------------

def _plan(profile):
    cfg = _cfg()
    session = Session(_hc(), profile=profile)
    session.submit(TrainJob(cfg, make_loader(cfg, seed=0), epochs=1,
                            steps_per_epoch=2, seed=0, batch=2, seq=64))
    return session, session.plan()


def test_plan_provenance_round_trips():
    session, plan = _plan(profile=None)
    assert plan.provenance["n_analytic"] > 0
    assert plan.provenance["n_measured"] == 0
    assert plan.provenance["profile"] is None
    text = plan.to_json()
    reloaded = Plan.from_json(text)
    assert reloaded.provenance == plan.provenance
    assert reloaded.to_json() == text
    assert plan.summary()["cost_source"] == "analytic"
    # provenance survives execution untouched
    rt = session.run(reloaded)
    assert reloaded.provenance == plan.provenance
    assert rt.train is not None


def test_plan_cites_measured_facts_when_profiled(tmp_path):
    cfg = _cfg()
    path = _measured_facts(cfg).save(str(tmp_path / "p.json"))
    _, plan_a = _plan(profile=None)
    _, plan_b = _plan(profile=path)
    assert plan_b.provenance["n_measured"] > 0
    assert plan_b.provenance["profile"] is not None
    assert cfg.family in plan_b.provenance["profile"]["decode_families"]
    assert plan_b.summary()["cost_source"] == "measured"
    assert plan_a.provenance != plan_b.provenance
    prov = plan_b.provenance["queries"]
    assert prov[f"partition:{cfg.name}"]["source"] == "measured"


def test_pre_profiler_plan_json_still_loads():
    _, plan = _plan(profile=None)
    d = json.loads(plan.to_json())
    d.pop("provenance")
    old = Plan.from_json(json.dumps(d))
    assert old.provenance == {}
    assert old.summary().get("cost_source") is None


def test_session_rejects_bad_profile_arg():
    with pytest.raises(TypeError):
        Session(_hc(), profile=42)


def test_unprofiled_session_emits_no_warnings():
    with warnings.catch_warnings():
        warnings.simplefilter("error", StaleProfileWarning)
        _plan(profile=None)
