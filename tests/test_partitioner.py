"""Automated partitioning (paper §4.3, Algorithm 1)."""

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core import partitioner as pt
from repro.core import shard_graph as sg
from repro.models import api


def _setup(arch="qwen3-0.6b"):
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    host = sg.prepare_host_params(cfg, jax.tree.map(np.array, params))
    plan = sg.build_plan(cfg)
    return cfg, host, plan


def test_partition_covers_all_segments_in_order():
    cfg, host, plan = _setup()
    res = pt.partition(cfg, host, plan, budget_bytes=20 * 10**6,
                       batch=2, seq=64)
    covered = []
    for sh in res.shards:
        covered.extend(range(sh.seg_lo, sh.seg_hi))
    assert covered == list(range(len(plan.segments)))


def test_bigger_budget_fewer_shards():
    cfg, host, plan = _setup()
    small = pt.partition(cfg, host, plan, budget_bytes=18 * 10**6,
                         batch=2, seq=64)
    big = pt.partition(cfg, host, plan, budget_bytes=10**9,
                       batch=2, seq=64)
    assert len(big) <= len(small)
    assert len(big) == 1          # whole smoke model fits 1 GB


def test_unpartitionable_raises():
    cfg, host, plan = _setup()
    with pytest.raises(MemoryError):
        pt.partition(cfg, host, plan, budget_bytes=10_000, batch=2, seq=64)


@settings(max_examples=10, deadline=None)
@given(st.integers(16, 400))
def test_partition_coverage_property(budget_mb_tenths):
    """Any feasible budget yields an exact, ordered, non-overlapping cover."""
    cfg, host, plan = _setup()
    budget = budget_mb_tenths * 10**5
    try:
        res = pt.partition(cfg, host, plan, budget_bytes=budget,
                           batch=2, seq=64)
    except MemoryError:
        return
    segs = [i for s in res.shards for i in range(s.seg_lo, s.seg_hi)]
    assert segs == list(range(len(plan.segments)))
    assert all(s.seg_hi > s.seg_lo for s in res.shards)


def test_probe_oracle_agrees_with_analytic_on_fit():
    """The AOT pilot-run oracle must also produce a full cover."""
    cfg, host, plan = _setup()
    res = pt.partition(cfg, host, plan, budget_bytes=60 * 10**6,
                       batch=2, seq=64, oracle="probe")
    segs = [i for s in res.shards for i in range(s.seg_lo, s.seg_hi)]
    assert segs == list(range(len(plan.segments)))


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "whisper-medium",
                                  "zamba2-1.2b", "xlstm-350m"])
def test_partition_all_families(arch):
    cfg, host, plan = _setup(arch)
    res = pt.partition(cfg, host, plan, budget_bytes=60 * 10**6,
                       batch=2, seq=64)
    segs = [i for s in res.shards for i in range(s.seg_lo, s.seg_hi)]
    assert segs == list(range(len(plan.segments)))
    assert all(s.param_bytes > 0 for s in res.shards)
