"""Data pipeline, checkpointing, losses."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.data import DataConfig, FileTokens, Prefetcher, SyntheticTokens
from repro.training.losses import softmax_xent


def test_synthetic_labels_are_shifted_tokens():
    it = iter(SyntheticTokens(DataConfig(batch_size=2, seq_len=16,
                                         vocab_size=100, seed=3)))
    b = next(it)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_synthetic_deterministic_per_seed():
    mk = lambda s: next(iter(SyntheticTokens(
        DataConfig(batch_size=2, seq_len=8, vocab_size=50, seed=s))))
    np.testing.assert_array_equal(mk(7)["tokens"], mk(7)["tokens"])
    assert not np.array_equal(mk(7)["tokens"], mk(8)["tokens"])


def test_file_tokens(tmp_path):
    data = np.arange(1000, dtype=np.uint16) % 50
    path = tmp_path / "toks.bin"
    data.tofile(path)
    it = iter(FileTokens(DataConfig(batch_size=2, seq_len=16, path=str(path),
                                    dtype="uint16", seed=0)))
    b = next(it)
    assert b["tokens"].shape == (2, 16)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_prefetcher_yields_device_arrays():
    it = Prefetcher(iter(SyntheticTokens(
        DataConfig(batch_size=2, seq_len=8, vocab_size=50))), depth=2)
    b = next(iter(it))
    assert isinstance(b["tokens"], jax.Array)
    it.close()


def test_checkpoint_roundtrip(tmp_path):
    tree = {"layers": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                       "b": jnp.ones((3,), jnp.bfloat16)},
            "step_count": jnp.array(7, jnp.int32)}
    d = ckpt.save(str(tmp_path / "step_5"), tree, step=5,
                  metadata={"note": "test"})
    restored, manifest = ckpt.restore(d, like=tree)
    assert manifest["step"] == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(a).dtype == np.asarray(b).dtype


def test_checkpoint_latest_step(tmp_path):
    for s in (10, 5, 20):
        ckpt.save(str(tmp_path / f"step_{s}"), {"x": jnp.zeros(1)}, step=s)
    assert ckpt.latest_step(str(tmp_path)).endswith("step_20")


def test_softmax_xent_matches_manual():
    logits = jnp.array([[[2.0, 0.0, -1.0], [0.5, 0.5, 0.5]]])
    labels = jnp.array([[0, 2]])
    manual = -(jax.nn.log_softmax(logits)[0, [0, 1], labels[0]]).mean()
    got = softmax_xent(logits, labels)
    np.testing.assert_allclose(got, manual, rtol=1e-6)


def test_softmax_xent_masked():
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.zeros((1, 4), jnp.int32)
    mask = jnp.array([[1.0, 1.0, 0.0, 0.0]])
    got = softmax_xent(logits, labels, mask)
    np.testing.assert_allclose(got, np.log(8.0), rtol=1e-6)
