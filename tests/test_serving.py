"""Continuous-batching serving engine (repro.serving).

The load-bearing property: because the engine vmaps the greedy decode step
over a slot pool of stacked batch=1 states, every request's token stream is
numerically identical to decoding it alone — joins, evictions, and slot
reuse must never perturb in-flight requests.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.scheduler import sharded_lrtf
from repro.models import api
from repro.serving import (CapabilityFallbackWarning, InferenceEngine,
                           KVBudget, MultiModelServer, Request, Status)
from repro.training.train_loop import make_decode_step, make_prefill_into_cache

MAX_SEQ = 64


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("qwen3-0.6b", smoke=True)
    return cfg, api.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def ssm():
    cfg = get_config("xlstm-350m", smoke=True)
    return cfg, api.init_params(cfg, jax.random.PRNGKey(1))


def _prompt(cfg, seed, plen):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (plen,), 0, cfg.vocab_size, jnp.int32))


@functools.lru_cache(maxsize=None)
def _ref_steps(cfg):
    # shared per-cfg so ~15 reference decodes don't each recompile
    return (jax.jit(make_prefill_into_cache(cfg)),
            jax.jit(make_decode_step(cfg)))


def _reference(cfg, params, prompt, gen, max_seq=MAX_SEQ):
    """Sequential per-request greedy decode: batch=1 prefill + decode loop."""
    prefill, decode = _ref_steps(cfg)
    state = api.init_decode_state(cfg, 1, max_seq)
    logits, state = prefill(params, state, jnp.asarray(prompt)[None, :])
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out = [int(tok[0, 0])]
    for _ in range(gen - 1):
        tok, state = decode(params, state, tok)
        out.append(int(tok[0, 0]))
    return out


# ---------------------------------------------------------------------------
# prefill-into-cache
# ---------------------------------------------------------------------------

def test_batched_prefill_matches_per_token_loop(dense):
    cfg, params = dense
    tokens = jax.random.randint(jax.random.PRNGKey(5), (2, 12), 0,
                                cfg.vocab_size, jnp.int32)
    state = api.init_decode_state(cfg, 2, MAX_SEQ)
    logits_b, state_b = make_prefill_into_cache(cfg)(params, state, tokens)

    state = api.init_decode_state(cfg, 2, MAX_SEQ)
    logits_l = None
    for i in range(tokens.shape[1]):
        logits_l, state = api.decode_step(cfg, params, state,
                                          tokens[:, i:i + 1])
    assert int(state_b["kv"]["index"]) == int(state["kv"]["index"]) == 12
    np.testing.assert_allclose(np.asarray(logits_b, np.float32),
                               np.asarray(logits_l[:, -1], np.float32),
                               atol=2e-3, rtol=2e-3)
    assert (jnp.argmax(logits_b, -1) == jnp.argmax(logits_l[:, -1], -1)).all()


def test_prefill_scan_fallback_matches_loop(ssm):
    cfg, params = ssm
    assert not api.family_spec(cfg).batched_prefill
    tokens = jax.random.randint(jax.random.PRNGKey(6), (2, 10), 0,
                                cfg.vocab_size, jnp.int32)
    state = api.init_decode_state(cfg, 2, MAX_SEQ)
    logits_s, _ = make_prefill_into_cache(cfg)(params, state, tokens)

    state = api.init_decode_state(cfg, 2, MAX_SEQ)
    logits_l = None
    for i in range(tokens.shape[1]):
        logits_l, state = api.decode_step(cfg, params, state,
                                          tokens[:, i:i + 1])
    assert (jnp.argmax(logits_s, -1) == jnp.argmax(logits_l[:, -1], -1)).all()


# ---------------------------------------------------------------------------
# (a) continuous batching == sequential greedy decode, exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family_fixture", ["dense", "ssm"])
def test_engine_token_identical_to_sequential(family_fixture, request):
    cfg, params = request.getfixturevalue(family_fixture)
    eng = InferenceEngine(cfg, params, capacity=3, max_seq=MAX_SEQ)
    # more requests than slots, mixed prompt lengths and decode budgets so
    # slots get reused and admission groups prefill different shapes
    specs = [(8, 5), (12, 7), (8, 4), (10, 6), (12, 3), (8, 8)]
    reqs = []
    for i, (plen, gen) in enumerate(specs):
        prompt = _prompt(cfg, 50 + i, plen)
        reqs.append((prompt, gen, eng.submit(prompt, gen)))
    done = eng.run()
    assert len(done) == len(specs)
    for prompt, gen, req in reqs:
        assert req.status == Status.FINISHED
        assert len(req.generated) == gen
        ref = _reference(cfg, params, prompt, gen)
        assert req.generated == ref, \
            f"{req.request_id}: {req.generated} != {ref}"


# ---------------------------------------------------------------------------
# (b) staggered arrivals join mid-flight without perturbing in-flight work
# ---------------------------------------------------------------------------

def test_staggered_arrivals_do_not_perturb_in_flight(dense):
    cfg, params = dense
    eng = InferenceEngine(cfg, params, capacity=4, max_seq=MAX_SEQ)
    first = [eng.submit(_prompt(cfg, 80 + i, 8), 10) for i in range(2)]
    eng.step()
    eng.step()                       # first wave is mid-decode
    assert all(len(r.generated) >= 2 for r in first)
    partial = {r.request_id: list(r.generated) for r in first}

    late = [eng.submit(_prompt(cfg, 90 + i, 10), 6) for i in range(2)]
    eng.step()                       # late wave joins here
    assert all(r.status == Status.RUNNING for r in late)
    # in-flight prefixes were not rewritten by the join
    for r in first:
        assert r.generated[:len(partial[r.request_id])] \
            == partial[r.request_id]
    eng.run()
    for i, r in enumerate(first):
        assert r.generated == _reference(cfg, params, _prompt(cfg, 80 + i, 8),
                                         10)
    for i, r in enumerate(late):
        assert r.generated == _reference(cfg, params,
                                         _prompt(cfg, 90 + i, 10), 6)


# ---------------------------------------------------------------------------
# (c) admission control never exceeds the KV budget
# ---------------------------------------------------------------------------

def test_admission_respects_kv_budget(dense):
    cfg, params = dense
    slot_bytes = api.decode_state_bytes(cfg, 1, MAX_SEQ)
    budget = 2 * slot_bytes + slot_bytes // 2      # room for exactly 2 slots
    eng = InferenceEngine(cfg, params, capacity=4, max_seq=MAX_SEQ,
                          kv_budget_bytes=budget)
    assert eng.budget.max_concurrent() == 2
    for i in range(5):
        eng.submit(_prompt(cfg, 120 + i, 8), 5)
    while eng.step():
        assert eng.budget.reserved_bytes <= budget
        assert len(eng.active_requests()) <= 2
    assert eng.budget.peak_bytes <= budget
    assert len(eng.completed) == 5                  # everyone still served
    assert eng.budget.peak_bytes == 2 * slot_bytes  # and it did batch 2-wide


def test_kv_budget_rejects_impossible_budget(dense):
    cfg, params = dense
    with pytest.raises(ValueError):
        KVBudget(budget_bytes=10, slot_bytes=1000)
    with pytest.raises(ValueError):
        InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                        kv_budget_bytes=10)


# ---------------------------------------------------------------------------
# request bookkeeping / metrics
# ---------------------------------------------------------------------------

def test_request_metrics_populated(dense):
    cfg, params = dense
    eng = InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ)
    req = eng.submit(_prompt(cfg, 7, 8), 4)
    assert req.arrival_time is not None and req.status == Status.QUEUED
    eng.run()
    m = req.metrics()
    assert m["status"] == "finished"
    assert m["n_generated"] == 4 and m["prompt_len"] == 8
    assert m["queue_wait_s"] >= 0 and m["ttft_s"] > 0 and m["e2e_s"] > 0
    assert m["ttft_s"] <= m["e2e_s"]
    s = eng.summary()
    assert s["n_completed"] >= 1 and s["kv_peak_bytes"] == eng.slot_bytes


def test_request_validation():
    with pytest.raises(ValueError):
        Request(prompt=np.zeros(4, np.int32), max_new_tokens=0)


def test_submit_rejects_overlong_prompt(dense):
    cfg, params = dense
    eng = InferenceEngine(cfg, params, capacity=1, max_seq=16)
    with pytest.raises(ValueError):
        eng.submit(_prompt(cfg, 1, 14), 8)
    # boundary fits: plen + gen - 1 rows (last token is never written back)
    eng.submit(_prompt(cfg, 1, 12), 5)


def test_engine_rejects_encoder_decoder_family():
    cfg = get_config("whisper-medium", smoke=True)
    with pytest.raises(ValueError, match="encoder-decoder"):
        InferenceEngine(cfg, params=None, capacity=1, max_seq=16)


# ---------------------------------------------------------------------------
# multi-model serving (LRTF routing)
# ---------------------------------------------------------------------------

def test_multi_model_lrtf_serves_all_and_stays_identical(dense, ssm):
    cfg_a, params_a = dense
    cfg_b, params_b = ssm
    server = MultiModelServer({
        "qwen": InferenceEngine(cfg_a, params_a, capacity=2, max_seq=MAX_SEQ,
                                model_name="qwen"),
        "xlstm": InferenceEngine(cfg_b, params_b, capacity=2, max_seq=MAX_SEQ,
                                 model_name="xlstm"),
    }, scheduler=sharded_lrtf)
    subs = []
    for i in range(3):
        pa, pb = _prompt(cfg_a, 200 + i, 8), _prompt(cfg_b, 300 + i, 8)
        subs.append((cfg_a, params_a, pa, 6, server.submit("qwen", pa, 6)))
        subs.append((cfg_b, params_b, pb, 4, server.submit("xlstm", pb, 4)))
    out = server.run()
    assert len(out["qwen"]) == 3 and len(out["xlstm"]) == 3
    assert set(server.schedule_trace) == {"qwen", "xlstm"}
    for cfg, params, prompt, gen, req in subs:
        assert req.generated == _reference(cfg, params, prompt, gen)


def test_multi_model_lrtf_prefers_more_remaining_work(dense):
    cfg, params = dense
    heavy = InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                            model_name="heavy")
    light = InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                            model_name="light")
    server = MultiModelServer({"heavy": heavy, "light": light})
    server.submit("heavy", _prompt(cfg, 1, 8), 12)
    server.submit("light", _prompt(cfg, 2, 8), 2)
    # same measured per-token cost, 6x the outstanding tokens: LRTF must
    # pick the heavy engine first
    assert server.step() == "heavy"


# ---------------------------------------------------------------------------
# length-bucketed prefill admission
# ---------------------------------------------------------------------------

def test_pow2_buckets_cover_range():
    from repro.serving import pow2_buckets
    assert pow2_buckets(64) == (1, 2, 4, 8, 16, 32, 64)
    assert pow2_buckets(40) == (1, 2, 4, 8, 16, 32, 40)
    assert pow2_buckets(1) == (1,)


def test_bucketed_prefill_tokens_identical_and_one_trace(dense):
    """Mixed prompt lengths in one bucket share ONE prefill call, and every
    request's token stream still equals its solo-decode reference."""
    cfg, params = dense
    lens = [9, 11, 13, 16]
    prompts = [_prompt(cfg, 70 + i, L) for i, L in enumerate(lens)]

    eng = InferenceEngine(cfg, params, capacity=4, max_seq=MAX_SEQ,
                          bucket_sizes=(4, 8, 16, 32))
    reqs = [eng.submit(p, 6) for p in prompts]
    eng.run()

    assert eng.prefill_calls == 1            # one (n=4, bucket=16) group
    assert eng.summary()["bucket_sizes"] == [4, 8, 16, 32]
    for p, r in zip(prompts, reqs):
        assert r.generated == _reference(cfg, params, p, 6)


def test_bucketed_vs_exact_engine_same_tokens(dense):
    cfg, params = dense
    prompts = [_prompt(cfg, 80 + i, L) for i, L in enumerate([5, 7, 12])]
    exact = InferenceEngine(cfg, params, capacity=3, max_seq=MAX_SEQ)
    bucketed = InferenceEngine(cfg, params, capacity=3, max_seq=MAX_SEQ,
                               bucket_sizes=(8, 16))
    reqs_e = [exact.submit(p, 5) for p in prompts]
    reqs_b = [bucketed.submit(p, 5) for p in prompts]
    exact.run()
    bucketed.run()
    assert exact.prefill_calls == 3 and bucketed.prefill_calls == 2
    for re_, rb in zip(reqs_e, reqs_b):
        assert re_.generated == rb.generated


def test_bucketing_ignored_on_recurrent_family(ssm):
    # recurrent state advances through every consumed token: no rewind, so
    # the engine silently falls back to exact-length admission groups
    cfg, params = ssm
    eng = InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                          bucket_sizes=(8, 16))
    assert eng.bucket_sizes is None
    req = eng.submit(_prompt(cfg, 90, 6), 4)
    eng.run()
    assert req.generated == _reference(cfg, params, _prompt(cfg, 90, 6), 4)


def test_padded_prefill_factory_rejects_recurrent(ssm):
    from repro.training.train_loop import make_padded_prefill_into_cache
    cfg, _ = ssm
    with pytest.raises(ValueError, match="rewindable"):
        make_padded_prefill_into_cache(cfg)


# ---------------------------------------------------------------------------
# paged KV cache (block-granular admission)
# ---------------------------------------------------------------------------

def test_paged_engine_token_identical_to_sequential(dense):
    cfg, params = dense
    eng = InferenceEngine(cfg, params, capacity=3, max_seq=MAX_SEQ,
                          paged=True, block_size=4)
    assert eng.paged
    specs = [(8, 5), (12, 7), (8, 4), (10, 6), (12, 3), (1, 8)]
    reqs = []
    for i, (plen, gen) in enumerate(specs):
        prompt = _prompt(cfg, 150 + i, plen)
        reqs.append((prompt, gen, eng.submit(prompt, gen)))
    done = eng.run()
    assert len(done) == len(specs)
    for prompt, gen, req in reqs:
        assert req.generated == _reference(cfg, params, prompt, gen), \
            f"{req.request_id}: {req.generated}"
    # every block returned to the free list; recycling actually happened
    assert eng.pool.n_free == eng.pool.n_allocatable
    assert eng.pool.total_allocs > eng.pool.peak_used


def test_paged_equals_slot_engine_tokens(dense):
    """The acceptance bar: the paged path decodes token-identically to the
    slot-pool path for the same submissions (staggered joins included)."""
    cfg, params = dense
    slot = InferenceEngine(cfg, params, capacity=3, max_seq=MAX_SEQ)
    paged = InferenceEngine(cfg, params, capacity=3, max_seq=MAX_SEQ,
                            paged=True, block_size=8)
    specs = [(8, 6), (12, 4), (9, 7), (11, 5), (8, 3)]
    rs = [slot.submit(_prompt(cfg, 160 + i, p), g)
          for i, (p, g) in enumerate(specs)]
    rp = [paged.submit(_prompt(cfg, 160 + i, p), g)
          for i, (p, g) in enumerate(specs)]
    slot.run()
    paged.run()
    for a, b in zip(rs, rp):
        assert a.generated == b.generated


def test_paged_admission_respects_budget_and_admits_more(dense):
    """Under ONE byte budget worth two max_seq slots, paging admits more
    short-prompt requests than the slot pool while never letting reserved
    or physically-allocated page bytes exceed the budget."""
    cfg, params = dense
    budget = 2 * api.decode_state_bytes(cfg, 1, MAX_SEQ)
    slot = InferenceEngine(cfg, params, capacity=6, max_seq=MAX_SEQ,
                           kv_budget_bytes=budget)
    paged = InferenceEngine(cfg, params, capacity=6, max_seq=MAX_SEQ,
                            kv_budget_bytes=budget, paged=True, block_size=4)
    for i in range(6):
        slot.submit(_prompt(cfg, 170 + i, 6), 4)
        paged.submit(_prompt(cfg, 170 + i, 6), 4)
    while paged.step():
        assert paged.budget.reserved_bytes <= budget
        assert paged.pool.used_bytes() <= paged.budget.reserved_bytes
    slot.run()
    assert len(paged.completed) == 6
    assert paged.budget.peak_bytes <= budget
    assert paged.pool.peak_bytes() <= budget
    assert paged.peak_concurrency > slot.peak_concurrency == 2


def test_paged_with_buckets_token_identical(dense):
    cfg, params = dense
    eng = InferenceEngine(cfg, params, capacity=4, max_seq=MAX_SEQ,
                          paged=True, block_size=8,
                          bucket_sizes=(4, 8, 16, 32))
    prompts = [_prompt(cfg, 180 + i, L) for i, L in enumerate([9, 11, 13, 16])]
    reqs = [eng.submit(p, 6) for p in prompts]
    eng.run()
    assert eng.prefill_calls == 1            # one (n=4, bucket=16) group
    for p, r in zip(prompts, reqs):
        assert r.generated == _reference(cfg, params, p, 6)


def test_paged_falls_back_on_recurrent_and_moe(ssm):
    cfg, params = ssm
    with pytest.warns(CapabilityFallbackWarning, match="paged backend"):
        eng = InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                              paged=True)
    assert not eng.paged                     # O(1) state: nothing to page
    assert eng.backend.name == "slot"
    assert eng.summary()["requested_backend"] == "paged"
    req = eng.submit(_prompt(cfg, 95, 6), 4)
    eng.run()
    assert req.generated == _reference(cfg, params, _prompt(cfg, 95, 6), 4)
    moe = get_config("mixtral-8x22b", smoke=True)
    with pytest.warns(CapabilityFallbackWarning):
        eng = InferenceEngine(moe, None, capacity=1, max_seq=16, paged=True)
    assert not eng.paged                     # expert capacity couples lanes


def test_backend_selected_by_name_and_unknown_rejected(dense):
    cfg, params = dense
    eng = InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                          backend="paged", block_size=8)
    assert eng.paged and eng.backend.name == "paged"
    eng = InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                          backend="slot")
    assert not eng.paged and eng.backend.name == "slot"
    with pytest.raises(ValueError, match="unknown decode backend"):
        InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                        backend="mmap")
    with pytest.raises(ValueError, match="conflicting"):
        InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                        backend="slot", paged=True)


def test_backend_instance_can_be_injected(dense):
    """The engine accepts a pre-built DecodeBackend object — the session
    selects a backend once and hands it over, no per-call branching."""
    from repro.serving import SlotBackend
    cfg, params = dense
    be = SlotBackend(cfg, capacity=2, max_seq=MAX_SEQ)
    eng = InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                          backend=be)
    assert eng.backend is be
    req = eng.submit(_prompt(cfg, 97, 8), 4)
    eng.run()
    assert req.generated == _reference(cfg, params, _prompt(cfg, 97, 8), 4)
    # a mis-sized injected backend would desync the engine's token buffer
    # and admission checks — rejected at construction
    with pytest.raises(ValueError, match="must match"):
        InferenceEngine(cfg, params, capacity=4, max_seq=MAX_SEQ,
                        backend=SlotBackend(cfg, capacity=2,
                                            max_seq=MAX_SEQ))
    with pytest.raises(ValueError, match="conflicting"):
        InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                        backend=SlotBackend(cfg, capacity=2,
                                            max_seq=MAX_SEQ), paged=True)


def test_bucket_fallback_warns_structured(ssm):
    cfg, params = ssm
    with pytest.warns(CapabilityFallbackWarning, match="bucket_sizes"):
        eng = InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                              bucket_sizes=(8, 16))
    assert eng.bucket_sizes is None


def test_paged_summary_reports_page_stats(dense):
    cfg, params = dense
    eng = InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                          paged=True, block_size=8)
    eng.submit(_prompt(cfg, 99, 10), 4)
    eng.run()
    s = eng.summary()
    assert s["paged"] and s["block_size"] == 8
    assert s["kv_page_peak_bytes"] == 2 * s["block_bytes"]  # 10+3 rows
    assert s["peak_concurrency"] == 1


# ---------------------------------------------------------------------------
# accounting guards survive python -O (real errors, not asserts)
# ---------------------------------------------------------------------------

def test_kv_budget_release_without_reserve_raises():
    b = KVBudget(budget_bytes=None, slot_bytes=100)
    with pytest.raises(RuntimeError, match="matching reserve"):
        b.release()
    b.reserve()
    b.release()                              # balanced: fine


def test_slot_pool_exhaustion_raises_clear_error(dense):
    from repro.serving import SlotPool
    cfg, _ = dense
    pool = SlotPool(cfg, capacity=1, max_seq=8)
    pool.alloc("r0")
    with pytest.raises(RuntimeError, match="SlotPool exhausted"):
        pool.alloc("r1")


def test_bucketing_ignored_on_moe_family():
    # capacity-bounded expert routing couples tokens: pad tokens would
    # consume expert capacity and displace real tokens' routes, so the
    # engine must refuse padded prefill for moe just like recurrent
    cfg = get_config("mixtral-8x22b", smoke=True)
    eng = InferenceEngine(cfg, None, capacity=1, max_seq=16,
                          bucket_sizes=(8, 16))
    assert eng.bucket_sizes is None
