"""First-class request cancellation + bounded retention (repro.serving).

The lifecycle bugs this locks down:

* ``_retire_finished`` used to stomp ``Status.CANCELLED`` to FINISHED, so
  a running request could never observably be cancelled — now the status
  survives retirement while the lane and KV reservation release through
  the normal backend path (slot, paged refcounts/orphans, spec draft
  state all included; ledger back to baseline, no leaked blocks).
* ``_admit`` used to admit cancelled queued requests — reserving a lane,
  burning a jitted prefill, and flipping the status back to RUNNING.  Now
  admission skips and retires them unreserved.
* ``run()``/retention: ``completed`` is drain-on-read with an optional
  cap, ``schedule_trace`` a capped ring, and repeated ``run()`` calls
  return only newly-completed requests — a long-lived server holds
  steady memory and never double-counts.
"""

import functools

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.spilling import DeviceMemory
from repro.models import api
from repro.serving import InferenceEngine, MultiModelServer, Status

MAX_SEQ = 48


@functools.lru_cache(maxsize=None)
def _dense():
    cfg = get_config("qwen3-0.6b", smoke=True)
    return cfg, api.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def dense():
    return _dense()


def _prompt(cfg, seed, plen=8):
    rng = np.random.RandomState(seed)
    return rng.randint(0, cfg.vocab_size, plen).astype(np.int32)


def _make_engine(cfg, params, backend, ledger=None, **kw):
    if backend == "spec":
        kw.update(draft_cfg=cfg, draft_params=params, draft_k=2)
    if backend == "paged":
        kw.update(block_size=8)
    return InferenceEngine(cfg, params, capacity=3, max_seq=MAX_SEQ,
                           backend=backend, ledger=ledger, **kw)


def _assert_baseline(eng, ledger):
    """Every reservation the engine ever took has been handed back."""
    assert eng.budget.reserved_bytes == 0
    if ledger is not None:
        assert ledger.kv_reserved_bytes == 0
    if eng.pool is not None and hasattr(eng.pool, "n_blocks"):
        assert eng.pool.n_free == eng.pool.n_allocatable   # no leaked blocks


# ---------------------------------------------------------------------------
# cancel mid-decode: status survives, lane + KV release on every backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["slot", "paged", "spec"])
def test_cancel_mid_decode_releases_and_preserves_status(dense, backend):
    cfg, params = dense
    ledger = (DeviceMemory(-1, budget_bytes=10**9)
              if backend in ("paged", "spec") else None)
    eng = _make_engine(cfg, params, backend, ledger)
    victim = eng.submit(_prompt(cfg, 1), 12)
    other = eng.submit(_prompt(cfg, 2), 6)
    for _ in range(2):
        eng.step()                      # both admitted and decoding
    assert victim.status is Status.RUNNING
    lanes_before = eng.n_free_lanes
    assert eng.cancel(victim.request_id)
    eng.step()                          # retirement happens within one tick
    # the original bug: this status came back FINISHED
    assert victim.status is Status.CANCELLED
    assert victim in eng.completed and victim.finish_time is not None
    assert len(victim.generated) < 12   # it really stopped early
    assert eng.n_free_lanes == lanes_before + 1
    done = eng.run()
    assert other in done and other.status is Status.FINISHED
    assert len(other.generated) == 6
    _assert_baseline(eng, ledger)
    # the freed lane is genuinely reusable and decode state was not
    # perturbed: replaying the surviving prompt reproduces its tokens
    replay = eng.submit(other.prompt, 6)
    eng.run()
    assert replay.generated == other.generated
    _assert_baseline(eng, ledger)


def test_cancelled_status_counts_in_metrics(dense):
    cfg, params = dense
    eng = _make_engine(cfg, params, "slot")
    req = eng.submit(_prompt(cfg, 3), 10)
    eng.step()
    eng.cancel(req.request_id)
    eng.step()
    rec = [m for m in eng.recent_metrics()
           if m["request_id"] == req.request_id]
    assert rec and rec[0]["status"] == "cancelled"
    assert rec[0]["e2e_s"] is not None


# ---------------------------------------------------------------------------
# cancel while queued: skipped at admission, never reserved or prefilled
# ---------------------------------------------------------------------------

def test_cancel_queued_request_is_never_prefilled(dense):
    cfg, params = dense
    eng = InferenceEngine(cfg, params, capacity=1, max_seq=MAX_SEQ)
    first = eng.submit(_prompt(cfg, 4), 4)
    victim = eng.submit(_prompt(cfg, 5), 4)
    last = eng.submit(_prompt(cfg, 6), 4)
    eng.step()                          # capacity 1: only `first` admitted
    assert victim.status is Status.QUEUED
    assert eng.cancel(victim.request_id)
    prefills_before = eng.prefill_calls
    eng.run()
    # the original bug: the cancelled entry was admitted anyway — a lane
    # reserved, a jitted prefill burned, the status stomped to RUNNING
    assert victim.status is Status.CANCELLED
    assert victim.admit_time is None and victim.generated == []
    assert victim in eng.completed
    assert first.status is Status.FINISHED
    assert last.status is Status.FINISHED
    # exactly one more prefill group ran (for `last`), none for the victim
    assert eng.prefill_calls == prefills_before + 1
    assert eng.budget.reserved_bytes == 0


def test_cancel_unknown_or_finished_returns_false(dense):
    cfg, params = dense
    eng = _make_engine(cfg, params, "slot")
    req = eng.submit(_prompt(cfg, 7), 2)
    eng.run()
    assert req.status is Status.FINISHED
    assert not eng.cancel(req.request_id)       # already retired
    assert not eng.cancel("no-such-request")


def test_cancel_all_queued_only_touches_queued(dense):
    cfg, params = dense
    eng = InferenceEngine(cfg, params, capacity=1, max_seq=MAX_SEQ)
    running = eng.submit(_prompt(cfg, 8), 3)
    queued = eng.submit(_prompt(cfg, 9), 3)
    eng.step()
    assert eng.cancel_all_queued() == 1
    eng.run()
    assert running.status is Status.FINISHED
    assert len(running.generated) == 3
    assert queued.status is Status.CANCELLED and queued.generated == []


# ---------------------------------------------------------------------------
# bounded retention + drain-on-read + no double counting
# ---------------------------------------------------------------------------

def test_completed_cap_bounds_retention_under_long_run(dense):
    cfg, params = dense
    eng = InferenceEngine(cfg, params, capacity=4, max_seq=MAX_SEQ,
                          completed_cap=8)
    server = MultiModelServer({"m": eng}, trace_cap=16)
    n = 30
    for i in range(n):
        server.submit("m", _prompt(cfg, 100 + i, plen=4), 1)
    server.run()
    # retention stays bounded while the monotonic counters keep the truth
    assert len(eng.completed) <= 8
    assert len(server.schedule_trace) <= 16
    assert eng.retired_total == n
    assert eng.summary()["n_completed"] == n
    drained = server.drain_completed()["m"]
    assert 0 < len(drained) <= 8
    assert server.drain_completed()["m"] == []      # drain-on-read: empty


def test_repeated_run_returns_only_new_completions(dense):
    cfg, params = dense
    eng = InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ)
    server = MultiModelServer({"m": eng})
    a = server.submit("m", _prompt(cfg, 20), 2)
    b = server.submit("m", _prompt(cfg, 21), 2)
    first = server.run()["m"]
    assert sorted(r.request_id for r in first) == \
        sorted([a.request_id, b.request_id])
    c = server.submit("m", _prompt(cfg, 22), 2)
    # the original bug: the full completed history came back again here
    second = server.run()["m"]
    assert [r.request_id for r in second] == [c.request_id]
    assert server.run() == {"m": []}                # idle run: nothing new
