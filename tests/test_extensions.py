"""Beyond-paper extensions wired into Hydra core: spilled inference
(paper §6), AutoML early stopping (§4.7.2's degradation trigger), and
device elasticity (§4.7 faults/elastic adds)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_loader
from repro.configs import get_config
from repro.core import HydraConfig, ModelOrchestrator, ModelTask
from repro.core.orchestrator import SpilledInference
from repro.models import api


def test_spilled_inference_matches_direct_forward():
    cfg = get_config("qwen3-0.6b", smoke=True).replace(n_layers=4)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = api.make_dummy_batch(cfg, 2, 64)
    infer = SpilledInference(cfg, params, device_budget_bytes=10 * 10**6,
                             batch=2, seq=64)
    assert infer.n_shards >= 2          # genuinely larger than the budget
    logits = infer(batch)
    ref = api.forward(cfg, params, batch)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-4, atol=2e-4)
    assert infer.bytes_moved > 0


def test_spilled_inference_moe():
    cfg = get_config("mixtral-8x22b", smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = api.make_dummy_batch(cfg, 2, 64)
    infer = SpilledInference(cfg, params, device_budget_bytes=25 * 10**6,
                             batch=2, seq=64)
    logits = infer(batch)
    ref = api.forward(cfg, params, batch)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_early_stopping_shrinks_workload():
    cfg = get_config("qwen3-0.6b", smoke=True)

    def stop_after_2(losses):
        return len(losses) >= 2

    tasks = [ModelTask(cfg, make_loader(cfg, seed=i), lr=1e-3, epochs=1,
                       steps_per_epoch=4, seed=i, batch=2, seq=64,
                       early_stop=stop_after_2 if i == 0 else None)
             for i in range(2)]
    hc = HydraConfig(n_devices=2, device_budget_bytes=18 * 10**6)
    orch = ModelOrchestrator(tasks, hc)
    report = orch.train_models()
    assert len(report.losses[0]) == 2          # stopped early
    assert len(report.losses[1]) == 4          # ran to completion
    assert orch.models[0].stopped_early and not orch.models[1].stopped_early


def test_device_removal_still_completes():
    cfg = get_config("qwen3-0.6b", smoke=True)
    tasks = [ModelTask(cfg, make_loader(cfg, seed=i), lr=1e-3, epochs=1,
                       steps_per_epoch=2, seed=i, batch=2, seq=64)
             for i in range(3)]
    # device 1 disappears almost immediately — everything lands on device 0
    hc = HydraConfig(n_devices=2, device_budget_bytes=18 * 10**6,
                     device_windows={1: (0.0, 1e-4)})
    report = ModelOrchestrator(tasks, hc).train_models()
    assert all(len(v) == 2 for v in report.losses.values())
    # and the surviving device did (almost) all the work
    assert report.utilization[0] > report.utilization[1]


def test_device_late_arrival():
    cfg = get_config("qwen3-0.6b", smoke=True)
    tasks = [ModelTask(cfg, make_loader(cfg, seed=i), lr=1e-3, epochs=1,
                       steps_per_epoch=2, seed=i, batch=2, seq=64)
             for i in range(3)]
    hc = HydraConfig(n_devices=2, device_budget_bytes=18 * 10**6,
                     device_windows={1: (10_000.0, None)})  # never arrives
    report = ModelOrchestrator(tasks, hc).train_models()
    assert all(len(v) == 2 for v in report.losses.values())
    assert report.utilization[1] == 0.0


def test_all_devices_retired_raises():
    cfg = get_config("qwen3-0.6b", smoke=True)
    tasks = [ModelTask(cfg, make_loader(cfg, seed=0), lr=1e-3, epochs=1,
                       steps_per_epoch=50, batch=2, seq=64)]
    hc = HydraConfig(n_devices=1, device_budget_bytes=18 * 10**6,
                     device_windows={0: (0.0, 1e-9)})
    with pytest.raises(RuntimeError, match="retired"):
        ModelOrchestrator(tasks, hc).train_models()