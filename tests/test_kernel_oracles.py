"""Differential kernel-oracle harness: every Pallas entry point in
``repro.kernels`` fuzzed against its pure-jnp oracle in ``kernels/ref.py``.

The decode hot path now runs three compounding kernel optimizations
(multi-query paged verify, the fused paged decode layer, int8-quantized
KV pages), and each is only trustworthy relative to a slow, obviously-
correct reference.  This harness is the gate:

* hypothesis sweeps randomize shapes, GQA group counts, block sizes,
  table layouts, lengths, windows, and dtypes per kernel, asserting
  ``allclose`` against the oracle under per-kernel tolerances;
* exact edge cases pin the block-table conventions the kernels must
  honor — lengths on a block boundary, garbage-block / stale-row
  invisibility (poisoned pages change nothing), and single-token lanes;
* the int8 KV path gets round-trip properties (zero rows exact, error
  bounded by half a quantization step) plus step-level decode
  token-identity vs the fp pool for both paged families (dense, vlm),
  with the max-logit drift REPORTED, not asserted — precision loss is
  a measured quantity here, only token flips are failures.

All Pallas launches run in interpret mode so the harness is hermetic on
CPU hosts; on TPU the same entry points compile for real.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

F32_TOL = 2e-5      # elementwise/attention kernels, f32
BF16_TOL = 2e-2     # bf16 rounding dominates
MM_TOL = 2e-4       # kernels ending in matmul chains (swiglu, fused layer)


def _tol(dtype, f32=F32_TOL):
    return f32 if dtype == jnp.float32 else BF16_TOL


def _close(out, exp, tol):
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


def _pages(seed, P, bs, nkv, hd, dtype=jnp.float32):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(k1, (P, bs, nkv, hd), dtype),
            jax.random.normal(k2, (P, bs, nkv, hd), dtype))


def _tables(rng, n, B, P):
    """Distinct physical blocks per lane; never the garbage block 0."""
    return jnp.asarray(
        (rng.permutation(P - 1)[: n * B] + 1).reshape(n, B), jnp.int32)


# ---------------------------------------------------------------------------
# fuzz sweeps: one property per kernel entry point
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10**6), st.integers(1, 2),
       st.sampled_from([64, 96, 128]), st.sampled_from([1, 2]),
       st.sampled_from([1, 2]), st.sampled_from([16, 32, 64]),
       st.booleans(), st.sampled_from([None, 32]),
       st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_fuzz_flash_attention(seed, b, s, nkv, groups, hd, causal, window,
                              dtype):
    nh = nkv * groups
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, nh, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, nkv, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, nkv, hd), dtype)
    win = window if causal else None
    out = ops.flash_attention(q, k, v, causal=causal, window=win,
                              interpret=True, block_q=64, block_k=64)
    exp = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal,
        window=win).transpose(0, 2, 1, 3)
    _close(out, exp, _tol(dtype))


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10**6), st.integers(1, 200),
       st.sampled_from([64, 128, 256]),
       st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_fuzz_rms_norm(seed, rows, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jax.random.normal(ks[0], (rows, d), dtype)
    w = jax.random.normal(ks[1], (d,)) * 0.1 + 1.0
    _close(ops.rms_norm(x, w, interpret=True), ref.rms_norm_ref(x, w),
           _tol(dtype, f32=1e-5))


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10**6), st.integers(1, 128),
       st.sampled_from([64, 128]), st.sampled_from([128, 300]))
def test_fuzz_swiglu(seed, m, d, f):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (m, d))
    wg = jax.random.normal(ks[1], (d, f)) * 0.05
    wu = jax.random.normal(ks[2], (d, f)) * 0.05
    wd = jax.random.normal(ks[3], (f, d)) * 0.05
    _close(ops.swiglu(x, wg, wu, wd, interpret=True),
           ref.swiglu_ref(x, wg, wu, wd), MM_TOL)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10**6), st.integers(1, 2), st.integers(1, 3),
       st.sampled_from([8, 16]), st.sampled_from([8, 16]),
       st.sampled_from([32, 64]))
def test_fuzz_ssd_scan(seed, b, h, p, n, chunk):
    s = chunk * (1 + seed % 3)
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    la = -jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.1
    bc = jax.random.normal(ks[2], (b, s, h, n)) * 0.3
    cc = jax.random.normal(ks[3], (b, s, h, n)) * 0.3
    y, _ = ops.ssd_scan(x, la, bc, cc, chunk=chunk, interpret=True)
    _close(y, ref.ssd_scan_ref(x, la, bc, cc, chunk=chunk), MM_TOL)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10**6), st.integers(1, 4), st.sampled_from([1, 2]),
       st.sampled_from([1, 2, 4]), st.sampled_from([16, 32, 64]),
       st.sampled_from([4, 8]), st.integers(1, 4),
       st.sampled_from([None, 5]),
       st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_fuzz_paged_attention(seed, n, nkv, groups, hd, bs, B, window,
                              dtype):
    rng = np.random.default_rng(seed)
    P = n * B + 1 + int(rng.integers(0, 3))
    kp, vp = _pages(seed, P, bs, nkv, hd, dtype)
    q = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (n, nkv * groups, hd), dtype)
    tables = _tables(rng, n, B, P)
    lengths = jnp.asarray(rng.integers(1, B * bs + 1, n), jnp.int32)
    out = ops.paged_attention(q, kp, vp, tables, lengths, window=window,
                              impl="pallas_interpret")
    exp = ref.paged_attention_ref(q, kp, vp, tables, lengths, window=window)
    _close(out, exp, _tol(dtype))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10**6), st.integers(1, 3), st.integers(1, 4),
       st.sampled_from([1, 2]), st.sampled_from([1, 2, 4]),
       st.sampled_from([16, 32]), st.sampled_from([4, 8]),
       st.integers(1, 3), st.sampled_from([None, 6]),
       st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_fuzz_paged_verify(seed, n, kk, nkv, groups, hd, bs, B, window,
                           dtype):
    """Multi-query verify: all k draft rows scored through block tables
    in one launch == the gathered multi-query oracle.  ``lengths`` is the
    rows committed BEFORE the round (draft row j attends through
    lengths + j), so the sweep includes zero-prefix lanes."""
    rng = np.random.default_rng(seed)
    B = max(B, -(-kk // bs))                     # table wide enough for kk
    P = n * B + 1 + int(rng.integers(0, 3))
    kp, vp = _pages(seed, P, bs, nkv, hd, dtype)
    q = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (n, kk, nkv * groups, hd), dtype)
    tables = _tables(rng, n, B, P)
    lengths = jnp.asarray(rng.integers(0, B * bs - kk + 1, n), jnp.int32)
    out = ops.paged_verify(q, kp, vp, tables, lengths, window=window,
                           impl="pallas_interpret")
    exp = ref.paged_verify_ref(q, kp, vp, tables, lengths, window=window)
    _close(out, exp, _tol(dtype))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10**6), st.integers(1, 3), st.sampled_from([1, 2]),
       st.sampled_from([1, 2, 4]), st.sampled_from([16, 32, 64]),
       st.sampled_from([4, 8]), st.integers(1, 3),
       st.sampled_from([None, 5]))
def test_fuzz_paged_attention_quant(seed, n, nkv, groups, hd, bs, B,
                                    window):
    """int8 decode attention: in-kernel dequant == gathered dequant
    oracle, over randomly quantized pages."""
    rng = np.random.default_rng(seed)
    P = n * B + 1 + int(rng.integers(0, 3))
    kf, vf = _pages(seed, P, bs, nkv, hd)
    kq, ks_ = ref.quantize_kv(kf)
    vq, vs = ref.quantize_kv(vf)
    q = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (n, nkv * groups, hd), jnp.float32)
    tables = _tables(rng, n, B, P)
    lengths = jnp.asarray(rng.integers(1, B * bs + 1, n), jnp.int32)
    out = ops.paged_attention_quant(q, kq, vq, ks_, vs, tables, lengths,
                                    window=window, impl="pallas_interpret")
    exp = ref.paged_attention_quant_ref(q, kq, vq, ks_, vs, tables,
                                        lengths, window=window)
    _close(out, exp, F32_TOL)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10**6), st.integers(1, 3), st.sampled_from([1, 2]),
       st.sampled_from([1, 2]), st.sampled_from([16, 32]),
       st.sampled_from([4, 8]), st.integers(1, 3),
       st.sampled_from([None, 6]), st.sampled_from([64, 96]))
def test_fuzz_fused_decode_layer(seed, n, nkv, groups, hd, bs, B, window,
                                 d):
    """Fused paged decode layer (attention + wo + RMSNorm + SwiGLU +
    residuals, one launch) == the composed oracle."""
    rng = np.random.default_rng(seed)
    nh, f = nkv * groups, 2 * d
    P = n * B + 1 + int(rng.integers(0, 3))
    kp, vp = _pages(seed, P, bs, nkv, hd)
    ks = jax.random.split(jax.random.PRNGKey(seed + 1), 7)
    h = jax.random.normal(ks[0], (n, d))
    q = jax.random.normal(ks[1], (n, nh, hd))
    wo = jax.random.normal(ks[2], (nh * hd, d)) * 0.05
    mlp_scale = jax.random.normal(ks[3], (d,)) * 0.1 + 1.0
    wg = jax.random.normal(ks[4], (d, f)) * 0.05
    wu = jax.random.normal(ks[5], (d, f)) * 0.05
    wd = jax.random.normal(ks[6], (f, d)) * 0.05
    tables = _tables(rng, n, B, P)
    lengths = jnp.asarray(rng.integers(1, B * bs + 1, n), jnp.int32)
    out = ops.fused_decode_layer(h, q, kp, vp, tables, lengths, wo,
                                 mlp_scale, wg, wu, wd, window=window,
                                 impl="pallas_interpret")
    exp = ref.fused_decode_layer_ref(h, q, kp, vp, tables, lengths, wo,
                                     mlp_scale, wg, wu, wd, window=window)
    _close(out, exp, MM_TOL)


# ---------------------------------------------------------------------------
# exact block-table edge cases (the conventions fuzz can miss)
# ---------------------------------------------------------------------------

_EDGE = dict(n=3, nkv=2, groups=2, hd=32, bs=4, B=3)


def _edge_fixture(seed=13, kk=0):
    e = _EDGE
    P = e["n"] * e["B"] + 2
    kp, vp = _pages(seed, P, e["bs"], e["nkv"], e["hd"])
    nh = e["nkv"] * e["groups"]
    shape = (e["n"], kk, nh, e["hd"]) if kk else (e["n"], nh, e["hd"])
    q = jax.random.normal(jax.random.PRNGKey(seed + 1), shape, jnp.float32)
    tables = _tables(np.random.default_rng(seed), e["n"], e["B"], P)
    return q, kp, vp, tables


def test_edge_block_boundary_lengths():
    """Lengths exactly on block boundaries: one full block, mid-table
    boundary, and the whole table — off-by-one in the block loop's mask
    shows up here first."""
    e = _EDGE
    q, kp, vp, tables = _edge_fixture()
    lengths = jnp.asarray([e["bs"], 2 * e["bs"], e["B"] * e["bs"]],
                          jnp.int32)
    _close(ops.paged_attention(q, kp, vp, tables, lengths,
                               impl="pallas_interpret"),
           ref.paged_attention_ref(q, kp, vp, tables, lengths), F32_TOL)
    qv, kp, vp, tables = _edge_fixture(kk=2)
    lv = jnp.asarray([e["bs"], 2 * e["bs"] - 2, e["bs"] - 1], jnp.int32)
    _close(ops.paged_verify(qv, kp, vp, tables, lv,
                            impl="pallas_interpret"),
           ref.paged_verify_ref(qv, kp, vp, tables, lv), F32_TOL)


def test_edge_garbage_block_and_stale_rows_invisible():
    """Poisoning the garbage block (0) and every row past each lane's
    length must not move the kernel's output at all — table entries past
    the live extent point at block 0, and attention masks the rest."""
    q, kp, vp, tables = _edge_fixture()
    # lane 2's table tail points at the garbage block (short sequence)
    tables = np.asarray(tables).copy()
    tables[2, 1:] = 0
    tables = jnp.asarray(tables)
    lengths = jnp.asarray([5, 9, 3], jnp.int32)
    base = ops.paged_attention(q, kp, vp, tables, lengths,
                               impl="pallas_interpret")
    kp2 = kp.at[0].set(997.0)
    vp2 = vp.at[0].set(-997.0)
    # also trash the masked tail rows of each lane's last live block
    for lane, ln in enumerate([5, 9, 3]):
        blk = int(np.asarray(tables)[lane, ln // _EDGE["bs"]])
        kp2 = kp2.at[blk, ln % _EDGE["bs"]:].set(999.0)
        vp2 = vp2.at[blk, ln % _EDGE["bs"]:].set(999.0)
    out = ops.paged_attention(q, kp2, vp2, tables, lengths,
                              impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(base), np.asarray(out))


def test_edge_garbage_block_invisible_to_verify():
    qv, kp, vp, tables = _edge_fixture(kk=3)
    lengths = jnp.asarray([0, 4, 2], jnp.int32)
    base = ops.paged_verify(qv, kp, vp, tables, lengths,
                            impl="pallas_interpret")
    out = ops.paged_verify(qv, kp.at[0].set(999.0), vp.at[0].set(-999.0),
                           tables, lengths, impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(base), np.asarray(out))


def test_edge_single_token_lanes():
    """Every lane at length 1 (first decode step after a 1-token prompt):
    softmax over a single row must be exact for all three paged kernels."""
    q, kp, vp, tables = _edge_fixture()
    lengths = jnp.asarray([1, 1, 1], jnp.int32)
    _close(ops.paged_attention(q, kp, vp, tables, lengths,
                               impl="pallas_interpret"),
           ref.paged_attention_ref(q, kp, vp, tables, lengths), F32_TOL)
    kq, ks_ = ref.quantize_kv(kp)
    vq, vs = ref.quantize_kv(vp)
    _close(ops.paged_attention_quant(q, kq, vq, ks_, vs, tables, lengths,
                                     impl="pallas_interpret"),
           ref.paged_attention_quant_ref(q, kq, vq, ks_, vs, tables,
                                         lengths), F32_TOL)
    qv, kp, vp, tables = _edge_fixture(kk=1)
    _close(ops.paged_verify(qv, kp, vp, tables, lengths,
                            impl="pallas_interpret"),
           ref.paged_verify_ref(qv, kp, vp, tables, lengths), F32_TOL)


# ---------------------------------------------------------------------------
# int8 KV quantization: round-trip properties + decode token identity
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10**6), st.integers(1, 6), st.sampled_from([16, 64]),
       st.floats(0.01, 100.0))
def test_quant_round_trip_bounded(seed, rows, hd, scale):
    """Per-row symmetric int8: |x - dq(q(x))| <= scale/2 elementwise
    (half a quantization step), for any row magnitude."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, hd)) * scale
    q, s = ref.quantize_kv(x)
    dq = ref.dequantize_kv(q, s)
    bound = np.asarray(s)[:, None] / 2 + 1e-12
    assert (np.abs(np.asarray(x) - np.asarray(dq)) <= bound).all()
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32


def test_quant_zero_rows_exact():
    """All-zero rows (the garbage block, freshly allocated pages) must
    round-trip EXACTLY — scale clamps at eps instead of dividing by 0."""
    q, s = ref.quantize_kv(jnp.zeros((3, 4, 2, 16)))
    np.testing.assert_array_equal(np.asarray(ref.dequantize_kv(q, s)), 0.0)


def _paged_family_tokens(cfg, params, kv_dtype, steps=12, seed=5):
    """Greedy token ids + per-step max logits from paged decode steps,
    growing the pool from empty (every step scatters then attends)."""
    from repro.models import api
    n, bs, B = 2, 4, (steps + 1 + 3) // 4 + 1
    P = n * B + 1
    pages = api.init_kv_pages(cfg, P, bs, kv_dtype)
    rng = np.random.default_rng(seed)
    tables = jnp.asarray(
        (rng.permutation(P - 1)[: n * B] + 1).reshape(n, B), jnp.int32)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (n, 1)), jnp.int32)
    toks, logit_peaks = [], []
    for step in range(steps):
        lengths = jnp.full((n,), step, jnp.int32)
        logits, pages = api.paged_decode_step(
            cfg, params, pages, tables, lengths, tok, impl="jnp")
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        toks.append(np.asarray(tok)[:, 0].copy())
        logit_peaks.append(np.asarray(logits[:, -1], np.float32))
    return np.stack(toks), np.stack(logit_peaks)


@pytest.mark.parametrize("model", ["qwen3-0.6b", "llava-next-mistral-7b"])
def test_int8_kv_decode_token_identity(model):
    """int8 KV pages decode token-identically to the fp pool on a seeded
    suite, for every kv_quant family (dense, vlm).  The max logit delta
    is reported — drift is a measured quantity, token flips are bugs."""
    from repro.configs import get_config
    from repro.models import api
    cfg = get_config(model, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    fp_toks, fp_logits = _paged_family_tokens(cfg, params, None)
    q_toks, q_logits = _paged_family_tokens(cfg, params, "int8")
    drift = float(np.max(np.abs(fp_logits - q_logits)))
    rel = drift / (float(np.max(np.abs(fp_logits))) + 1e-9)
    print(f"\n[kv-quant drift] {model}: max |logit delta| = {drift:.4f} "
          f"({rel:.2%} of peak logit) over {fp_toks.shape[0]} steps")
    np.testing.assert_array_equal(fp_toks, q_toks)


def test_int8_kv_default_stays_fp():
    """Nothing quantizes unless asked: default pools carry no scale
    planes, and the default ServeJob keeps kv_dtype None."""
    from repro.api.jobs import ServeJob
    from repro.configs import get_config
    from repro.models import api
    from repro.serving.paging import BlockPool
    cfg = get_config("qwen3-0.6b", smoke=True)
    assert set(api.init_kv_pages(cfg, 4, 4)) == {"k", "v"}
    assert set(api.init_kv_pages(cfg, 4, 4, "fp")) == {"k", "v"}
    assert set(api.init_kv_pages(cfg, 4, 4, "int8")) \
        == {"k", "v", "k_scale", "v_scale"}
    assert BlockPool(cfg, 4, 4).kv_dtype == "fp"
    assert ServeJob(cfg=cfg).kv_dtype is None
    # and the quantized pool is priced strictly below fp under the same
    # geometry — the whole point of the optimization
    assert api.kv_block_bytes(cfg, 16, "int8") < api.kv_block_bytes(cfg, 16)


def test_int8_kv_rejects_non_quant_family():
    """Families without a declared quantized page layout fail loudly at
    pool construction, not silently at decode."""
    from repro.configs import get_config
    from repro.models import api
    cfg = get_config("mixtral-8x22b", smoke=True)
    with pytest.raises(ValueError, match="int8|kv_quant|paging|paged"):
        api.kv_block_bytes(cfg, 16, "int8")


def test_fused_impl_matches_jnp_paged_decode():
    """impl='fused_interpret' (fused layer kernel per scan step) is
    numerically interchangeable with the jnp paged decode path, and
    token-identical on the argmax."""
    from repro.configs import get_config
    from repro.models import api
    cfg = get_config("qwen3-0.6b", smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    n, bs, B = 2, 4, 5
    P = n * B + 1
    rng = np.random.default_rng(3)
    tables = jnp.asarray(
        (rng.permutation(P - 1)[: n * B] + 1).reshape(n, B), jnp.int32)
    pages_j = api.init_kv_pages(cfg, P, bs)
    pages_f = api.init_kv_pages(cfg, P, bs)
    tok_j = tok_f = jnp.asarray(rng.integers(0, cfg.vocab_size, (n, 1)),
                                jnp.int32)
    for step in range(6):
        lengths = jnp.full((n,), step, jnp.int32)
        lj, pages_j = api.paged_decode_step(
            cfg, params, pages_j, tables, lengths, tok_j, impl="jnp")
        lf, pages_f = api.paged_decode_step(
            cfg, params, pages_f, tables, lengths, tok_f,
            impl="fused_interpret")
        np.testing.assert_allclose(
            np.asarray(lj, np.float32), np.asarray(lf, np.float32),
            rtol=5e-2, atol=5e-2)      # bf16 end-to-end stack rounding
        tok_j = jnp.argmax(lj[:, -1], -1).astype(jnp.int32)[:, None]
        tok_f = jnp.argmax(lf[:, -1], -1).astype(jnp.int32)[:, None]
        np.testing.assert_array_equal(np.asarray(tok_j), np.asarray(tok_f))
