"""Unified session API (repro.api / hydra alias): plan/execute split,
JSON plan round-trips, mixed train+serve sessions, EvalJob parity, cold
serve promotion, config validation, and the submit/poll/cancel lifecycle."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_loader
from repro.api import (EvalJob, HydraConfig, Plan, ServeJob, Session,
                       TrainJob)
from repro.configs import get_config
from repro.models import api as mapi

BUDGET = 18 * 10**6


def _cfg():
    return get_config("qwen3-0.6b", smoke=True)


def _hc(**kw):
    kw.setdefault("n_devices", 2)
    kw.setdefault("device_budget_bytes", BUDGET)
    return HydraConfig(**kw)


def _train_jobs(cfg, n=2, steps=2):
    return [TrainJob(cfg, make_loader(cfg, seed=i), lr=1e-3, epochs=1,
                     steps_per_epoch=steps, seed=i, batch=2, seq=64)
            for i in range(n)]


def _prompt(cfg, seed, plen):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (plen,), 0, cfg.vocab_size, jnp.int32))


# ---------------------------------------------------------------------------
# plan / execute split
# ---------------------------------------------------------------------------

def test_plan_is_json_serializable_and_round_trips():
    cfg = _cfg()
    session = Session(_hc())
    for job in _train_jobs(cfg):
        session.submit(job)
    plan = session.plan()
    text = plan.to_json()
    json.loads(text)                       # valid JSON
    reloaded = Plan.from_json(text)
    assert reloaded.to_json() == text      # byte-identical round trip
    assert [jp.job_id for jp in reloaded.jobs] == ["train-0", "train-1"]
    # reconstructed partitions are identical dataclasses (incl. runtimes)
    for jp, orig in zip(reloaded.jobs, session.train_execs):
        assert jp.shards().shards == orig.partition.shards
    assert plan.schedule["est_makespan_s"] > 0
    assert plan.summary()["jobs"]["train-0"]["n_shards"] >= 2


def test_plan_execute_equivalence_across_json_reload(tmp_path):
    """A Plan re-loaded from JSON reproduces the original session's
    partition, schedule, and losses exactly when run."""
    cfg = _cfg()
    hc = dict(pilot=False, fixed_unit_runtime=1e-3)

    sess_a = Session(_hc(**hc))
    for job in _train_jobs(cfg):
        sess_a.submit(job)
    plan_a = sess_a.plan()
    path = tmp_path / "plan.json"
    plan_a.save(str(path))

    sess_b = Session(_hc(**hc))
    for job in _train_jobs(cfg):
        sess_b.submit(job)
    plan_b = Plan.load(str(path))
    report_b = sess_b.run(plan_b)
    report_a = sess_a.run(plan_a)

    for ma, mb in zip(sess_a.train_execs, sess_b.train_execs):
        assert ma.partition.shards == mb.partition.shards
    assert report_a.unit_trace == report_b.unit_trace
    for mid in report_a.train.losses:
        np.testing.assert_array_equal(report_a.train.losses[mid],
                                      report_b.train.losses[mid])


def test_run_rejects_diverged_plan():
    cfg = _cfg()
    sess = Session(_hc())
    for job in _train_jobs(cfg, n=1):
        sess.submit(job)
    plan = sess.plan()
    # corrupt the planned partition: pretend it has one giant shard
    plan.jobs[0].partition["shards"] = [plan.jobs[0].partition["shards"][0]]
    with pytest.raises(ValueError, match="divergence"):
        sess.run(plan)


# ---------------------------------------------------------------------------
# mixed train + serve in one session
# ---------------------------------------------------------------------------

def test_mixed_train_serve_session():
    cfg = _cfg()
    interleaved = []

    def spy_early_stop(losses):
        # runs at each minibatch boundary, i.e. strictly during training
        interleaved.append(len(session.serve_trace))
        return False

    session = Session(_hc())
    t_jobs = _train_jobs(cfg, n=2, steps=2)
    t_jobs[0].early_stop = spy_early_stop
    for job in t_jobs:
        session.submit(job)
    sj = session.submit(ServeJob(cfg, seed=3, capacity=2, max_seq=32))
    for i in range(2):
        session.submit_request(sj, _prompt(cfg, 40 + i, 8), 4)

    report = session.run()

    assert report.train is not None and len(report.train.losses) == 2
    rec = report.serve[sj]
    assert rec["n_completed"] == 2
    assert all(r["status"] == "finished" and r["n_generated"] == 4
               for r in rec["requests"])
    # serve engines genuinely ticked while training was still running
    assert interleaved and interleaved[0] > 0
    assert len(report.unit_trace) == report.train.units_executed


def test_serve_outputs_match_singleton_engine():
    """Tokens produced through a session tick-loop equal a lone engine's."""
    cfg = _cfg()
    params = mapi.init_params(cfg, jax.random.PRNGKey(5))
    prompt = _prompt(cfg, 11, 9)

    from repro.serving import InferenceEngine
    ref_eng = InferenceEngine(cfg, params, capacity=2, max_seq=32)
    ref_req = ref_eng.submit(prompt, 5)
    ref_eng.run()

    session = Session(_hc())
    sj = session.submit(ServeJob(cfg, params=params, capacity=2, max_seq=32))
    req = session.submit_request(sj, prompt, 5)
    session.drain_serving()
    assert req.generated == ref_req.generated


# ---------------------------------------------------------------------------
# EvalJob
# ---------------------------------------------------------------------------

def test_eval_job_matches_direct_forward_loop():
    from repro.training.losses import softmax_xent
    cfg = _cfg().replace(n_layers=4)
    params = mapi.init_params(cfg, jax.random.PRNGKey(0))
    batches = [mapi.make_dummy_batch(cfg, 2, 64,
                                     key=jax.random.PRNGKey(100 + i))
               for i in range(3)]

    session = Session(_hc(n_devices=1, device_budget_bytes=10 * 10**6))
    jid = session.submit(EvalJob(cfg, iter(batches), n_batches=3,
                                 params=params, batch=2, seq=64))
    rec = session.run().evals[jid]

    assert rec["n_shards"] >= 2            # genuinely spilled
    assert rec["bytes_moved"] > 0
    direct = [float(softmax_xent(mapi.forward(cfg, params, b), b["labels"]))
              for b in batches]
    np.testing.assert_allclose(rec["losses"], direct, rtol=2e-4, atol=2e-4)
    assert rec["perplexity"] == pytest.approx(np.exp(rec["mean_loss"]))


# ---------------------------------------------------------------------------
# cold serve (SHARP-for-inference entry point)
# ---------------------------------------------------------------------------

def test_cold_serve_promotes_on_first_request():
    cfg = _cfg()
    params = mapi.init_params(cfg, jax.random.PRNGKey(5))
    prompt = _prompt(cfg, 11, 9)

    from repro.serving import InferenceEngine
    ref_eng = InferenceEngine(cfg, params, capacity=2, max_seq=32)
    ref_req = ref_eng.submit(prompt, 5)
    ref_eng.run()

    session = Session(_hc(n_devices=1, device_budget_bytes=10 * 10**6))
    sj = session.submit(ServeJob(cfg, params=params, capacity=2, max_seq=32,
                                 cold=True))
    assert session.poll(sj)["status"] == "pending"
    plan = session.plan()
    assert plan.job(sj).partition is not None        # spill placement planned
    assert session.poll(sj)["promoted"] is False     # still host-resident

    req = session.submit_request(sj, prompt, 5)      # promotion happens here
    assert session.poll(sj)["promoted"] is True
    report = session.run()

    assert req.generated == ref_req.generated        # cold == warm outputs
    rec = report.serve[sj]
    assert rec["cold"] and rec["promote_bytes"] > 0


# ---------------------------------------------------------------------------
# validation + lifecycle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [
    dict(buffer_frac=0.9), dict(buffer_frac=0.0),
    dict(device_budget_bytes=0), dict(device_budget_bytes=-5),
    dict(link_bw=0.0), dict(scheduler="bogus"), dict(n_devices=0),
])
def test_session_rejects_invalid_config(bad):
    with pytest.raises(ValueError):
        Session(HydraConfig(**bad))


def test_submit_poll_cancel_lifecycle():
    cfg = _cfg()
    session = Session(_hc())
    jobs = _train_jobs(cfg, n=3, steps=2)
    jids = [session.submit(j) for j in jobs]
    assert jids == ["train-0", "train-1", "train-2"]
    assert all(session.poll(j)["status"] == "pending" for j in jids)

    session.cancel(jids[1])
    assert session.poll(jids[1])["status"] == "cancelled"

    report = session.run()
    # cancelled job never trained; survivors keep dense model ids 0..1
    assert sorted(report.train.losses) == [0, 1]
    assert all(len(v) == 2 for v in report.train.losses.values())
    assert session.poll(jids[0])["status"] == "done"
    assert session.poll(jids[1])["status"] == "cancelled"

    with pytest.raises(KeyError):
        session.poll("train-99")


def test_cancel_then_submit_keeps_model_ids_unique():
    """Regression: a cancel between materializations must not make a later
    job collide with an existing exec's model_id (losses are keyed by it)."""
    cfg = _cfg()
    session = Session(_hc())
    j0 = session.submit(TrainJob(cfg, make_loader(cfg, seed=0), epochs=1,
                                 steps_per_epoch=2, batch=2, seq=64))
    j1 = session.submit(TrainJob(cfg, make_loader(cfg, seed=1), epochs=1,
                                 steps_per_epoch=2, batch=2, seq=64))
    session.plan()                      # materializes j0 -> 0, j1 -> 1
    session.cancel(j0)
    session.submit(TrainJob(cfg, make_loader(cfg, seed=2), epochs=1,
                            steps_per_epoch=2, batch=2, seq=64))
    report = session.run()
    # j0 trained nothing; j1 and j2 each trained 2 steps under distinct ids
    assert sorted(report.train.losses) == [1, 2]
    assert all(len(v) == 2 for v in report.train.losses.values())


def test_run_rejects_plan_from_different_config():
    cfg = _cfg()
    sess_a = Session(_hc(scheduler="lrtf"))
    sess_a.submit(TrainJob(cfg, make_loader(cfg, seed=0), epochs=1,
                           steps_per_epoch=2, batch=2, seq=64))
    plan = Plan.from_json(sess_a.plan().to_json())   # as if disk-reloaded

    sess_b = Session(_hc(scheduler="fifo"))
    sess_b.submit(TrainJob(cfg, make_loader(cfg, seed=0), epochs=1,
                           steps_per_epoch=2, batch=2, seq=64))
    with pytest.raises(ValueError, match="scheduler"):
        sess_b.run(plan)


def test_arch_config_json_round_trip_is_exact():
    from repro.api.plan import cfg_from_dict, cfg_to_dict
    from repro.configs import ASSIGNED_ARCHS
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch, smoke=True)
        back = cfg_from_dict(json.loads(json.dumps(cfg_to_dict(cfg))))
        assert back == cfg and hash(back) == hash(cfg)


def test_cancel_serve_job_marks_queued_requests_cancelled():
    cfg = _cfg()
    session = Session(_hc())
    sj = session.submit(ServeJob(cfg, seed=0, capacity=1, max_seq=32))
    # capacity 1: the second request stays queued behind the first
    r1 = session.submit_request(sj, _prompt(cfg, 1, 8), 3)
    r2 = session.submit_request(sj, _prompt(cfg, 2, 8), 3)
    session.serve_tick()                     # r1 admitted, r2 still queued
    session.cancel(sj)
    assert r2.status.value == "cancelled" and r2.done
    session.drain_serving()                  # in-flight r1 finishes
    assert r1.status.value == "finished" and len(r1.generated) == 3


def test_duplicate_serve_name_rejected():
    cfg = _cfg()
    session = Session(_hc())
    session.submit(ServeJob(cfg, seed=0))
    with pytest.raises(ValueError, match="routing name"):
        session.submit(ServeJob(cfg, seed=1))
    session.submit(ServeJob(cfg, seed=1, name="replica-b"))  # distinct: fine


def test_run_rejects_plan_missing_a_session_job():
    cfg = _cfg()
    session = Session(_hc())
    session.submit(TrainJob(cfg, make_loader(cfg, seed=0), epochs=1,
                            steps_per_epoch=2, batch=2, seq=64))
    plan = session.plan()
    session.submit(TrainJob(cfg, make_loader(cfg, seed=1), epochs=1,
                            steps_per_epoch=2, batch=2, seq=64))
    with pytest.raises(ValueError, match="not\\s+in the plan"):
        session.run(plan)


def test_truncated_run_returns_job_to_pending():
    cfg = _cfg()
    session = Session(_hc())
    jid = session.submit(TrainJob(cfg, make_loader(cfg, seed=0), epochs=1,
                                  steps_per_epoch=4, batch=2, seq=64))
    session.run(max_units=1)                 # far short of a full epoch
    assert session.poll(jid)["status"] == "pending"
    report = session.run()                   # resumes and completes
    assert session.poll(jid)["status"] == "done"
    assert len(report.train.losses[0]) == 4


def test_plan_does_not_build_warm_engines():
    cfg = _cfg()
    session = Session(_hc())
    sj = session.submit(ServeJob(cfg, seed=0, capacity=2, max_seq=32))
    plan = session.plan()
    # the plan records the serve spec, but no engine (device state) exists
    assert plan.job(sj).meta["capacity"] == 2
    assert "n_completed" not in session.poll(sj)
    session.submit_request(sj, _prompt(cfg, 1, 8), 2)   # lazily built here
    assert "n_completed" in session.poll(sj)


def test_resumed_run_does_not_rerun_finished_eval():
    cfg = _cfg()
    session = Session(_hc())
    session.submit(TrainJob(cfg, make_loader(cfg, seed=0), epochs=1,
                            steps_per_epoch=2, batch=2, seq=64))
    ej = session.submit(EvalJob(cfg, make_loader(cfg, seed=9), n_batches=2,
                                seed=0, batch=2, seq=64))
    first = session.run(max_units=1)         # truncates train; eval completes
    assert len(first.evals[ej]["losses"]) == 2
    second = session.run()                   # resumes train only
    assert second.evals[ej]["losses"] == first.evals[ej]["losses"]
    assert session.poll(ej)["batches_done"] == 2


def test_cancelled_serve_name_is_reusable():
    cfg = _cfg()
    session = Session(_hc())
    s0 = session.submit(ServeJob(cfg, seed=0, name="m"))
    session.cancel(s0)
    s1 = session.submit(ServeJob(cfg, seed=1, name="m"))   # name freed
    req = session.submit_request("m", _prompt(cfg, 1, 8), 2)
    session.drain_serving()
    assert req.done and session.poll(s1)["n_completed"] == 1


def test_short_eval_dataloader_yields_partial_results_not_crash():
    cfg = _cfg()
    batches = [mapi.make_dummy_batch(cfg, 2, 64)]      # 1 batch, 3 requested
    session = Session(_hc())
    session.submit(TrainJob(cfg, make_loader(cfg, seed=0), epochs=1,
                            steps_per_epoch=2, batch=2, seq=64))
    ej = session.submit(EvalJob(cfg, iter(batches), n_batches=3,
                                seed=0, batch=2, seq=64))
    report = session.run()                  # must not raise StopIteration
    assert len(report.train.losses[0]) == 2             # train survived
    assert len(report.evals[ej]["losses"]) == 1         # partial eval
    assert session.poll(ej)["status"] == "done"


def test_bad_bucket_spec_fails_at_submit():
    cfg = _cfg()
    session = Session(_hc())
    with pytest.raises(ValueError, match="pow2"):
        session.submit(ServeJob(cfg, bucket_sizes="pow2 "))
    with pytest.raises(ValueError, match="positive"):
        session.submit(ServeJob(cfg, bucket_sizes=(0, 8)))
    with pytest.raises(ValueError, match="max_seq"):
        session.submit(ServeJob(cfg, max_seq=64, bucket_sizes=(8, 512)))
    # the failed submits registered nothing
    assert session.jobs() == {}


def test_rejected_foreign_plan_does_not_poison_session():
    """Config verification must fire BEFORE materializing from the plan:
    after the rejection, a plain run() partitions under the session's own
    budget, not the foreign plan's."""
    cfg = _cfg()
    big = Session(HydraConfig(n_devices=2, device_budget_bytes=10**9))
    big.submit(TrainJob(cfg, make_loader(cfg, seed=0), epochs=1,
                        steps_per_epoch=2, batch=2, seq=64))
    foreign = Plan.from_json(big.plan().to_json())
    assert len(foreign.jobs[0].partition["shards"]) == 1   # fits whole

    small = Session(_hc())                                 # 18MB: 2+ shards
    small.submit(TrainJob(cfg, make_loader(cfg, seed=0), epochs=1,
                          steps_per_epoch=2, batch=2, seq=64))
    with pytest.raises(ValueError, match="HydraConfig differs"):
        small.run(foreign)
    report = small.run()       # must partition under 18MB and complete
    assert len(small.train_execs[0].partition.shards) >= 2
    assert len(report.train.losses[0]) == 2


# ---------------------------------------------------------------------------
# async run (background executor thread with live poll)
# ---------------------------------------------------------------------------

def test_run_async_lifecycle():
    """run_async returns immediately; poll stays live mid-run; result()
    joins and hands back the same report run() would; a second run_async
    mid-flight raises; after completion a new one is allowed."""
    import time as _time
    cfg = _cfg()
    session = Session(_hc(fixed_unit_runtime=1e-3, pilot=False))
    t0 = session.submit(TrainJob(cfg, make_loader(cfg, seed=0), epochs=1,
                                 steps_per_epoch=3, batch=2, seq=64))
    sv = session.submit(ServeJob(cfg, seed=1, capacity=2, max_seq=32,
                                 paged=True, block_size=8))
    req = session.submit_request(sv, _prompt(cfg, 5, 6), 4)
    handle = session.run_async()
    with pytest.raises(RuntimeError, match="already in flight"):
        session.run_async()
    seen_statuses = set()
    while not handle.done():
        seen_statuses.add(session.poll(t0)["status"])    # live mid-run
        _time.sleep(0.01)
    report = handle.result(timeout=30)
    assert handle.done()
    assert len(report.train.losses[0]) == 3
    assert req.done and len(req.generated) == 4
    assert session.poll(t0)["status"] == "done"
    assert seen_statuses <= {"pending", "running", "done"}
    # a finished handle can be waited on repeatedly
    assert handle.result() is report
    # and the session accepts a fresh async run afterwards
    session.submit_request(sv, _prompt(cfg, 6, 5), 2)
    assert session.run_async().result(timeout=30).serve[sv]["n_completed"] == 2


def test_plain_run_refused_while_async_run_in_flight():
    """Two executors over one session's stores/ledgers would corrupt each
    other — the guard covers run(), not just a second run_async()."""
    import threading
    cfg = _cfg()
    session = Session(_hc(fixed_unit_runtime=1e-3, pilot=False))
    gate = threading.Event()

    def gated_loader():
        gate.wait(30)                        # pins the async run in-flight
        yield from make_loader(cfg, seed=0)

    session.submit(TrainJob(cfg, gated_loader(), epochs=1,
                            steps_per_epoch=2, batch=2, seq=64))
    handle = session.run_async()
    try:
        with pytest.raises(RuntimeError, match="already in flight"):
            session.run()
    finally:
        gate.set()
        handle.result(timeout=60)
    session.run()                            # finished handle: allowed again


def test_run_async_propagates_failures():
    cfg = _cfg()
    session = Session(_hc())

    def exploding():
        raise RuntimeError("boom-loader")
        yield

    session.submit(TrainJob(cfg, exploding(), epochs=1, steps_per_epoch=1,
                            batch=2, seq=64))
    handle = session.run_async()
    with pytest.raises(RuntimeError, match="boom-loader"):
        handle.result(timeout=60)


# ---------------------------------------------------------------------------
# paged serving through the session: one ledger, one plan-reported split
# ---------------------------------------------------------------------------

def test_paged_serve_shares_session_ledger_with_training():
    cfg = _cfg()
    session = Session(_hc())
    session.submit(TrainJob(cfg, make_loader(cfg, seed=0), epochs=1,
                            steps_per_epoch=2, batch=2, seq=64))
    sv = session.submit(ServeJob(cfg, seed=1, capacity=2, max_seq=32,
                                 paged=True, block_size=8))
    plan = session.plan()
    mem = plan.schedule["memory"]
    meta = plan.job(sv).meta
    assert meta["paged"] and meta["shared_ledger"]
    assert mem["serve_kv_page_cap_bytes"] == meta["kv_page_cap_bytes"] > 0
    assert mem["device_budget_bytes"] == BUDGET
    assert mem["shard_headroom_bytes"] == BUDGET \
        - mem["train_buffer_bytes"] - mem["serve_kv_page_cap_bytes"]
    # the split is operative, not informational: shards are sized against
    # the budget minus the KV-page cap, so planned promotions can never
    # collide with worst-case serve reservations on the shared ledger
    assert plan.job("train-0").partition["budget_bytes"] == \
        BUDGET - mem["serve_kv_page_cap_bytes"]

    req = session.submit_request(sv, _prompt(cfg, 3, 7), 5)
    eng = session.engine(sv)
    assert eng.paged and eng.ledger is session.devices[0]
    report = session.run(plan)
    assert req.done and len(req.generated) == 5
    rec = report.serve[sv]
    assert rec["paged"] and rec["kv_page_peak_bytes"] <= BUDGET
    # drained: the shared ledger holds no leftover page reservation
    assert session.devices[0].kv_reserved_bytes == 0
    assert session.devices[0].kv_peak_bytes > 0


def test_paged_serve_private_budget_keeps_own_ledger():
    cfg = _cfg()
    session = Session(_hc())
    budget = 64 * 1024
    sv = session.submit(ServeJob(cfg, seed=1, capacity=2, max_seq=32,
                                 paged=True, block_size=8,
                                 kv_budget_bytes=budget))
    meta = session.plan().job(sv).meta
    assert meta["paged"] and not meta["shared_ledger"]
    eng = session.engine(sv)
    assert eng.ledger is not session.devices[0]
    assert eng.budget.budget_bytes == budget


# ---------------------------------------------------------------------------
# backend selection + capability fallbacks surface in plan meta and poll
# ---------------------------------------------------------------------------

def test_plan_meta_records_effective_backend_and_capabilities():
    cfg = _cfg()
    session = Session(_hc())
    sv = session.submit(ServeJob(cfg, seed=1, capacity=2, max_seq=32,
                                 backend="paged", block_size=8))
    meta = session.plan().job(sv).meta
    assert meta["backend"] == meta["requested_backend"] == "paged"
    assert meta["capabilities"]["paging"] is True
    assert meta["capability_fallbacks"] == {}
    assert meta["prefix_share"] is True
    st = session.poll(sv)
    assert st["backend"] == "paged"
    assert st["capabilities"]["padded_prefill"] is True


def test_plan_meta_records_backend_fallback_with_reason():
    """ServeJob(paged=True) on a recurrent family is no longer a silent
    degrade: the plan meta and poll() both carry the effective backend
    and the reason, and engine construction warns once."""
    from repro.serving import CapabilityFallbackWarning
    cfg = get_config("xlstm-350m", smoke=True)
    session = Session(_hc())
    sv = session.submit(ServeJob(cfg, seed=1, capacity=2, max_seq=32,
                                 paged=True, bucket_sizes=(8, 16)))
    meta = session.plan().job(sv).meta
    assert meta["requested_backend"] == "paged"
    assert meta["backend"] == "slot" and not meta["paged"]
    assert "nothing to page" in meta["capability_fallbacks"]["backend"]
    assert "rewound" in meta["capability_fallbacks"]["bucket_sizes"]
    assert meta["bucket_sizes"] is None
    assert meta["capabilities"]["paging"] is False
    st = session.poll(sv)
    assert st["backend"] == "slot" and st["requested_backend"] == "paged"
    with pytest.warns(CapabilityFallbackWarning):
        session.engine(sv)
    assert session.poll(sv)["backend"] == "slot"


def test_bad_backend_name_fails_at_submit():
    session = Session(_hc())
    with pytest.raises(ValueError, match="known decode backends"):
        session.submit(ServeJob(_cfg(), backend="mmap"))
    with pytest.raises(ValueError, match="conflicting spec"):
        session.submit(ServeJob(_cfg(), backend="slot", paged=True))
    assert session.jobs() == {}              # nothing half-registered


def test_prefix_share_disabled_via_job_spec():
    cfg = _cfg()
    session = Session(_hc())
    sv = session.submit(ServeJob(cfg, seed=1, capacity=2, max_seq=32,
                                 backend="paged", block_size=8,
                                 prefix_share=False))
    assert session.plan().job(sv).meta["prefix_share"] is False
    eng = session.engine(sv)
    assert eng.summary()["prefix_share"] is False
