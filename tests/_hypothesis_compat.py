"""Optional-``hypothesis`` shim (see requirements-dev.txt).

When hypothesis is installed the real ``given``/``settings``/``st`` are
re-exported unchanged.  When it is absent, minimal stand-ins degrade each
``@given`` property test to a seeded fixed-examples loop: the same strategy
surface the suite uses (integers / floats / sampled_from / lists), drawn
from ``random.Random`` with a deterministic per-example seed, so tier-1
stays green — with reduced (but reproducible) case coverage — on bare
containers.
"""

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False
    import random

    _DEFAULT_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class st:  # noqa: N801 — mirrors `strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: r.choice(elements))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.getrandbits(1)))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(r):
                n = r.randint(min_size, max_size)
                return [elements.example(r) for _ in range(n)]

            return _Strategy(draw)

    def settings(max_examples=_DEFAULT_EXAMPLES, **_ignored):
        """Accepts (and ignores) hypothesis-only knobs like ``deadline``."""

        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn

        return deco

    def given(*strategies, **kw_strategies):
        def deco(fn):
            # NOTE: wrapper takes *args only — pytest must not see fn's
            # positional params and try to resolve them as fixtures
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_shim_max_examples",
                            getattr(fn, "_shim_max_examples",
                                    _DEFAULT_EXAMPLES))
                for i in range(n):
                    rng = random.Random(0xC0FFEE + i)
                    vals = [s.example(rng) for s in strategies]
                    kvals = {k: s.example(rng)
                             for k, s in sorted(kw_strategies.items())}
                    fn(*args, *vals, **kvals, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__dict__.update(fn.__dict__)
            return wrapper

        return deco
