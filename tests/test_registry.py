"""FamilySpec registry contract: every registered family's *declared*
capabilities must match *behavior*.

The registry (repro.models.registry) is the single source of capability
truth for the serving backends, prefill factories, and the session
planner — a spec that over- or under-declares would silently break
admission sizing or token identity, so this suite checks each flag
against the real code path:

* ``batched_prefill``: consuming a whole prompt chunk in ONE decode_step
  call is token-identical to the per-token loop iff declared;
* ``padded_prefill``: the padded-prefill factory builds (and is
  token-identical) iff declared;
* ``paging``: the paged decode path exists iff declared (and the paged
  engine is token-identical to the slot engine — tests/test_serving.py);
* ``servable``: the engine accepts the family iff declared;
* cost fns: ``decode_state_bytes`` / ``kv_block_bytes`` equal the
  ``jax.eval_shape``-derived byte totals of the real constructors.
"""

import math
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.models import registry

FAMILY_ARCH = {
    "dense": "qwen3-0.6b",
    "vlm": "llava-next-mistral-7b",
    "moe": "mixtral-8x22b",
    "ssm": "xlstm-350m",
    "hybrid": "zamba2-1.2b",
    "audio": "whisper-medium",
}

MAX_SEQ = 32


def _cfg(family):
    return get_config(FAMILY_ARCH[family], smoke=True)


def test_every_family_is_registered():
    assert set(registry.registered_families()) == set(FAMILY_ARCH)


@pytest.mark.parametrize("family", sorted(FAMILY_ARCH))
def test_spec_module_implements_the_family_surface(family):
    spec = registry.spec(family)
    for fn in ("init_params", "forward", "init_decode_state", "decode_step"):
        assert hasattr(spec.module, fn), f"{family}: module lacks {fn}"
    if spec.paging:
        assert hasattr(spec.module, "paged_decode_step")


@pytest.mark.parametrize("family", sorted(FAMILY_ARCH))
def test_decode_state_cost_matches_eval_shape(family):
    cfg = _cfg(family)
    spec = registry.spec(cfg)
    shapes = jax.eval_shape(
        lambda: spec.module.init_decode_state(cfg, 1, MAX_SEQ))
    expect = sum(math.prod(x.shape) * x.dtype.itemsize
                 for x in jax.tree.leaves(shapes))
    assert spec.decode_state_bytes(cfg, 1, MAX_SEQ) == expect


@pytest.mark.parametrize("family", sorted(f for f in FAMILY_ARCH
                                          if registry.spec(f).paging))
def test_kv_block_cost_matches_eval_shape(family):
    cfg = _cfg(family)
    spec = registry.spec(cfg)
    shapes = jax.eval_shape(lambda: api.init_kv_pages(cfg, 1, 8))
    expect = sum(math.prod(x.shape) * x.dtype.itemsize
                 for x in jax.tree.leaves(shapes))
    assert spec.kv_block_bytes(cfg, 8) == expect


@pytest.mark.parametrize("family", ["dense", "moe", "ssm", "hybrid"])
def test_batched_prefill_declaration_matches_behavior(family):
    """Declared batched_prefill => one whole-chunk decode_step call equals
    the per-token loop exactly (argmax-identical last logits and the same
    write index).  Undeclared families still prefill correctly through the
    scan fallback — the factory must route on the declaration."""
    cfg = _cfg(family)
    spec = registry.spec(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(5), (2, 8), 0,
                                cfg.vocab_size, jnp.int32)

    state = api.init_decode_state(cfg, 2, MAX_SEQ)
    logits_l = None
    for i in range(tokens.shape[1]):
        logits_l, state = api.decode_step(cfg, params, state,
                                          tokens[:, i:i + 1])

    if spec.batched_prefill:
        state_b = api.init_decode_state(cfg, 2, MAX_SEQ)
        logits_b, _ = api.decode_step(cfg, params, state_b, tokens)
        assert (jnp.argmax(logits_b[:, -1], -1)
                == jnp.argmax(logits_l[:, -1], -1)).all()

    from repro.training.train_loop import make_prefill_into_cache
    state_f = api.init_decode_state(cfg, 2, MAX_SEQ)
    logits_f, _ = make_prefill_into_cache(cfg)(params, state_f, tokens)
    assert (jnp.argmax(logits_f, -1) == jnp.argmax(logits_l[:, -1], -1)).all()


@pytest.mark.parametrize("family", ["dense", "moe", "ssm", "hybrid"])
def test_padded_prefill_declaration_matches_behavior(family):
    """Declared padded_prefill => a right-padded prompt prefills
    argmax-identically to the exact-length one; undeclared => the factory
    refuses (silent wrong answers are the failure mode it guards)."""
    cfg = _cfg(family)
    spec = registry.spec(cfg)
    from repro.training.train_loop import (make_padded_prefill_into_cache,
                                           make_prefill_into_cache)
    if not spec.padded_prefill:
        with pytest.raises(ValueError, match="padded prefill"):
            make_padded_prefill_into_cache(cfg)
        return
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    plen, bucket = 6, 8
    tokens = jax.random.randint(jax.random.PRNGKey(3), (1, plen), 0,
                                cfg.vocab_size, jnp.int32)
    padded = jnp.pad(tokens, ((0, 0), (0, bucket - plen)))
    state = api.init_decode_state(cfg, 1, MAX_SEQ)
    exact, state_e = make_prefill_into_cache(cfg)(params, state, tokens)
    state = api.init_decode_state(cfg, 1, MAX_SEQ)
    pad, state_p = make_padded_prefill_into_cache(cfg)(
        params, state, padded, jnp.int32(plen))
    assert (jnp.argmax(exact, -1) == jnp.argmax(pad, -1)).all()
    assert int(state_p["kv"]["index"]) == int(state_e["kv"]["index"]) == plen


@pytest.mark.parametrize("family", ["moe", "ssm", "hybrid"])
def test_paging_undeclared_raises(family):
    cfg = _cfg(family)
    assert not registry.spec(cfg).paging
    with pytest.raises(ValueError):
        api.paged_decode_step(cfg, None, None, None, None, None)


def test_paging_declared_round_trips():
    """Declared paging => the paged decode step exists and one step through
    block tables is argmax-identical to the contiguous decode step."""
    cfg = _cfg("dense")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    plen, bs = 7, 4
    tokens = jax.random.randint(jax.random.PRNGKey(9), (1, plen), 0,
                                cfg.vocab_size, jnp.int32)
    state = api.init_decode_state(cfg, 1, MAX_SEQ)
    _, state = api.decode_step(cfg, params, state, tokens)
    nxt = jnp.asarray([[11]], jnp.int32)
    ref, _ = api.decode_step(cfg, params, state, nxt)

    # copy the contiguous cache into pages (blocks 1..) and decode via table
    pages = api.init_kv_pages(cfg, 4, bs)
    k, v = state["kv"]["k"], state["kv"]["v"]          # (L, 1, S, kv, hd)
    nb = -(-plen // bs)
    for j in range(nb):
        rows = k[:, 0, j * bs:(j + 1) * bs]
        pad = bs - rows.shape[1]
        if pad:
            rows = jnp.pad(rows, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pages["k"] = pages["k"].at[:, 1 + j].set(rows.astype(pages["k"].dtype))
        rows = v[:, 0, j * bs:(j + 1) * bs]
        if pad:
            rows = jnp.pad(rows, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pages["v"] = pages["v"].at[:, 1 + j].set(rows.astype(pages["v"].dtype))
    tables = jnp.zeros((1, 8), jnp.int32).at[0, :nb].set(
        jnp.arange(1, nb + 1))
    logits, _ = api.paged_decode_step(
        cfg, params, pages, tables, jnp.asarray([plen], jnp.int32), nxt)
    assert (jnp.argmax(logits[:, -1], -1) == jnp.argmax(ref[:, -1], -1)).all()


def test_servable_declaration_matches_engine():
    from repro.serving import InferenceEngine
    cfg = _cfg("audio")
    assert not registry.spec(cfg).servable
    with pytest.raises(ValueError, match="encoder-decoder"):
        InferenceEngine(cfg, params=None, capacity=1, max_seq=16)


def test_spec_lookup_by_cfg_and_name_and_unknown():
    cfg = _cfg("dense")
    assert registry.spec(cfg) is registry.spec("dense")
    with pytest.raises(KeyError, match="no registered model family"):
        registry.spec("not-a-family")


def test_families_with_capability_queries():
    assert set(registry.families_with("paging")) == {"dense", "vlm"}
    assert set(registry.families_with("batched_prefill")) \
        == {"dense", "vlm", "moe"}
    assert set(registry.families_with("padded_prefill")) == {"dense", "vlm"}
    assert "audio" not in registry.families_with("servable")


def test_every_absent_capability_has_a_reason():
    for family in registry.registered_families():
        spec = registry.spec(family)
        for cap, on in spec.capabilities().items():
            if not on:
                assert spec.why_not(cap) != \
                    "not declared by the family spec", \
                    f"{family}.{cap}: absent capability needs a note"


# ---------------------------------------------------------------------------
# deprecated predicate shims (one release of grace, then delete)
# ---------------------------------------------------------------------------

def test_deprecated_predicates_still_answer_through_the_registry():
    dense, moe = _cfg("dense"), _cfg("moe")
    with pytest.warns(DeprecationWarning):
        assert api.is_attention_family(dense)
    with pytest.warns(DeprecationWarning):
        assert not api.supports_padded_prefill(moe)
    with pytest.warns(DeprecationWarning):
        assert api.supports_paging(dense) and not api.supports_paging(moe)
    with pytest.warns(DeprecationWarning):
        assert set(api.ATTENTION_FAMILIES) == {"dense", "vlm", "moe"}
    with pytest.warns(DeprecationWarning):
        assert set(api.PAGED_FAMILIES) == {"dense", "vlm"}
    with pytest.raises(AttributeError):
        api.NOT_A_THING


def test_registry_symbols_reexported_from_hydra():
    import hydra
    assert hydra.family_spec(_cfg("dense")).paging
    assert isinstance(hydra.family_spec("ssm"), hydra.FamilySpec)
    assert "dense" in hydra.registered_families()
    assert issubclass(hydra.CapabilityFallbackWarning, UserWarning)
    assert isinstance(hydra.SlotBackend, type)
    assert isinstance(hydra.PagedBackend, type)
