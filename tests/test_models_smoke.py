"""Per-architecture smoke tests (assignment: reduced variant of each family,
one forward / train step on CPU, shape + NaN assertions)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import api
from repro.optim import OptimizerConfig, init_state
from repro.training import make_train_step

ALL_ARCHS = ASSIGNED_ARCHS + ["bert-large-1b", "vit-300m"]


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = api.make_dummy_batch(cfg, 2, 128)
    logits = api.forward(cfg, params, batch)
    assert logits.shape == (2, 128, cfg.vocab_size)
    assert not jnp.isnan(logits).any()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_no_nan(arch):
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    ocfg = OptimizerConfig(lr=1e-3)
    state = init_state(ocfg, params)
    step = jax.jit(make_train_step(cfg, ocfg))
    batch = api.make_dummy_batch(cfg, 2, 128)
    params, state, m0 = step(params, state, batch)
    params, state, m1 = step(params, state, batch)
    assert not jnp.isnan(m0["loss"]) and not jnp.isnan(m1["loss"])
    # same batch twice -> loss must drop
    assert float(m1["loss"]) < float(m0["loss"])


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    state = api.init_decode_state(cfg, 2, 64)
    toks = jnp.ones((2, 1), jnp.int32)
    logits, state = api.decode_step(cfg, params, state, toks)
    logits2, _ = api.decode_step(cfg, params, state, toks)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert not jnp.isnan(logits).any() and not jnp.isnan(logits2).any()


def test_grad_accumulation_matches_full_batch():
    # SGD (linear in grads) so the comparison is not sensitive to Adam's
    # sign-like normalization of near-zero gradients
    cfg = get_config("qwen3-0.6b", smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    ocfg = OptimizerConfig(kind="sgd", lr=1e-2, grad_clip=0.0,
                           weight_decay=0.0)
    batch = api.make_dummy_batch(cfg, 4, 64)
    s0 = init_state(ocfg, params)
    p1, _, m1 = jax.jit(make_train_step(cfg, ocfg, accum_steps=1))(
        params, s0, batch)
    p2, _, m2 = jax.jit(make_train_step(cfg, ocfg, accum_steps=4))(
        params, s0, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 3e-3
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)
    assert max(jax.tree.leaves(diffs)) < 5e-4


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "whisper-medium",
                                  "xlstm-350m", "zamba2-1.2b",
                                  "mixtral-8x22b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode reproduces the full-sequence forward logits."""
    cfg = get_config(arch, smoke=True)
    cfg = cfg.replace(remat=False)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 8
    batch = api.make_dummy_batch(cfg, b, s)
    full = api.forward(cfg, params, batch)          # (b, s, V)

    state = api.init_decode_state(cfg, b, s + 4)
    outs = []
    for i in range(s):
        logits, state = api.decode_step(cfg, params, state,
                                        batch["tokens"][:, i:i + 1])
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    # note: whisper decode path needs the real cross-KV; replace stub cache
    if cfg.family == "audio":
        from repro.models import encdec
        enc_out = encdec.encode(cfg, params, batch["enc_embeds"])
        state = api.init_decode_state(cfg, b, s + 4)
        state["cross"] = encdec.precompute_cross_kv(cfg, params, enc_out)
        outs = []
        for i in range(s):
            logits, state = api.decode_step(cfg, params, state,
                                            batch["tokens"][:, i:i + 1])
            outs.append(logits[:, 0])
        dec = jnp.stack(outs, axis=1)
    assert jnp.max(jnp.abs(dec - full)) < 2e-2, float(
        jnp.max(jnp.abs(dec - full)))


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mixtral-8x22b"])
def test_fp8_kv_cache_decode(arch):
    """Serving optimization: fp8 KV cache decodes without blowup and tracks
    the bf16-cache logits closely."""
    cfg8 = get_config(arch, smoke=True).replace(
        kv_cache_dtype="float8_e4m3fn")
    cfg16 = get_config(arch, smoke=True)
    params = api.init_params(cfg16, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg16.vocab_size, jnp.int32)
    outs = {}
    for name, cfg in (("f8", cfg8), ("bf16", cfg16)):
        state = api.init_decode_state(cfg, 2, 16)
        for i in range(8):
            logits, state = api.decode_step(cfg, params, state,
                                            toks[:, i:i + 1])
        outs[name] = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    assert not jnp.isnan(outs["f8"]).any()
    # distributions agree loosely (fp8 quantization noise)
    assert float(jnp.mean(jnp.abs(outs["f8"] - outs["bf16"]))) < 2e-3
