"""End-to-end behaviour of the full system: the paper's Fig-4 API drives a
real multi-model workload, and the dry-run launcher lowers reduced configs on
a forced multi-device host mesh (subprocess, so the device-count env is set
before jax initializes)."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_paper_fig4_api():
    """The exact usage pattern of paper Fig. 4."""
    from conftest import make_loader
    from repro.configs import get_config
    from repro.core import HydraConfig, ModelOrchestrator, ModelTask

    cfg = get_config("bert-large-1b", smoke=True)
    task_0 = ModelTask(cfg, make_loader(cfg, seed=0), lr=1e-3, epochs=1,
                       steps_per_epoch=2, batch=2, seq=64)
    task_1 = ModelTask(cfg, make_loader(cfg, seed=1), lr=1e-4, epochs=1,
                       steps_per_epoch=2, batch=2, seq=64)
    orchestra = ModelOrchestrator([task_0, task_1],
                                  HydraConfig(n_devices=2,
                                              device_budget_bytes=8 * 10**6))
    report = orchestra.train_models()
    assert len(report.losses[0]) == 2 and len(report.losses[1]) == 2
    assert all(np.isfinite(l) for ls in report.losses.values() for l in ls)
    # trained params are reassembled into the standard tree
    params = orchestra.model_params(0)
    assert "layers" in params and "embed" in params


def _run_subprocess(code: str) -> str:
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_dryrun_small_mesh_all_families():
    """Reduced configs lower + compile on a forced 8-device (2,4) mesh —
    the in-process analogue of the 512-device production dry-run."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_config, INPUT_SHAPES
from repro.models import api
from repro.optim import OptimizerConfig, init_state
from repro.sharding import specs as sh
from repro.training import make_train_step, make_decode_step

from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
for arch in ["qwen3-0.6b", "mixtral-8x22b", "xlstm-350m", "zamba2-1.2b",
             "whisper-medium"]:
    cfg = get_config(arch, smoke=True)
    ocfg = OptimizerConfig()
    params_s = jax.eval_shape(lambda: api.init_params(cfg, jax.random.PRNGKey(0)))
    pshard = sh.to_shardings(mesh, sh.param_specs(cfg, params_s, mesh))
    opt_s = jax.eval_shape(lambda: init_state(ocfg, jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), params_s)))
    oshard = sh.to_shardings(mesh, sh.opt_state_specs(cfg, opt_s, mesh))
    import dataclasses
    from repro.configs.base import InputShape
    shape = InputShape("t", 128, 4, "train")
    batch_s = api.input_specs(cfg, shape, kind="train")
    bshard = sh.to_shardings(mesh, sh.batch_specs(cfg, batch_s, mesh))
    fn = jax.jit(make_train_step(cfg, ocfg),
                 in_shardings=(pshard, oshard, bshard))
    compiled = fn.lower(params_s, opt_s, batch_s).compile()
    ma = compiled.memory_analysis()
    assert ma.temp_size_in_bytes >= 0
    # decode too
    state_s = jax.eval_shape(lambda: api.init_decode_state(cfg, 4, 128))
    sshard = sh.to_shardings(mesh, sh.decode_state_specs(cfg, state_s, mesh))
    tok = jax.ShapeDtypeStruct((4, 1), jnp.int32)
    dfn = jax.jit(make_decode_step(cfg), in_shardings=(pshard, sshard, None))
    dfn.lower(params_s, state_s, tok).compile()
    print("OK", arch)
"""
    out = _run_subprocess(code)
    assert out.count("OK") == 5


def test_train_launcher_end_to_end():
    from repro.launch.train import train

    class A:
        arch = "qwen3-0.6b"; smoke = True; steps = 6; batch = 2; seq = 64
        accum = 1; lr = 1e-3; optimizer = "adamw"; seed = 0; data = None
        mesh = "auto"; multi_pod = False; log_every = 2
        ckpt_dir = None; ckpt_every = 100

    out = train(A())
    assert np.isfinite(out["final_loss"])
    assert out["history"][-1]["loss"] < out["history"][0]["loss"] + 1.0


def test_serve_launcher_end_to_end():
    from repro.launch.serve import serve

    class A:
        arch = "qwen3-0.6b"; smoke = True; batch = 2
        prompt_len = 8; gen = 4; seed = 0
        capacity = 2; max_seq = 0; kv_budget_mb = 0
        stagger = 0; scheduler = "lrtf"

    out = serve(A())
    assert len(out["requests"]) == 2
    assert all(r["n_generated"] == 4 and r["status"] == "finished"
               for r in out["requests"])
    assert out["engines"]["qwen3-0.6b"]["n_completed"] == 2
    assert len(out["sample"]) == 4
