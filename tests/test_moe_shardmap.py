"""shard_map expert-parallel MoE (explicit all_to_all) vs the local path.

Runs in a subprocess with 8 forced host devices so the mesh is real.
"""

import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import api, moe
from repro.sharding.context import activation_axes

from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
cfg = get_config("mixtral-8x22b", smoke=True)   # 4 experts on model=4
params = api.init_params(cfg, jax.random.PRNGKey(0))

# layer-level: exact agreement in f32
lp = jax.tree.map(lambda a: a[0], params["layers"])["moe"]
x = jax.random.normal(jax.random.PRNGKey(2), (4, 64, cfg.d_model),
                      jnp.float32) * 0.3
y_ref, aux_ref = moe.moe_mlp(lp, x, cfg)
with activation_axes(mesh):
    y_sm, aux_sm = jax.jit(lambda p, xx: moe.moe_mlp(p, xx, cfg))(lp, x)
np.testing.assert_allclose(np.asarray(y_sm), np.asarray(y_ref),
                           rtol=1e-5, atol=1e-5)
np.testing.assert_allclose(float(aux_sm["lb_loss"]),
                           float(aux_ref["lb_loss"]), rtol=1e-6)
print("LAYER_OK")

# end-to-end: distributions agree (bf16 reduction-order noise only) and the
# compiled program really carries all-to-all collectives
batch = api.make_dummy_batch(cfg, 4, 64)
ref = api.forward(cfg, params, batch)
with activation_axes(mesh):
    fn = jax.jit(lambda p, b: api.forward(cfg, p, b))
    out = fn(params, batch)
    txt = fn.lower(params, batch).compile().as_text()
pp = jax.nn.softmax(out.astype(jnp.float32), -1)
pr = jax.nn.softmax(ref.astype(jnp.float32), -1)
assert float(jnp.max(jnp.abs(pp - pr))) < 5e-3
assert "all-to-all" in txt
print("E2E_OK", txt.count("all-to-all"))
"""


def test_shardmap_moe_subprocess():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "LAYER_OK" in out.stdout and "E2E_OK" in out.stdout
