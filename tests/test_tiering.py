"""Tiered KV + weights memory (ROADMAP item 3; docs/serving.md).

Three invariants under test, all on the ONE shared ``DeviceMemory``
ledger:

1. **Byte reconciliation** — across any interleaving of preempt → demote
   → prefetch → resume / cancel, device-side reservations plus the host
   pool reconcile exactly with the ledger's ``kv_reserved_bytes`` /
   ``host_kv_bytes`` terms, and a full drain returns every term to its
   baseline (no leaked bytes, blocks, or refcounts).
2. **Token identity** — a demote → prefetch → resume cycle reproduces
   exactly the tokens of untiered decode (the pages round-trip through
   host numpy arrays bit-exactly), on the paged backend directly and
   through the ``Session`` serve surface.
3. **Weight residency** — ``ShardResidentParams`` pins hot shards under
   ``reserve_weights``, streams cold shards through the same double-buffer
   discipline SHARP training uses, demotes idle models under ledger
   pressure (LRU by last-served tick), and never changes decode output
   (weights are read-only; residency is pure mechanism).
"""

import functools

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.spilling import DeviceMemory
from repro.models import api
from repro.serving.engine import InferenceEngine
from repro.serving.request import Status

from tests._hypothesis_compat import given, settings, st

MAX_SEQ = 64


@functools.lru_cache(maxsize=None)
def _dense():
    cfg = get_config("qwen3-0.6b", smoke=True)
    return cfg, api.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def dense():
    return _dense()


def _prompt(cfg, seed, plen=8):
    rng = np.random.RandomState(seed)
    return rng.randint(0, cfg.vocab_size, plen).astype(np.int32)


def _paged(cfg, params, *, capacity=2, policy="slo", ledger=None,
           tiered=False, prefetch_ticks=1, n_blocks=32):
    return InferenceEngine(cfg, params, capacity=capacity, max_seq=MAX_SEQ,
                           backend="paged", block_size=8, n_blocks=n_blocks,
                           ledger=ledger, policy=policy, tiered_kv=tiered,
                           prefetch_ticks=prefetch_ticks)


def _sequential(cfg, params, prompts_gens):
    """Reference: each prompt decoded alone — the token-identity oracle."""
    out = []
    eng = _paged(cfg, params, capacity=1, policy="fifo")
    for prompt, gen in prompts_gens:
        r = eng.submit(prompt, gen)
        eng.run()
        out.append(r.generated)
    return out


def _run_preempt_scenario(cfg, params, ledger, **kw):
    """Two low-priority longs saturate both lanes; a high-priority short
    preempts one.  With tiering on, the victim's pages demote eagerly."""
    eng = _paged(cfg, params, capacity=2, ledger=ledger, tiered=True, **kw)
    longs = [eng.submit(_prompt(cfg, i), 16, priority="low")
             for i in (1, 2)]
    for _ in range(3):
        eng.step()
    assert all(r.status is Status.RUNNING for r in longs)
    short = eng.submit(_prompt(cfg, 3), 4, priority="high",
                       deadline_ms=60_000.0)
    eng.step()
    return eng, longs, short


def _assert_drained(eng, ledger):
    """Every tier back to baseline: device bytes, host bytes, blocks,
    refcounts — the reconciliation terms of docs/serving.md."""
    assert eng.budget.reserved_bytes == 0
    assert ledger.kv_reserved_bytes == 0
    assert ledger.host_kv_bytes == 0
    assert eng.backend.host_pool.n_blocks == 0
    assert eng.pool.n_free == eng.pool.n_allocatable
    assert eng.pool.refcounts() == {}


def _reconcile(eng, ledger):
    """Mid-flight invariant: the host pool and the ledger's host term are
    the same bytes, and device usage never exceeds the budget."""
    assert eng.backend.host_pool.used_bytes() == ledger.host_kv_bytes
    assert ledger.used_bytes() <= ledger.budget


# ---------------------------------------------------------------------------
# tiered KV: demote -> prefetch -> resume
# ---------------------------------------------------------------------------

def test_preempt_demotes_eagerly_and_resumes_identical(dense):
    cfg, params = dense
    ledger = DeviceMemory(-1, budget_bytes=10**9)
    eng, longs, short = _run_preempt_scenario(cfg, params, ledger)
    assert eng.n_preempted >= 1
    victim = next(r for r in longs if r.status is Status.PREEMPTED)
    # eager demotion: the parked snapshot's sole-owner pages moved to host
    assert eng.backend.parked_state(victim) == "demoted"
    assert eng.backend.demoted_blocks(victim) > 0
    assert ledger.host_kv_bytes > 0
    _reconcile(eng, ledger)
    eng.run()
    assert all(r.status is Status.FINISHED for r in longs + [short])
    ref = _sequential(cfg, params,
                      [(_prompt(cfg, 1), 16), (_prompt(cfg, 2), 16),
                       (_prompt(cfg, 3), 4)])
    assert [longs[0].generated, longs[1].generated, short.generated] == ref
    s = eng.summary()
    assert s["tiered"] is True
    assert s["kv_demoted_bytes"] > 0
    assert s["kv_prefetched_bytes"] == s["kv_demoted_bytes"]
    _assert_drained(eng, ledger)


def test_slow_prefetch_counts_misses_still_identical(dense):
    """prefetch_ticks=3: the scheduler wants the lane before the transfer
    lands, so the wait is a recorded miss — and costs only latency, never
    tokens."""
    cfg, params = dense
    ledger = DeviceMemory(-1, budget_bytes=10**9)
    eng, longs, short = _run_preempt_scenario(cfg, params, ledger,
                                              prefetch_ticks=3)
    eng.run()
    assert all(r.status is Status.FINISHED for r in longs + [short])
    ref = _sequential(cfg, params,
                      [(_prompt(cfg, 1), 16), (_prompt(cfg, 2), 16),
                       (_prompt(cfg, 3), 4)])
    assert [longs[0].generated, longs[1].generated, short.generated] == ref
    assert eng.summary()["prefetch_misses"] >= 1
    _assert_drained(eng, ledger)


def test_cancel_while_demoted_settles_everything(dense):
    cfg, params = dense
    ledger = DeviceMemory(-1, budget_bytes=10**9)
    eng, longs, short = _run_preempt_scenario(cfg, params, ledger)
    victim = next(r for r in longs if r.status is Status.PREEMPTED)
    assert eng.backend.demoted_blocks(victim) > 0
    assert eng.cancel(victim.request_id)
    eng.run()
    assert victim.status is Status.CANCELLED
    assert eng.n_resumed == 0
    _assert_drained(eng, ledger)


def test_preempted_ttft_estimate_includes_resume_cost(dense):
    """Satellite 1: min_slack_seconds charges a demoted victim the
    prefetch + re-admission latency, so the SLO router sees the true
    time-to-next-token of a parked request."""
    cfg, params = dense
    ledger = DeviceMemory(-1, budget_bytes=10**9)
    eng, longs, short = _run_preempt_scenario(cfg, params, ledger)
    victim = next(r for r in longs if r.status is Status.PREEMPTED)
    assert eng.resume_cost_seconds(victim) > 0.0
    # an active request pays no resume cost
    active = next(r for r in longs + [short]
                  if r.status is Status.RUNNING)
    assert eng.resume_cost_seconds(active) == 0.0
    eng.run()
    _assert_drained(eng, ledger)


def test_untiered_engine_rejects_nothing_changes(dense):
    """tiered_kv=False is the exact PR-7 engine: no host pool, no demote
    hooks, same preempt/resume tokens."""
    cfg, params = dense
    eng = _paged(cfg, params, capacity=2,
                 ledger=DeviceMemory(-1, budget_bytes=10**9))
    assert eng.backend.host_pool is None
    assert eng.backend.tiered is False
    assert "host_pool_blocks" not in eng.summary()


def test_bad_prefetch_ticks_rejected(dense):
    cfg, params = dense
    with pytest.raises(ValueError, match="prefetch_ticks"):
        _paged(cfg, params, tiered=True, prefetch_ticks=0,
               ledger=DeviceMemory(-1, budget_bytes=10**9))


# ---------------------------------------------------------------------------
# property: byte reconciliation across random interleavings
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_property_interleavings_reconcile(seed):
    """Random preempt/demote/prefetch/cancel/step interleavings: the
    ledger's device + host terms reconcile with the engine's pools at
    every step, and a full drain restores the baseline."""
    cfg, params = _dense()
    rng = np.random.RandomState(seed)
    ledger = DeviceMemory(-1, budget_bytes=10**9)
    eng = _paged(cfg, params, capacity=2, ledger=ledger, tiered=True,
                 prefetch_ticks=int(rng.randint(1, 4)))
    reqs = [eng.submit(_prompt(cfg, int(rng.randint(100))),
                       int(rng.randint(4, 14)),
                       priority=["low", "normal", "high"][i % 3])
            for i in range(4)]
    for _ in range(30):
        op = rng.randint(4)
        if op == 0:
            eng.step()
        elif op == 1:
            # demote any resident parked snapshot by hand
            parked = [r for r in reqs if r.status is Status.PREEMPTED]
            if parked:
                eng.backend.demote_parked(parked[int(rng.randint(
                    len(parked)))])
        elif op == 2:
            # cancel someone (possibly mid-demotion / mid-prefetch)
            live = [r for r in reqs if r.status in (Status.QUEUED,
                                                    Status.RUNNING,
                                                    Status.PREEMPTED)]
            if live:
                eng.cancel(live[int(rng.randint(len(live)))].request_id)
        else:
            # a high-priority arrival to force preemption traffic
            if len(reqs) < 8:
                reqs.append(eng.submit(_prompt(cfg, int(rng.randint(100))),
                                       4, priority="high",
                                       deadline_ms=60_000.0))
        _reconcile(eng, ledger)
    eng.run()
    _reconcile(eng, ledger)
    _assert_drained(eng, ledger)
    assert all(r.status in (Status.FINISHED, Status.CANCELLED,
                            Status.REJECTED) for r in reqs)


# ---------------------------------------------------------------------------
# weight residency: ShardResidentParams + cross-model LRU
# ---------------------------------------------------------------------------

PART_BUDGET = 3_200_000     # partitions the smoke model into 2 shards
HOT_CAP = 3_000_000         # pins exactly one ~2.75 MB shard


def _shard_setup(ledger_budget, *, hot_bytes=None, name=None,
                 ledger=None):
    """A 2-shard host store + ShardResidentParams: ``hot_bytes=HOT_CAP``
    pins the first shard and streams the second (partial residency)."""
    from repro.core import shard_graph as sg
    from repro.core import partitioner as pt
    from repro.core.spilling import HostModelStore
    from repro.optim import optimizers as opt
    from repro.serving.residency import ShardResidentParams
    cfg, params = _dense()
    shard_plan = sg.build_plan(cfg)
    host = sg.prepare_host_params(cfg, jax.tree.map(np.asarray, params))
    partition = pt.partition(cfg, host, shard_plan,
                             budget_bytes=PART_BUDGET, batch=1,
                             seq=MAX_SEQ, train=False)
    store = HostModelStore(cfg, shard_plan, params,
                           opt.OptimizerConfig(grad_clip=0.0), partition)
    led = ledger or DeviceMemory(-1, budget_bytes=ledger_budget)
    src = ShardResidentParams(cfg, store, partition, led,
                              hot_bytes=hot_bytes, name=name)
    return cfg, params, partition, led, src


def test_shard_residency_streams_and_reconciles():
    cfg, params, partition, led, src = _shard_setup(6 * 10**6,
                                                    hot_bytes=HOT_CAP)
    assert src.n_shards > 1, "budget did not force a multi-shard partition"
    assembled = src.begin_tick()
    # mid-tick: hot pins + the in-flight streamed shard charge the ledger
    assert led.weight_resident_bytes == src.hot_resident_bytes
    assert led.used_bytes() <= led.budget
    src.end_tick()
    assert led.resident_bytes == 0 and led.buffered_bytes == 0
    # partial residency: the hot cap pins one shard, streams the other
    assert 0 < src.n_hot_shards < src.n_shards
    assert 0 < src.hot_resident_bytes < src.total_bytes
    assert src.summary()["n_stream_promotions"] > 0
    # the assembled tree is numerically the full model
    ref = jax.tree.leaves(params)[0]
    got = jax.tree.leaves(assembled)[0]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_shard_residency_decode_token_identity():
    """Decoding with only part of the model pinned produces exactly the
    tokens of fully-resident decode."""
    cfg, params, partition, led, src = _shard_setup(6 * 10**6,
                                                    hot_bytes=HOT_CAP)
    eng = InferenceEngine(cfg, None, capacity=1, max_seq=MAX_SEQ,
                          backend="paged", block_size=8, policy="fifo",
                          param_source=src)
    r = eng.submit(_prompt(cfg, 5), 8)
    eng.run()
    assert r.status is Status.FINISHED
    ref = _sequential(cfg, params, [(_prompt(cfg, 5), 8)])
    assert r.generated == ref[0]
    # residency traffic is visible in the engine summary
    s = eng.summary()
    assert s["residency"] == "shard"
    assert s["n_hot_shards"] < s["n_shards"]
    assert s["stream_promoted_bytes"] > 0
    # between ticks only the hot set stays charged
    assert led.weight_resident_bytes == src.hot_resident_bytes
    assert led.resident_bytes == 0 and led.buffered_bytes == 0


def test_pressure_demotes_lru_model():
    """Two models under one ledger: reserving bytes that do not fit
    demotes the least-recently-served model's pinned shards first."""
    from repro.serving.residency import ResidencyCoordinator
    budget = 12 * 10**6     # fits both models' ~5.5 MB of pinned weights
    led = DeviceMemory(-1, budget_bytes=budget)
    coord = ResidencyCoordinator(led)
    _, _, _, _, a = _shard_setup(budget, ledger=led, name="model-a")
    _, _, _, _, b = _shard_setup(budget, ledger=led, name="model-b")
    coord.register(a)
    coord.register(b)
    a.begin_tick()
    a.end_tick()
    b.begin_tick()
    b.end_tick()            # LRU order now: a older than b
    a_before, b_before = a.hot_resident_bytes, b.hot_resident_bytes
    assert a_before > 0 and b_before > 0
    # a KV reservation that cannot fit beside both pins: pressure fires
    need = budget - led.used_bytes() + a_before // 2
    assert led.reserve_kv(need)
    # the LRU model (a) demoted first; b stays warm
    assert a.hot_resident_bytes < a_before
    assert b.hot_resident_bytes == b_before
    assert led.used_bytes() <= led.budget
    led.release_kv(need)


def test_relieve_never_demotes_mid_tick():
    """A model mid-serve-tick must keep its pins: pressure skips it."""
    cfg, params, partition, led, src = _shard_setup(6 * 10**6,
                                                    hot_bytes=HOT_CAP)
    src.begin_tick()
    pinned = src.hot_resident_bytes
    freed = src.demote(pinned or 1)
    assert freed == 0                      # guarded by _in_tick
    assert src.hot_resident_bytes == pinned
    src.end_tick()
    freed = src.demote(pinned or 1)        # after the tick: demotable
    assert freed == pinned


def test_weight_reservation_over_release_raises():
    led = DeviceMemory(-1, budget_bytes=10**6)
    assert led.reserve_weights(1000)
    with pytest.raises(RuntimeError, match="release_weights"):
        led.release_weights(2000)
    led.release_weights(1000)
    assert led.weight_resident_bytes == 0


# ---------------------------------------------------------------------------
# ledger unit properties: demote/prefetch/drop bookkeeping
# ---------------------------------------------------------------------------

def test_ledger_kv_tier_roundtrip():
    led = DeviceMemory(-1, budget_bytes=10_000)
    assert led.reserve_kv(8_000)
    led.demote_kv(6_000)
    assert led.kv_reserved_bytes == 2_000
    assert led.host_kv_bytes == 6_000
    assert led.used_bytes() == 2_000       # host bytes are NOT device bytes
    # prefetch pulls them back under the budget check
    assert led.prefetch_kv(6_000)
    assert led.kv_reserved_bytes == 8_000 and led.host_kv_bytes == 0
    led.demote_kv(8_000)
    led.drop_host_kv(8_000)                # cancel while parked
    assert led.host_kv_bytes == 0 and led.kv_reserved_bytes == 0
    assert led.stats.kv_demoted_bytes == 14_000
    assert led.stats.kv_prefetched_bytes == 6_000


def test_ledger_prefetch_respects_budget_and_pressure():
    led = DeviceMemory(-1, budget_bytes=10_000)
    assert led.reserve_kv(10_000)
    led.demote_kv(4_000)
    # someone else takes the freed bytes: prefetch must fail, not deadlock
    assert led.reserve_kv(4_000)
    assert not led.prefetch_kv(4_000)
    assert led.host_kv_bytes == 4_000      # still parked, nothing lost
    led.release_kv(4_000)
    assert led.prefetch_kv(4_000)
    assert led.host_kv_bytes == 0


def test_ledger_host_over_release_raises():
    led = DeviceMemory(-1, budget_bytes=10_000)
    assert led.reserve_kv(2_000)
    led.demote_kv(2_000)
    with pytest.raises(RuntimeError, match="host"):
        led.prefetch_kv(3_000)
    with pytest.raises(RuntimeError, match="host"):
        led.drop_host_kv(3_000)
    led.drop_host_kv(2_000)


# ---------------------------------------------------------------------------
# session surface: train-then-serve + shard-resident cold serve
# ---------------------------------------------------------------------------

def _synth_loader(cfg, n=4, batch=2, seq=16):
    rng = np.random.RandomState(0)
    out = []
    for _ in range(n):
        toks = rng.randint(0, cfg.vocab_size, (batch, seq)).astype(np.int32)
        out.append({"tokens": toks, "labels": toks})
    return out


def test_session_train_then_serve_promotion(dense):
    """Satellite 2: a finished TrainJob's weights flow into a ServeJob in
    the same session, served shard-granular, token-identical to decoding
    the trained store by hand."""
    from repro.api.jobs import ServeJob, TrainJob
    from repro.api.session import Session
    from repro.core.sharp import HydraConfig
    cfg, params = dense
    sess = Session(HydraConfig(n_devices=1, device_budget_bytes=10**9))
    tid = sess.submit(TrainJob(cfg, dataloader=_synth_loader(cfg), lr=1e-3,
                               epochs=1, steps_per_epoch=2, seed=0,
                               batch=2, seq=16))
    sid = sess.submit(ServeJob(cfg, params_from=tid, residency="shard",
                               backend="paged", max_seq=MAX_SEQ,
                               capacity=2, block_size=8))
    sess.run()
    r = sess.submit_request(sid, _prompt(cfg, 2), 6)
    sess.drain_serving()
    assert r.status is Status.FINISHED
    trained = jax.tree.map(np.asarray,
                           sess._train_execs[tid].store.model_params())
    ref = _sequential(cfg, trained, [(_prompt(cfg, 2), 6)])
    assert r.generated == ref[0]
    # plan meta records the tiering spec
    meta = sess._serve_meta(sess._jobs[sid], cold=True)
    assert meta["residency"] == "shard"
    assert meta["params_from"] == tid


def test_session_params_from_before_training_refused(dense):
    from repro.api.jobs import ServeJob, TrainJob
    from repro.api.session import Session
    from repro.core.sharp import HydraConfig
    cfg, _ = dense
    sess = Session(HydraConfig(n_devices=1, device_budget_bytes=10**9))
    tid = sess.submit(TrainJob(cfg, dataloader=_synth_loader(cfg),
                               epochs=1, steps_per_epoch=2, batch=2,
                               seq=16))
    sid = sess.submit(ServeJob(cfg, params_from=tid, max_seq=MAX_SEQ))
    with pytest.raises(RuntimeError, match="has not finished training"):
        sess.submit_request(sid, _prompt(cfg, 1), 4)


def test_session_validates_tiering_specs(dense):
    from repro.api.jobs import ServeJob
    from repro.api.session import Session
    from repro.core.sharp import HydraConfig
    cfg, _ = dense
    for bad, msg in ((dict(residency="shard"), "cold"),
                     (dict(residency="page"), "residency"),
                     (dict(tiered_kv=True), "paged"),
                     (dict(residency="model", hot_bytes=5), "hot_bytes"),
                     (dict(backend="paged", tiered_kv=True,
                           prefetch_ticks=0), "prefetch_ticks"),
                     (dict(params_from="train-99"), "params_from")):
        with pytest.raises(ValueError, match=msg):
            Session(HydraConfig()).submit(ServeJob(cfg, **bad))
