"""Copy-on-write prefix sharing in the paged decode backend.

The load-bearing properties:

* requests with a common block-aligned prompt prefix ALIAS the donor's
  physical blocks (refcounted in ``BlockPool``) instead of allocating and
  re-writing their own copies — admission charges only unshared blocks,
  so a common-prefix workload admits strictly more concurrency under the
  same byte budget than unshared paging;
* the first write past the shared extent triggers COPY-ON-WRITE: the
  boundary block is copied before the lane's decode row lands in it, so
  aliasing never perturbs the donor — outputs stay token-identical to
  unshared paged decode and to sequential per-request decode;
* the pool never double-frees: blocks freed only when the LAST reference
  drops, donor-first and sharer-first retirement orders both settle the
  engine-held orphan charge, and a drained engine returns every block to
  the free list with the ledger back at zero.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.spilling import DeviceMemory
from repro.models import api
from repro.serving import (BlockPool, InferenceEngine, PagedBackend,
                           blocks_for_rows)
from repro.training.train_loop import make_decode_step, make_prefill_into_cache

MAX_SEQ = 48
BS = 4


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("qwen3-0.6b", smoke=True)
    return cfg, api.init_params(cfg, jax.random.PRNGKey(0))


def _prompt(cfg, seed, plen):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (plen,), 0, cfg.vocab_size, jnp.int32))


@functools.lru_cache(maxsize=None)
def _ref_steps(cfg):
    return (jax.jit(make_prefill_into_cache(cfg)),
            jax.jit(make_decode_step(cfg)))


def _reference(cfg, params, prompt, gen, max_seq=MAX_SEQ):
    prefill, decode = _ref_steps(cfg)
    state = api.init_decode_state(cfg, 1, max_seq)
    logits, state = prefill(params, state, jnp.asarray(prompt)[None, :])
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out = [int(tok[0, 0])]
    for _ in range(gen - 1):
        tok, state = decode(params, state, tok)
        out.append(int(tok[0, 0]))
    return out


def _engine(cfg, params, *, share, capacity=4, **kw):
    return InferenceEngine(cfg, params, capacity=capacity, max_seq=MAX_SEQ,
                           paged=True, block_size=BS, prefix_share=share,
                           **kw)


# ---------------------------------------------------------------------------
# aliasing + refcounts
# ---------------------------------------------------------------------------

def test_common_prefix_aliases_blocks_and_stays_token_identical(dense):
    """Four requests sharing a 2-block prefix + distinct tails: the full
    prefix blocks are aliased (refcounted), only tails allocate, and every
    stream equals its solo reference."""
    cfg, params = dense
    prefix = _prompt(cfg, 600, 2 * BS)
    prompts = [np.concatenate([prefix, _prompt(cfg, 610 + i, BS)])
               for i in range(4)]
    shared = _engine(cfg, params, share=True)
    reqs = [shared.submit(p, 5) for p in prompts]
    # admitted together: the first request owns the prefix, the rest alias
    shared.step()
    be = shared.backend
    assert be.shared_block_hits == 3 * 2       # 3 sharers x 2 prefix blocks
    owner_prefix = be._lane_blocks[reqs[0].slot][:2]
    for r in reqs[1:]:
        assert be._lane_blocks[r.slot][:2] == owner_prefix
        assert r.shared_blocks == 2
    assert all(shared.pool.ref(b) == 4 for b in owner_prefix)
    shared.run()
    for p, r in zip(prompts, reqs):
        assert r.generated == _reference(cfg, params, p, 5)
    assert shared.pool.n_free == shared.pool.n_allocatable
    assert shared.budget.reserved_bytes == 0


def test_admission_charges_only_unshared_blocks(dense):
    cfg, params = dense
    prefix = _prompt(cfg, 620, 2 * BS)
    p1 = np.concatenate([prefix, _prompt(cfg, 621, BS)])
    p2 = np.concatenate([prefix, _prompt(cfg, 622, BS)])
    eng = _engine(cfg, params, share=True)
    r1 = eng.submit(p1, 4)
    r2 = eng.submit(p2, 4)
    eng.step()
    worst = blocks_for_rows(3 * BS + 4 - 1, BS)
    assert r1.reserved_blocks == worst          # owner pays in full
    assert r2.reserved_blocks == worst - 2      # sharer skips the 2 aliased
    eng.run()


def test_cow_fires_on_boundary_write_and_preserves_tokens(dense):
    """Identical prompts with a partial tail block: sharers alias the
    donor's boundary block too, and the first decode write copies it
    (COW) instead of clobbering rows the donor is still reading."""
    cfg, params = dense
    p = _prompt(cfg, 630, 2 * BS + 2)           # 2 full blocks + 2-row tail
    unshared = _engine(cfg, params, share=False)
    shared = _engine(cfg, params, share=True)
    ru = [unshared.submit(p, 6) for _ in range(3)]
    rs = [shared.submit(p, 6) for _ in range(3)]
    unshared.run()
    shared.run()
    assert shared.backend.cow_copies == 2       # one copy per sharer
    assert unshared.backend.cow_copies == 0
    for a, b in zip(ru, rs):
        assert a.generated == b.generated \
            == _reference(cfg, params, p, 6)
    # unshared wrote 3 copies of everything; shared allocated strictly less
    assert shared.pool.total_allocs < unshared.pool.total_allocs


def test_prefix_share_admits_more_under_fixed_budget(dense):
    """The acceptance bar: under ONE byte budget, a common-prefix workload
    admits strictly more concurrent requests with prefix sharing than
    paged admission alone."""
    cfg, params = dense
    n, tail_gen = 6, 4
    prefix = _prompt(cfg, 640, 8 * BS)          # 8 shared blocks
    prompts = [np.concatenate([prefix, _prompt(cfg, 650 + i, 2)])
               for i in range(n)]
    worst = blocks_for_rows(len(prompts[0]) + tail_gen - 1, BS)
    budget = 2 * worst * api.kv_block_bytes(cfg, BS)   # 2 unshared requests
    done = {}
    for share in (False, True):
        eng = _engine(cfg, params, share=share, capacity=n,
                      kv_budget_bytes=budget)
        reqs = [eng.submit(p, tail_gen) for p in prompts]
        eng.run()
        assert eng.budget.peak_bytes <= budget
        assert eng.pool.peak_bytes() <= budget
        done[share] = (eng.peak_concurrency,
                       [r.generated for r in reqs])
    assert done[True][0] > done[False][0], \
        f"sharing admitted {done[True][0]} <= unshared {done[False][0]}"
    assert done[True][1] == done[False][1]      # token-identical throughout


def test_late_arrival_aliases_running_donor(dense):
    """A request that arrives AFTER the donor started decoding still
    aliases the donor's prefix blocks, mid-flight, without perturbing
    either stream."""
    cfg, params = dense
    prefix = _prompt(cfg, 660, 2 * BS)
    pa = np.concatenate([prefix, _prompt(cfg, 661, 3)])
    pb = np.concatenate([prefix, _prompt(cfg, 662, 5)])
    eng = _engine(cfg, params, share=True)
    ra = eng.submit(pa, 8)
    eng.step()
    eng.step()                                  # donor mid-decode
    rb = eng.submit(pb, 6)
    eng.run()
    assert rb.shared_blocks == 2
    assert ra.generated == _reference(cfg, params, pa, 8)
    assert rb.generated == _reference(cfg, params, pb, 6)


# ---------------------------------------------------------------------------
# lifetime / accounting: never double-free, orphan charges settle
# ---------------------------------------------------------------------------

def test_donor_retires_first_orphan_charge_settles(dense):
    """Donor finishes while a sharer still reads its prefix blocks: the
    blocks stay alive (refcount), their bytes stay charged (engine-held
    orphan), and everything frees exactly once when the sharer retires."""
    cfg, params = dense
    ledger = DeviceMemory(-1, budget_bytes=10**9)
    eng = _engine(cfg, params, share=True, ledger=ledger)
    prefix = _prompt(cfg, 670, 2 * BS)
    donor = eng.submit(np.concatenate([prefix, _prompt(cfg, 671, 1)]), 2)
    sharer = eng.submit(np.concatenate([prefix, _prompt(cfg, 672, 1)]), 12)
    while not donor.done:
        eng.step()
        assert eng.pool.used_bytes() <= eng.budget.reserved_bytes
    eng.step()                                  # donor retires here
    assert donor.status.value == "finished" and not sharer.done
    prefix_blocks = eng.backend._lane_blocks[sharer.slot][:2]
    assert all(eng.pool.ref(b) == 1 for b in prefix_blocks)
    assert eng.backend._orphans == set(prefix_blocks)
    assert eng.pool.used_bytes() <= eng.budget.reserved_bytes
    eng.run()
    assert sharer.generated == _reference(
        cfg, params,
        np.concatenate([prefix, _prompt(cfg, 672, 1)]), 12)
    assert eng.pool.n_free == eng.pool.n_allocatable
    assert eng.budget.reserved_bytes == 0
    assert ledger.kv_reserved_bytes == 0
    assert not eng.backend._orphans


def test_orphaned_prefix_is_still_sharable(dense):
    """After the donor dies, a NEW arrival can still alias the orphaned
    prefix blocks (the index keeps them while references last)."""
    cfg, params = dense
    eng = _engine(cfg, params, share=True)
    prefix = _prompt(cfg, 680, 2 * BS)
    donor = eng.submit(np.concatenate([prefix, _prompt(cfg, 681, 1)]), 2)
    holder = eng.submit(np.concatenate([prefix, _prompt(cfg, 682, 1)]), 10)
    while not donor.done:
        eng.step()
    eng.step()                                  # donor gone, holder running
    late = eng.submit(np.concatenate([prefix, _prompt(cfg, 683, 2)]), 4)
    eng.run()
    assert late.shared_blocks == 2
    assert late.generated == _reference(
        cfg, params, np.concatenate([prefix, _prompt(cfg, 683, 2)]), 4)
    assert eng.pool.n_free == eng.pool.n_allocatable
    assert eng.budget.reserved_bytes == 0


def test_block_pool_refcounts_never_double_free(dense):
    cfg, _ = dense
    pool = BlockPool(cfg, n_blocks=4, block_size=BS)
    (a,) = pool.alloc(1)
    assert pool.ref(a) == 1
    pool.incref(a)
    assert pool.ref(a) == 2
    assert pool.decref(a) == 1                  # still held
    assert pool.n_free == 2                     # not freed yet
    assert pool.decref(a) == 0                  # last ref frees
    assert pool.n_free == 3
    with pytest.raises(RuntimeError, match="not allocated"):
        pool.decref(a)                          # double free
    with pytest.raises(RuntimeError, match="cannot alias"):
        pool.incref(a)                          # alias a free block
    with pytest.raises(RuntimeError, match="cannot alias"):
        pool.incref(BlockPool.GARBAGE)


def test_sharing_disabled_never_aliases(dense):
    cfg, params = dense
    eng = _engine(cfg, params, share=False)
    p = _prompt(cfg, 690, 2 * BS + 1)
    reqs = [eng.submit(p, 4) for _ in range(3)]
    eng.run()
    assert eng.backend.shared_block_hits == 0
    assert eng.backend.cow_copies == 0
    assert all(r.shared_blocks in (None, 0) for r in reqs)
    assert eng.summary()["prefix_share"] is False


def test_bucketed_prefill_composes_with_sharing(dense):
    """Length buckets pad the prefill; shared blocks are skipped by the
    page scatter, so bucketing + sharing still decode token-identically."""
    cfg, params = dense
    prefix = _prompt(cfg, 700, 2 * BS)
    prompts = [np.concatenate([prefix, _prompt(cfg, 701 + i, 1 + i)])
               for i in range(3)]
    eng = _engine(cfg, params, share=True, bucket_sizes=(4, 8, 16, 32))
    reqs = [eng.submit(p, 5) for p in prompts]
    eng.run()
    assert eng.backend.shared_block_hits > 0
    for p, r in zip(prompts, reqs):
        assert r.generated == _reference(cfg, params, p, 5)
    assert eng.pool.n_free == eng.pool.n_allocatable


def test_shared_summary_reports_reuse(dense):
    cfg, params = dense
    eng = _engine(cfg, params, share=True)
    p = _prompt(cfg, 710, 3 * BS)
    reqs = [eng.submit(p, 3) for _ in range(4)]
    eng.run()
    s = eng.summary()
    assert s["prefix_share"] and s["shared_block_hits"] == 3 * 3
    # block-reuse ratio: logical blocks referenced / physical allocated
    ratio = (s["shared_block_hits"] + s["kv_block_allocs"]) \
        / s["kv_block_allocs"]
    assert ratio > 1
    for r in reqs:
        assert r.generated == _reference(cfg, params, p, 3)
        assert r.metrics()["kv_shared_blocks"] == r.shared_blocks
