"""Double buffering (paper §4.6): transfer-hiding accounting and the
configs' structural invariants."""

import jax
import numpy as np
import pytest

from conftest import make_loader
from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.core import HydraConfig, ModelOrchestrator, ModelTask


def _run(db: bool, link_bw: float, fixed_unit_runtime=None):
    cfg = get_config("qwen3-0.6b", smoke=True)
    tasks = [ModelTask(cfg, make_loader(cfg, seed=i), lr=1e-3, epochs=1,
                       steps_per_epoch=2, seed=i, batch=2, seq=64)
             for i in range(4)]
    hc = HydraConfig(n_devices=2, device_budget_bytes=18 * 10**6,
                     enable_double_buffer=db, link_bw=link_bw,
                     fixed_unit_runtime=fixed_unit_runtime)
    return ModelOrchestrator(tasks, hc).train_models()


def test_double_buffering_reduces_makespan_on_slow_link():
    # pinned unit runtimes: the makespan gap is then a deterministic
    # property of the transfer-hiding model, not of pilot-measurement noise
    # (measured runtimes flake on a loaded shared CPU)
    with_db = _run(True, link_bw=5e8, fixed_unit_runtime=5e-3)
    without = _run(False, link_bw=5e8, fixed_unit_runtime=5e-3)
    assert with_db.makespan < without.makespan
    assert with_db.hidden_transfer_time > 0


def test_db_irrelevant_on_infinite_link():
    # with free transfers neither mode exposes any transfer time, and with
    # pinned unit runtimes both modes schedule identically
    fast_db = _run(True, link_bw=1e15, fixed_unit_runtime=5e-3)
    fast_no = _run(False, link_bw=1e15, fixed_unit_runtime=5e-3)
    assert fast_db.exposed_transfer_time < 1e-6
    assert fast_no.exposed_transfer_time < 1e-6
    # identical up to the O(bytes/1e15 s) residual modeled transfer time
    assert abs(fast_db.makespan - fast_no.makespan) / fast_no.makespan < 1e-4


# ---------------------------------------------------------------------------
# config invariants (assignment sanity)
# ---------------------------------------------------------------------------

EXPECTED = {
    "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
    "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
    "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
    "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
    "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
    "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    "yi-34b": (60, 7168, 56, 8, 20480, 64000),
    "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
    "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_assigned_config_dims(arch):
    cfg = get_config(arch)
    L, d, H, kv, ff, V = EXPECTED[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab_size) == (L, d, H, kv, ff, V)
    assert cfg.source, "every config must cite its source"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_configs_reduced(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    if cfg.family == "moe":
        assert cfg.n_experts <= 4


def test_input_shapes_assignment():
    s = INPUT_SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) \
        == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) \
        == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) \
        == (524288, 1)


def test_moe_extra_params():
    mix = get_config("mixtral-8x22b")
    dbrx = get_config("dbrx-132b")
    assert (mix.n_experts, mix.top_k, mix.window) == (8, 2, 4096)
    assert (dbrx.n_experts, dbrx.top_k) == (16, 4)
    assert get_config("zamba2-1.2b").ssm_state == 64
