"""Attention + recurrence math invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as nn
from repro.models import ssm


def _qkv(b, sq, sk, nh, nkv, hd, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (b, sq, nh, hd)),
            jax.random.normal(ks[1], (b, sk, nkv, hd)),
            jax.random.normal(ks[2], (b, sk, nkv, hd)))


def test_sdpa_gqa_equals_repeated_kv():
    q, k, v = _qkv(2, 32, 32, 8, 2, 16)
    out = nn.sdpa(q, k, v, causal=True)
    k_rep = jnp.repeat(k, 4, axis=2)
    v_rep = jnp.repeat(v, 4, axis=2)
    out_rep = nn.sdpa(q, k_rep, v_rep, causal=True)
    np.testing.assert_allclose(out, out_rep, rtol=1e-5, atol=1e-5)


def test_sdpa_chunked_matches_dense():
    q, k, v = _qkv(1, 2048, 2048, 2, 2, 16)
    dense = nn._sdpa_dense(
        q.reshape(1, 2048, 2, 1, 16), k, v, 1 / 4.0,
        jnp.arange(2048), jnp.arange(2048), True, None
    ).reshape(1, 2048, 2, 16)
    # force the chunked path
    old = nn._SDPA_CHUNK_ELEMS
    nn._SDPA_CHUNK_ELEMS = 1024 * 1024
    try:
        chunked = nn.sdpa(q, k, v, causal=True)
    finally:
        nn._SDPA_CHUNK_ELEMS = old
    np.testing.assert_allclose(chunked, dense.astype(chunked.dtype),
                               rtol=1e-5, atol=1e-5)


def test_sliding_window_masks_far_tokens():
    q, k, v = _qkv(1, 64, 64, 2, 2, 16)
    w = nn.sdpa(q, k, v, causal=True, window=8)
    # distant value perturbation must not affect outputs beyond the window
    v2 = v.at[:, 0].add(100.0)
    w2 = nn.sdpa(q, k, v2, causal=True, window=8)
    np.testing.assert_allclose(w[:, 16:], w2[:, 16:], rtol=1e-5, atol=1e-5)
    assert not np.allclose(w[:, :8], w2[:, :8])


def test_kv_cache_decode_equals_full_attention():
    cfg = _cfg()
    params = nn.init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model))
    full, _ = nn.attention(params, x, cfg, causal=True)
    cache = nn.init_kv_cache(cfg, 2, 16, n_layers=1, dtype=jnp.float32)
    cache = {"k": cache["k"][0], "v": cache["v"][0], "index": cache["index"]}
    outs = []
    for i in range(12):
        pos = jnp.full((2, 1), i, jnp.int32)
        o, cache = nn.attention(params, x[:, i:i + 1], cfg,
                                positions=pos, causal=True, kv_cache=cache)
        outs.append(o[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(dec, full, rtol=1e-4, atol=1e-4)


def _cfg():
    from repro.configs.base import ArchConfig
    return ArchConfig(name="t", family="dense", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)


def test_rope_relative_property():
    """RoPE: q·k depends only on relative positions."""
    hd = 32
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))
    def dot_at(pq, pk):
        qr = nn.apply_rope(q, jnp.array([[pq]]))
        kr = nn.apply_rope(k, jnp.array([[pk]]))
        return float(jnp.sum(qr * kr))
    assert dot_at(5, 3) == pytest.approx(dot_at(105, 103), rel=1e-4)
    assert dot_at(5, 3) != pytest.approx(dot_at(5, 4), rel=1e-3)


def test_mamba2_forward_matches_decode_steps():
    from repro.configs import get_config
    cfg = get_config("zamba2-1.2b", smoke=True)
    params = ssm.init_mamba2(jax.random.PRNGKey(0), cfg)
    b, s = 2, 64
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.5
    y_par = ssm.mamba2_forward(params, x, cfg)
    state = ssm.init_mamba2_state(cfg, b)
    ys = []
    for i in range(s):
        y, state = ssm.mamba2_step(params, x[:, i], state, cfg)
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_par, y_seq, rtol=2e-3, atol=2e-3)


def test_mlstm_forward_matches_decode_steps():
    from repro.configs import get_config
    cfg = get_config("xlstm-350m", smoke=True)
    params = ssm.init_mlstm(jax.random.PRNGKey(0), cfg)
    b, s = 1, 64
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.5
    y_par = ssm.mlstm_forward(params, x, cfg)
    state = ssm.init_mlstm_state(cfg, b)
    ys = []
    for i in range(s):
        y, state = ssm.mlstm_step(params, x[:, i], state, cfg)
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_par, y_seq, rtol=2e-3, atol=2e-3)


def test_slstm_forward_matches_decode_steps():
    from repro.configs import get_config
    cfg = get_config("xlstm-350m", smoke=True)
    params = ssm.init_slstm(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.5
    y_par = ssm.slstm_forward(params, x, cfg)
    carry = ssm.init_slstm_state(cfg, b)
    ys = []
    for i in range(s):
        y, carry = ssm.slstm_step(params, x[:, i], carry, cfg)
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_par, y_seq, rtol=2e-3, atol=2e-3)


def test_causal_conv_matches_steps():
    b, s, c, k = 2, 10, 6, 4
    x = jax.random.normal(jax.random.PRNGKey(0), (b, s, c))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, c)) * 0.3
    bias = jnp.zeros((c,))
    y_par = ssm.causal_conv1d(x, w, bias)
    state = jnp.zeros((b, k - 1, c))
    ys = []
    for i in range(s):
        y, state = ssm.causal_conv1d_step(state, x[:, i], w, bias)
        ys.append(y)
    np.testing.assert_allclose(y_par, jnp.stack(ys, axis=1),
                               rtol=1e-5, atol=1e-5)
