"""Sharding-rule unit tests (pure functions; mesh mocked via .shape dict)."""

from types import SimpleNamespace

import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import specs as sh


def mesh(shape: dict, axes=None):
    return SimpleNamespace(shape=shape,
                           axis_names=tuple(axes or shape.keys()))


SINGLE = mesh({"data": 16, "model": 16})
MULTI = mesh({"pod": 2, "data": 16, "model": 16})


def test_spec_fits_divisibility():
    assert sh.spec_fits(SINGLE, P("data", None), (32, 7))
    assert not sh.spec_fits(SINGLE, P("data", None), (24, 7))
    assert sh.spec_fits(SINGLE, P(("data", "model"), None), (512, 3))
    assert not sh.spec_fits(SINGLE, P(("data", "model"), None), (128, 3))


def test_pick_spec_falls_back_in_order():
    cands = [P("model", None), P(None, "model"), P(None, None)]
    assert sh.pick_spec(SINGLE, cands, (32, 64)) == P("model", None)
    assert sh.pick_spec(SINGLE, cands, (7, 64)) == P(None, "model")
    assert sh.pick_spec(SINGLE, cands, (7, 9)) == P(None, None)


def test_param_candidates_projection_rules():
    c = sh._param_candidates("layers/attn/wq", 3, SINGLE)
    assert c[0] == P(None, "data", "model")      # stacked FSDP+TP
    c = sh._param_candidates("attn/wo", 2, SINGLE)
    assert c[0] == P("model", "data")
    c = sh._param_candidates("layers/moe/w_gate", 4, SINGLE)
    assert c[0] == P(None, "model", "data", None)   # expert parallel


def test_param_candidates_multipod_uses_pod_axis():
    c = sh._param_candidates("layers/attn/wq", 3, MULTI)
    assert c[0] == P(None, ("pod", "data"), "model")


def test_embed_table_rules():
    c = sh._param_candidates("embed/table", 2, SINGLE)
    assert c[0] == P("model", "data")
    # whisper vocab 51865 is odd -> must fall through to a fitting candidate
    got = sh.pick_spec(SINGLE, c, (51865, 1024))
    assert got in (P(None, "data"), P(None, None))


def test_norm_scales_replicate():
    c = sh._param_candidates("layers/attn_norm/scale", 2, SINGLE)
    assert c == [P(None, None)]


def test_batch_axes():
    assert sh.batch_axes(SINGLE) == "data"
    assert sh.batch_axes(MULTI) == ("pod", "data")
