"""Sharded-LRTF + the scheduling simulator (paper §4.7, Fig 7)."""

import random

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import scheduler as sched


def test_lrtf_picks_longest():
    ms = [sched.ModelProgress(i, e, 10, 5, 1.0, 0.5)
          for i, e in enumerate([1, 3, 2])]
    assert sched.sharded_lrtf(ms) == 1


def test_remaining_time_formula():
    # Algorithm 2: ((e-1)*b + ce - 1) * t + cm
    m = sched.ModelProgress(0, remaining_epochs=3, minibatches_per_epoch=10,
                            remaining_in_epoch=4, minibatch_time=2.0,
                            remaining_in_minibatch=0.5)
    assert m.remaining_time() == ((3 - 1) * 10 + 4 - 1) * 2.0 + 0.5


def test_greedy_sim_single_model_single_device():
    times = [[1.0, 2.0, 3.0]]
    assert sched.greedy_list_makespan(times, 1) == pytest.approx(6.0)
    # extra devices cannot help a single sequential chain
    assert sched.greedy_list_makespan(times, 4) == pytest.approx(6.0)


def test_greedy_sim_perfect_interleave():
    # 2 identical models, 2 devices: perfect task parallelism
    times = [[1.0] * 4, [1.0] * 4]
    assert sched.greedy_list_makespan(times, 2) == pytest.approx(4.0)


def test_lrtf_beats_srtf_on_heterogeneous():
    rng = random.Random(0)
    wins = 0
    for trial in range(10):
        times = [[rng.uniform(0.5, 2.0) for _ in range(rng.randint(2, 12))]
                 for _ in range(6)]
        lrtf = sched.greedy_list_makespan(times, 3, sched.sharded_lrtf)
        srtf = sched.greedy_list_makespan(times, 3, sched.sharded_srtf)
        if lrtf <= srtf + 1e-9:
            wins += 1
    assert wins >= 7   # LRTF should (almost) never lose to anti-LRTF


def test_lrtf_near_optimal_small():
    rng = random.Random(1)
    for trial in range(5):
        times = [[rng.uniform(0.5, 2.0) for _ in range(rng.randint(1, 4))]
                 for _ in range(3)]
        opt = sched.optimal_makespan(times, 2)
        lrtf = sched.greedy_list_makespan(times, 2, sched.sharded_lrtf)
        assert lrtf >= opt - 1e-9          # optimality of B&B incumbent
        assert lrtf <= opt * 1.6 + 1e-9    # LRTF near-optimal (paper Fig 7)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.lists(st.floats(0.1, 5.0), min_size=1, max_size=6),
                min_size=1, max_size=5),
       st.integers(1, 4))
def test_sim_invariants(times, n_devices):
    """Makespan >= max-chain and >= total-work/devices lower bounds, and
    the schedule always terminates covering every unit."""
    mk = sched.greedy_list_makespan(times, n_devices, sched.sharded_lrtf)
    chain_lb = max(sum(t) for t in times)
    work_lb = sum(sum(t) for t in times) / n_devices
    assert mk >= chain_lb - 1e-6
    assert mk >= work_lb - 1e-6
    # and is attainable: never worse than running everything serially
    assert mk <= sum(sum(t) for t in times) + 1e-6


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_random_scheduler_never_beats_lower_bounds(seed):
    rng = random.Random(seed)
    times = [[rng.uniform(0.1, 2.0) for _ in range(rng.randint(1, 5))]
             for _ in range(4)]
    r = sched.greedy_list_makespan(
        times, 2, sched.make_random_scheduler(seed))
    assert r >= max(sum(t) for t in times) - 1e-6
