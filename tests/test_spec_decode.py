"""Speculative decoding (``serving/backends.SpecDecodeBackend``).

The load-bearing property: greedy-exact acceptance makes spec decode
**token-identical** to target-only greedy decode — for ANY draft model
(zero-accept random drafts through full-accept self-drafts), on BOTH
inner backends, across staggered joins — with KV state rolled back past
the accept point (slot: per-lane index rewind; paged: lane lengths +
tail-block rewind with no leaked blocks and the ledger back at baseline).
"""

import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.spilling import DeviceMemory
from repro.models import api
from repro.models.registry import spec as family_spec
from repro.serving import (CapabilityFallbackWarning, InferenceEngine,
                           SpecDecodeBackend)

MAX_SEQ = 48
CAPACITY = 4


@functools.lru_cache(maxsize=None)
def _dense():
    cfg = get_config("qwen3-0.6b", smoke=True)
    return cfg, api.init_params(cfg, jax.random.PRNGKey(0))


@functools.lru_cache(maxsize=None)
def _drafts():
    """Draft param sets: 'self' accepts every draft (greedy determinism),
    fresh random inits accept essentially none."""
    cfg, params = _dense()
    return {"self": params,
            7: api.init_params(cfg, jax.random.PRNGKey(7)),
            13: api.init_params(cfg, jax.random.PRNGKey(13))}


@pytest.fixture(scope="module")
def dense():
    return _dense()


@pytest.fixture(scope="module")
def drafts(dense):
    return _drafts()


def _workload(cfg, seed, n=4):
    rng = np.random.RandomState(seed)
    prompts = [np.asarray(jax.random.randint(
        jax.random.PRNGKey(100 + seed * 16 + i),
        (int(rng.randint(3, 12)),), 0, cfg.vocab_size, jnp.int32))
        for i in range(n)]
    gens = [int(rng.randint(2, 12)) for _ in range(n)]
    return prompts, gens


def _run(cfg, params, prompts, gens, **kw):
    eng = InferenceEngine(cfg, params, capacity=CAPACITY, max_seq=MAX_SEQ,
                          **kw)
    reqs = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    eng.run()
    return eng, [r.generated for r in reqs]


@functools.lru_cache(maxsize=None)
def _baseline_cache():
    return {}


def _baseline(seed):
    cache = _baseline_cache()
    if seed not in cache:
        cfg, params = _dense()
        prompts, gens = _workload(cfg, seed)
        _, toks = _run(cfg, params, prompts, gens)
        cache[seed] = toks
    return cache[seed]


# ---------------------------------------------------------------------------
# the property: token identity for random draft/target pairs, both inners
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(inner=st.sampled_from(["slot", "paged"]),
       draft=st.sampled_from(["self", 7, 13]),
       draft_k=st.sampled_from([1, 3]),
       seed=st.integers(min_value=0, max_value=2))
def test_spec_token_identical_to_plain_greedy(inner, draft, draft_k, seed):
    cfg, params = _dense()
    prompts, gens = _workload(cfg, seed)
    eng, toks = _run(cfg, params, prompts, gens, backend="spec",
                     spec_inner=inner, draft_cfg=cfg,
                     draft_params=_drafts()[draft], draft_k=draft_k,
                     block_size=4)
    assert toks == _baseline(seed), \
        f"spec({inner}, draft={draft}, k={draft_k}) diverged"
    s = eng.summary()
    # every verify forward yields between 1 and k tokens
    assert s["target_steps"] <= s["spec_tokens"] \
        <= s["target_steps"] * draft_k
    if inner == "paged":
        # rollback freed every speculative tail block; nothing leaked
        assert eng.backend.inner.pool.n_used == 0
        assert eng.backend.inner.ledger.kv_reserved_bytes == 0


def test_full_accept_rounds_save_target_steps(dense):
    """Self-draft = the full-accept extreme: every round accepts all k
    drafts, so target verify steps are strictly fewer than tokens."""
    cfg, params = dense
    prompts, gens = _workload(cfg, 3)
    for inner in ("slot", "paged"):
        eng, toks = _run(cfg, params, prompts, gens, backend="spec",
                         spec_inner=inner, draft_cfg=cfg,
                         draft_params=params, draft_k=4, block_size=4)
        assert toks == _baseline(3)
        s = eng.summary()
        assert s["draft_accept_rate"] == 1.0
        assert s["target_steps"] < s["spec_tokens"]
        assert s["accepted_tokens_per_target_step"] > 1


def test_zero_accept_rounds_still_exact(dense, drafts):
    """A random draft agrees with the target essentially never: every
    round falls back to the target's own correction token — one token per
    verify step, outputs still exact."""
    cfg, params = dense
    prompts, gens = _workload(cfg, 1)
    eng, toks = _run(cfg, params, prompts, gens, backend="spec",
                     spec_inner="paged", draft_cfg=cfg,
                     draft_params=drafts[13], draft_k=3, block_size=4)
    assert toks == _baseline(1)
    s = eng.summary()
    assert s["draft_accept_rate"] < 1.0
    # zero-accept rounds emit exactly one (correction) token each
    assert s["spec_tokens"] >= s["target_steps"]


def test_paged_verify_headroom_at_max_seq(dense):
    """A request whose decode extent exactly fills max_seq: the k verify
    rows land past it, in the reservation's headroom — allocation must
    never fail and the tail blocks must rewind."""
    cfg, params = dense
    plen = 8
    gen = MAX_SEQ - plen + 1        # prompt + gen - 1 == MAX_SEQ
    prompt = np.asarray(jax.random.randint(
        jax.random.PRNGKey(5), (plen,), 0, cfg.vocab_size, jnp.int32))
    _, base = _run(cfg, params, [prompt], [gen])
    eng, toks = _run(cfg, params, [prompt], [gen], backend="spec",
                     spec_inner="paged", draft_cfg=cfg,
                     draft_params=params, draft_k=4, block_size=4)
    assert toks == base
    assert eng.backend.inner.pool.n_used == 0
    assert eng.backend.inner.ledger.kv_reserved_bytes == 0


def test_staggered_joins_do_not_perturb_spec_rounds(dense, drafts):
    """Requests joining mid-flight enter rounds whose other lanes hold
    buffered tokens; the masked-lane machinery must keep everyone exact."""
    cfg, params = dense
    prompts, gens = _workload(cfg, 2, n=6)
    base = []
    for p, g in zip(prompts, gens):
        _, t = _run(cfg, params, [p], [g])
        base.append(t[0])
    eng = InferenceEngine(cfg, params, capacity=3, max_seq=MAX_SEQ,
                          backend="spec", spec_inner="paged", draft_cfg=cfg,
                          draft_params=drafts[7], draft_k=3, block_size=4)
    reqs = [eng.submit(prompts[0], gens[0]), eng.submit(prompts[1], gens[1])]
    n = 2
    while eng.has_work() or n < len(prompts):
        if n < len(prompts):
            reqs.append(eng.submit(prompts[n], gens[n]))
            n += 1
        eng.step()
    eng.run()
    assert [r.generated for r in reqs] == base


def test_eos_mid_buffer_stops_early_and_exact(dense):
    cfg, params = dense
    prompts, gens = _workload(cfg, 0)
    base = _baseline(0)[0]
    eos = base[1]                   # stop at this token's first occurrence
    eng = InferenceEngine(cfg, params, capacity=CAPACITY, max_seq=MAX_SEQ,
                          backend="spec", draft_cfg=cfg, draft_params=params,
                          draft_k=4)
    req = eng.submit(prompts[0], gens[0], eos_id=eos)
    eng.run()
    assert req.generated == base[:base.index(eos) + 1]


# ---------------------------------------------------------------------------
# ledger accounting: draft + target + headroom on ONE shared budget
# ---------------------------------------------------------------------------

def test_shared_ledger_charges_draft_and_target(dense):
    cfg, params = dense
    ledger = DeviceMemory(0, 64 * 2**20)
    eng = InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                          backend="spec", spec_inner="paged", draft_cfg=cfg,
                          draft_params=params, draft_k=2, block_size=4,
                          ledger=ledger)
    prompt = np.asarray(jax.random.randint(
        jax.random.PRNGKey(2), (6,), 0, cfg.vocab_size, jnp.int32))
    req = eng.submit(prompt, 4)
    eng.step()
    draft_bytes = eng.backend.draft_slot_bytes
    # mid-flight: the ledger holds the draft state AND the target blocks
    assert ledger.kv_reserved_bytes >= draft_bytes \
        + req.reserved_blocks * eng.backend.inner.pool.block_bytes
    eng.run()
    assert ledger.kv_reserved_bytes == 0


def test_private_paged_budget_charges_draft_state(dense):
    """Without a shared session ledger, the draft state still reserves
    against the paged inner's private ledger — a user sizing
    kv_budget_bytes bounds draft + target together."""
    cfg, params = dense
    eng = InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                          backend="spec", spec_inner="paged", draft_cfg=cfg,
                          draft_params=params, draft_k=2, block_size=4,
                          kv_budget_bytes=8 * 2**20)
    ledger = eng.backend.inner.ledger
    prompt = np.asarray(jax.random.randint(
        jax.random.PRNGKey(4), (6,), 0, cfg.vocab_size, jnp.int32))
    req = eng.submit(prompt, 4)
    eng.step()
    assert ledger.kv_reserved_bytes >= eng.backend.draft_slot_bytes \
        + req.reserved_blocks * eng.backend.inner.pool.block_bytes
    eng.run()
    assert ledger.kv_reserved_bytes == 0


def test_never_admissible_spec_request_rejected_at_submit(dense):
    cfg, params = dense
    spec = family_spec(cfg)
    # fits ONE target slot (incl. headroom) but not target + draft state:
    # the spec-level combined admission check must reject up front
    slot_bytes = spec.decode_state_bytes(cfg, 1, MAX_SEQ + 2)
    tight = DeviceMemory(0, slot_bytes + 1)
    eng = InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                          backend="spec", draft_cfg=cfg, draft_params=params,
                          draft_k=2, ledger=tight)
    prompt = np.asarray([1, 2, 3], np.int32)
    with pytest.raises(ValueError, match="never admit"):
        eng.submit(prompt, 4)


# ---------------------------------------------------------------------------
# capability gates + construction validation
# ---------------------------------------------------------------------------

def test_spec_falls_back_on_undraftable_family():
    cfg = get_config("xlstm-350m", smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    dense_cfg = get_config("qwen3-0.6b", smoke=True)
    with pytest.warns(CapabilityFallbackWarning, match="spec_draftable"):
        eng = InferenceEngine(cfg, params, capacity=2, max_seq=32,
                              backend="spec", draft_cfg=dense_cfg,
                              draft_params=None)
    assert eng.backend.name == "slot"
    assert eng.requested_backend == "spec"


def test_spec_backend_validates_draft(dense):
    cfg, params = dense
    with pytest.raises(ValueError, match="draft member model"):
        SpecDecodeBackend(cfg, 2, 32)
    ssm_cfg = get_config("xlstm-350m", smoke=True)
    with pytest.raises(ValueError, match="rolled back"):
        SpecDecodeBackend(cfg, 2, 32, draft_cfg=ssm_cfg, draft_params={})
    with pytest.raises(ValueError, match="draft_k"):
        SpecDecodeBackend(cfg, 2, 32, draft_cfg=cfg, draft_params=params,
                          draft_k=0)


def test_verify_step_gated_on_capability():
    ssm_cfg = get_config("xlstm-350m", smoke=True)
    with pytest.raises(ValueError, match="spec_draftable|rolled back"):
        api.verify_step(ssm_cfg, {}, {}, np.zeros((1, 2), np.int32))
    assert "spec_draftable" in family_spec(ssm_cfg).capabilities()
    assert family_spec("dense").spec_draftable


# ---------------------------------------------------------------------------
# session surface
# ---------------------------------------------------------------------------

def test_session_spec_job_end_to_end(dense):
    from repro.api import HydraConfig, ServeJob, Session
    cfg, params = dense
    session = Session(HydraConfig(n_devices=1,
                                  device_budget_bytes=96 * 2**20))
    jid = session.submit(ServeJob(cfg, params=params, backend="spec",
                                  draft_model=cfg, draft_params=params,
                                  draft_k=3, spec_inner="paged",
                                  capacity=3, max_seq=MAX_SEQ,
                                  block_size=4))
    plan = session.plan()
    meta = plan.job(jid).meta
    assert meta["backend"] == "spec"
    assert meta["spec_inner"] == "paged"
    assert meta["draft_model"] == cfg.name
    assert meta["draft_k"] == 3
    assert meta["draft_state_bytes"] > 0 and meta["shared_ledger"]
    prompt = np.asarray(jax.random.randint(
        jax.random.PRNGKey(3), (6,), 0, cfg.vocab_size, jnp.int32))
    session.submit_request(jid, prompt, 5)
    assert session.poll(jid)["backend"] == "spec"
    assert session.poll(jid)["capabilities"]["spec_draftable"]
    report = session.run(plan)
    rec = report.serve[jid]
    assert rec["backend"] == "spec" and rec["inner_backend"] == "paged"
    assert rec["n_completed"] == 1
    assert rec["accepted_tokens_per_target_step"] >= 1
    # the session ledger settled once the request retired
    assert session.devices[0].kv_reserved_bytes == 0


# ---------------------------------------------------------------------------
# fused multi-query paged-verify kernel (kernels/paged_verify.py)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(draft=st.sampled_from(["self", 7, 13]),
       draft_k=st.sampled_from([1, 3]),
       seed=st.integers(min_value=0, max_value=2))
def test_fused_verify_token_identical_to_gathered(draft, draft_k, seed):
    """Property: the fused multi-query verify kernel (all k draft rows
    scored through block tables in ONE launch) is token-identical to the
    gathered-jnp verify path — across accept-rate extremes and k."""
    cfg, params = _dense()
    prompts, gens = _workload(cfg, seed)
    _, toks = _run(cfg, params, prompts, gens, backend="spec",
                   spec_inner="paged", draft_cfg=cfg,
                   draft_params=_drafts()[draft], draft_k=draft_k,
                   block_size=4, verify_impl="pallas_interpret")
    assert toks == _baseline(seed), \
        f"fused verify(draft={draft}, k={draft_k}) diverged from greedy"


def test_fused_verify_staggered_joins(dense, drafts):
    """Mid-flight joins under the fused verify kernel: fresh lanes enter
    rounds through the same batched launch as buffered lanes."""
    cfg, params = dense
    prompts, gens = _workload(cfg, 4, n=5)
    base = []
    for p, g in zip(prompts, gens):
        _, t = _run(cfg, params, [p], [g])
        base.append(t[0])
    eng = InferenceEngine(cfg, params, capacity=2, max_seq=MAX_SEQ,
                          backend="spec", spec_inner="paged", draft_cfg=cfg,
                          draft_params=drafts[7], draft_k=3, block_size=4,
                          verify_impl="pallas_interpret")
    reqs = [eng.submit(prompts[0], gens[0])]
    n = 1
    while eng.has_work() or n < len(prompts):
        if n < len(prompts):
            reqs.append(eng.submit(prompts[n], gens[n]))
            n += 1
        eng.step()
    eng.run()
    assert [r.generated for r in reqs] == base
    assert eng.backend.verify_impl == "pallas_interpret"
    assert eng.backend.inner.pool.n_used == 0


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10**6), kk=st.sampled_from([1, 3]))
def test_fused_verify_on_preemption_shaped_tables(seed, kk):
    """Preempt/resume leaves lanes with interleaved, non-monotone block
    tables (resumed snapshots re-attach wherever free blocks landed) and
    aliased prefix blocks (COW sharing).  The kernel must match the
    gathered oracle on exactly that table-state space: scrambled physical
    order, shared blocks across lanes, rewound lengths, garbage tails."""
    from repro.kernels import ops, ref
    n, nkv, groups, hd, bs, B = 3, 2, 2, 32, 4, 4
    rng = np.random.default_rng(seed)
    P = n * B + 2
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    kp = jax.random.normal(k1, (P, bs, nkv, hd), jnp.float32)
    vp = jax.random.normal(k2, (P, bs, nkv, hd), jnp.float32)
    q = jax.random.normal(k3, (n, kk, nkv * groups, hd), jnp.float32)
    # scrambled physical order per lane (resume re-attach)
    tables = (rng.permutation(P - 1)[: n * B] + 1).reshape(n, B)
    # lanes 1 and 2 alias lane 0's first block (shared prompt prefix)
    tables[1, 0] = tables[2, 0] = tables[0, 0]
    # lane 2's tail points at the garbage block (short, rewound lane)
    tables[2, 2:] = 0
    tables = jnp.asarray(tables, jnp.int32)
    # rewound lengths: mid-block accept points, one lane at a boundary
    lengths = jnp.asarray(
        [int(rng.integers(0, B * bs - kk + 1)), bs, 2], jnp.int32)
    out = ops.paged_verify(q, kp, vp, tables, lengths,
                           impl="pallas_interpret")
    exp = ref.paged_verify_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


def test_verify_impl_rejected_on_slot_inner(dense):
    cfg, params = dense
    with pytest.raises(ValueError, match="verify_impl"):
        SpecDecodeBackend(cfg, 2, 32, draft_cfg=cfg, draft_params=params,
                          inner="slot", verify_impl="pallas")
    from repro.api import ServeJob
    with pytest.raises(ValueError, match="verify_impl"):
        ServeJob(cfg, backend="paged",
                 verify_impl="pallas").validate_tiering()


def test_serve_job_spec_validation(dense):
    from repro.api import ServeJob
    cfg, _ = dense
    with pytest.raises(ValueError, match="draft member model"):
        ServeJob(cfg, backend="spec").requested_backend()
    # a bad DRAFT has no fallback: it must fail at submit/plan time, not
    # mid-run in the backend constructor
    ssm_cfg = get_config("xlstm-350m", smoke=True)
    with pytest.raises(ValueError, match="spec_draftable|rolled back"):
        ServeJob(cfg, backend="spec",
                 draft_model=ssm_cfg).requested_backend()
    with pytest.raises(ValueError, match="spec_inner"):
        ServeJob(cfg, backend="spec", draft_model=cfg,
                 spec_inner="bogus").resolved_spec_inner()
    job = ServeJob(cfg, backend="spec", draft_model=cfg, spec_inner="paged")
    assert job.effective_backend() == "spec"
    assert job.effective_spec_inner() == "paged"
    ssm = get_config("xlstm-350m", smoke=True)
    job = ServeJob(ssm, backend="spec", draft_model=cfg)
    assert job.effective_backend() == "slot"
    assert job.effective_spec_inner() is None
