"""End-to-end Hydra: multi-model SHARP training must reproduce sequential
training losses exactly (the paper's 'no effect on accuracy' desideratum),
across families; ablation modes must run and order correctly."""

import jax
import numpy as np
import pytest

from conftest import make_loader
from repro.configs import get_config
from repro.core import (HydraConfig, ModelOrchestrator, ModelTask,
                        train_sequential_reference)

BUDGET = {"qwen3-0.6b": 18, "mixtral-8x22b": 45, "zamba2-1.2b": 30,
          "whisper-medium": 40, "xlstm-350m": 60, "bert-large-1b": 6}


def _tasks(arch, n=2, steps=2):
    cfg = get_config(arch, smoke=True)
    return [ModelTask(cfg, make_loader(cfg, seed=i), lr=1e-3, epochs=1,
                      steps_per_epoch=steps, seed=i, batch=2, seq=64)
            for i in range(n)]


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mixtral-8x22b",
                                  "zamba2-1.2b", "whisper-medium"])
def test_hydra_matches_sequential(arch):
    tasks = _tasks(arch)
    hc = HydraConfig(n_devices=2,
                     device_budget_bytes=BUDGET[arch] * 10**6)
    orch = ModelOrchestrator(tasks, hc)
    report = orch.train_models()
    for i in range(len(tasks)):
        _, ref = train_sequential_reference(_tasks(arch)[i])
        np.testing.assert_allclose(ref, report.losses[i],
                                   rtol=3e-4, atol=3e-4)


def test_multiple_shards_per_model():
    tasks = _tasks("qwen3-0.6b", n=3, steps=3)
    hc = HydraConfig(n_devices=2, device_budget_bytes=18 * 10**6)
    orch = ModelOrchestrator(tasks, hc)
    assert all(len(m.partition.shards) >= 2 for m in orch.models)
    report = orch.train_models()
    assert report.units_executed == 3 * 3 * 2 * len(
        orch.models[0].partition.shards)
    assert report.makespan > 0
    for i in range(3):
        _, ref = train_sequential_reference(_tasks("qwen3-0.6b", 3, 3)[i])
        np.testing.assert_allclose(ref, report.losses[i],
                                   rtol=3e-4, atol=3e-4)


def test_sharp_beats_spilling_only():
    """Paper Table 3 ordering: SHARP >> spilling-only on makespan & util."""
    def run(sharp, db):
        tasks = _tasks("qwen3-0.6b", n=4, steps=2)
        hc = HydraConfig(n_devices=2, device_budget_bytes=18 * 10**6,
                         enable_sharp=sharp, enable_double_buffer=db,
                         link_bw=1e9)   # slow link makes transfers matter
        return ModelOrchestrator(tasks, hc).train_models()

    full = run(True, True)
    no_db = run(True, False)
    no_sharp = run(False, False)
    assert full.makespan < no_sharp.makespan
    # each mode re-measures unit times on a noisy shared CPU; allow slack
    assert full.makespan <= no_db.makespan * 1.15
    assert full.avg_utilization > no_sharp.avg_utilization
    # losses identical across modes (scheduling never touches math)
    for i in full.losses:
        np.testing.assert_allclose(full.losses[i], no_sharp.losses[i],
                                   rtol=1e-5, atol=1e-5)


def test_more_devices_dont_slow_down():
    tasks4 = _tasks("qwen3-0.6b", n=4, steps=2)
    hc2 = HydraConfig(n_devices=2, device_budget_bytes=18 * 10**6)
    r2 = ModelOrchestrator(tasks4, hc2).train_models()
    tasks4b = _tasks("qwen3-0.6b", n=4, steps=2)
    hc4 = HydraConfig(n_devices=4, device_budget_bytes=18 * 10**6)
    r4 = ModelOrchestrator(tasks4b, hc4).train_models()
    assert r4.makespan <= r2.makespan * 1.05


def test_scheduler_choice_random_still_correct():
    tasks = _tasks("qwen3-0.6b", n=2, steps=2)
    hc = HydraConfig(n_devices=2, device_budget_bytes=18 * 10**6,
                     scheduler="random")
    report = ModelOrchestrator(tasks, hc).train_models()
    for i in range(2):
        _, ref = train_sequential_reference(_tasks("qwen3-0.6b")[i])
        np.testing.assert_allclose(ref, report.losses[i],
                                   rtol=3e-4, atol=3e-4)
