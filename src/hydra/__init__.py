"""``import hydra`` — the paper-named alias for ``repro.api``.

The paper presents Hydra's user surface as a handful of names
(Fig. 4: tasks in, orchestration out); this package re-exports the unified
session API under that name so examples read like the paper:

    import hydra

    session = hydra.Session(hydra.HydraConfig(n_devices=2))
    session.submit(hydra.TrainJob(cfg, loader))
    report = session.run(session.plan())

The capability registry and decode-backend surface are re-exported too:
``hydra.family_spec(cfg)`` answers what a model family can do
(``batched_prefill`` / ``padded_prefill`` / ``paging`` /
``spec_draftable`` / ...), and ``hydra.SlotBackend`` /
``hydra.PagedBackend`` / ``hydra.SpecDecodeBackend`` are the
decode-state layouts serving engines select between (see docs/api.md).

Everything here is a re-export; the implementation lives in ``repro``.
"""

from repro.api import (AsyncRun, EvalJob, HydraConfig, JobPlan, JobSpec,
                       JobState, Plan, ServeJob, Session, SessionReport,
                       SpmdTrainJob, TrainJob)
from repro.models.api import family_spec
from repro.models.registry import (CapabilityFallbackWarning, FamilySpec,
                                   families_with, registered_families)
from repro.serving import (DecodeBackend, InferenceEngine, PagedBackend,
                           SlotBackend, SpecDecodeBackend)

__all__ = ["Session", "SessionReport", "AsyncRun", "JobState",
           "JobSpec", "TrainJob", "ServeJob", "EvalJob", "SpmdTrainJob",
           "Plan", "JobPlan", "HydraConfig",
           "FamilySpec", "family_spec", "families_with",
           "registered_families", "CapabilityFallbackWarning",
           "DecodeBackend", "SlotBackend", "PagedBackend",
           "SpecDecodeBackend", "InferenceEngine"]
