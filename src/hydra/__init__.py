"""``import hydra`` — the paper-named alias for ``repro.api``.

The paper presents Hydra's user surface as a handful of names
(Fig. 4: tasks in, orchestration out); this package re-exports the unified
session API under that name so examples read like the paper:

    import hydra

    session = hydra.Session(hydra.HydraConfig(n_devices=2))
    session.submit(hydra.TrainJob(cfg, loader))
    report = session.run(session.plan())

Everything here is a re-export; the implementation lives in ``repro.api``.
"""

from repro.api import (AsyncRun, EvalJob, HydraConfig, JobPlan, JobSpec,
                       JobState, Plan, ServeJob, Session, SessionReport,
                       SpmdTrainJob, TrainJob)

__all__ = ["Session", "SessionReport", "AsyncRun", "JobState",
           "JobSpec", "TrainJob", "ServeJob", "EvalJob", "SpmdTrainJob",
           "Plan", "JobPlan", "HydraConfig"]
