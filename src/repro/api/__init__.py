"""Unified Hydra session API: one resource-managed plan/execute entrypoint
for training, serving, and eval.

    from repro.api import Session, TrainJob, ServeJob, EvalJob
    # (or: import hydra — the paper-named alias package)

    session = Session(HydraConfig(n_devices=2, device_budget_bytes=6 * 10**6))
    session.submit(TrainJob(cfg, loader, lr=1e-3, epochs=1))
    session.submit(ServeJob(cfg, params=weights, cold=True))
    plan = session.plan()        # JSON-serializable; == the dry-run's view
    report = session.run(plan)

The legacy surfaces (``repro.core.ModelOrchestrator``, ``launch/train.py``,
``launch/serve.py``) are thin wrappers over this module; see docs/api.md
for the migration table.
"""

from repro.api.jobs import (EvalJob, JobSpec, ServeJob, SpmdTrainJob,
                            TrainJob)
from repro.api.plan import JobPlan, Plan
from repro.api.session import (AsyncRun, JobState, Session,
                               SessionReport)
from repro.core.sharp import HydraConfig

__all__ = ["Session", "SessionReport", "AsyncRun", "JobState",
           "JobSpec", "TrainJob", "ServeJob", "EvalJob", "SpmdTrainJob",
           "Plan", "JobPlan", "HydraConfig"]
