"""Typed job specs accepted by ``repro.api.Session``.

One resource manager, many workloads (the unification ZeRO-Infinity and
Nagrecha & Kumar's model-selection systems both argue for):

* ``TrainJob``  — one model-selection candidate trained under SHARP
  (wraps the fields of ``repro.core.ModelTask``).
* ``ServeJob``  — one loaded model behind the continuous-batching slot-pool
  engine; ``cold=True`` keeps the params spilled in the session's shared
  host store until the first request promotes them (SHARP-for-inference).
* ``EvalJob``   — fixed-batch loss/perplexity over a dataloader, executed
  forward-only through the same shard queue as training.
* ``SpmdTrainJob`` — single-model pjit training over a mesh (the substrate
  Hydra schedules over); kept here so ``launch/train.py`` is a thin shell.

A job is inert data; ``Session.plan`` turns submitted jobs into a ``Plan``
and ``Session.run`` executes one.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence


@dataclass
class JobSpec:
    """Base spec: subclasses add workload fields; the session assigns ids."""
    cfg: Any                                    # ArchConfig

    kind: str = ""                              # set by subclasses

    def job_id_prefix(self) -> str:
        return self.kind or "job"


@dataclass
class TrainJob(JobSpec):
    """One SHARP training candidate (paper Fig. 4's ModelTask, spec form)."""
    dataloader: Optional[Any] = None            # iterable of batches
    lr: float = 1e-3
    epochs: int = 1
    steps_per_epoch: int = 4
    optimizer: str = "adamw"
    params: Optional[Any] = None                # init'd from seed if None
    seed: int = 0
    batch: int = 2                              # partitioning pilot shape
    seq: int = 128
    early_stop: Optional[Callable[[list], bool]] = None
    kind: str = field(default="train", init=False)

    @classmethod
    def from_task(cls, task) -> "TrainJob":
        """Adapter from the legacy ``repro.core.ModelTask``."""
        return cls(cfg=task.cfg, dataloader=task.dataloader, lr=task.lr,
                   epochs=task.epochs, steps_per_epoch=task.steps_per_epoch,
                   optimizer=task.optimizer, params=task.params,
                   seed=task.seed, batch=task.batch, seq=task.seq,
                   early_stop=task.early_stop)

    def opt_config(self):
        from repro.optim import optimizers as opt
        # per-shard stepping composes with sequential training only when
        # gradient clipping is off (clipping needs the global norm, which no
        # single shard sees) — Hydra therefore disables it
        return opt.OptimizerConfig(kind=self.optimizer, lr=self.lr,
                                   grad_clip=0.0)


@dataclass
class ServeJob(JobSpec):
    """One served model over the slot-pool continuous-batching engine.

    ``bucket_sizes``: length buckets for prefill admission — a sequence of
    ints, the string ``"pow2"`` for power-of-two buckets up to ``max_seq``,
    or None for exact-length groups.  ``cold=True`` defers promotion: the
    params live spilled in the session's host store and move to the device
    only when the first request arrives (shards promoted through
    ``core/spilling.py``, bytes accounted in the serve report).

    ``backend`` selects the decode backend by name — ``"slot"`` (default),
    ``"paged"`` (``paged=True`` is the legacy spelling of the same
    request), or ``"spec"`` (speculative decode: a small ``draft_model``
    member drafts ``draft_k`` tokens per round and the target verifies
    them in one batched forward over a ``spec_inner`` slot or paged
    backend — token-identical to plain greedy decode, strictly fewer
    target forwards than generated tokens; admission additionally
    reserves the k-row verify headroom plus, on any byte-ledger-backed
    job, the draft model's decode state).  The paged backend keeps K/V
    in the block-granular paged
    cache (``block_size`` rows per block): admission reserves blocks for
    the request's actual prompt + decode budget instead of a ``max_seq``
    slot, and ``prefix_share`` (default on) lets requests with a common
    block-aligned prompt prefix alias physical pages copy-on-write.  With
    ``kv_budget_bytes=None`` the pages charge the SESSION's device-0
    ``DeviceMemory`` ledger — the same budget SHARP shard promotions and
    double-buffers charge — so mixed train+serve plans stay byte-accurate;
    a non-None ``kv_budget_bytes`` keeps a private ledger of that size
    instead.  A family whose ``FamilySpec`` does not declare the requested
    capability falls back (slot backend / exact-length groups) with a
    ``CapabilityFallbackWarning``; the *effective* backend is recorded in
    the plan meta and ``session.poll``.
    """
    params: Optional[Any] = None                # init'd from seed if None
    seed: int = 0
    name: Optional[str] = None                  # routing key; cfg.name default
    capacity: int = 4
    max_seq: int = 256
    kv_budget_bytes: Optional[int] = None
    window: Optional[int] = None
    bucket_sizes: Optional[Any] = None          # Sequence[int] | "pow2" | None
    cold: bool = False
    backend: Optional[str] = None               # "slot"|"paged"|"spec"|None
    paged: bool = False                         # legacy alias: backend="paged"
    block_size: int = 16                        # KV rows per physical block
    prefix_share: bool = True                   # COW prefix sharing (paged)
    # kv_dtype='int8' quantizes the paged KV pool (per-row scales stored
    # alongside the pages; dequantized inside the attention kernel), so
    # the same byte budget admits ~4x the blocks.  Default None keeps
    # full-precision KV.  Needs a paged pool (backend='paged', or 'spec'
    # with spec_inner='paged') and a family declaring ``kv_quant``.
    kv_dtype: Optional[str] = None              # None|"fp"|"int8"
    # verify_impl picks the spec backend's paged-verify kernel ("pallas"
    # enables the fused multi-query kernel; None follows the decode impl)
    verify_impl: Optional[str] = None
    # "auto" lets Session.submit pick the draft and/or k from the machine
    # profile's measured draft-vs-target step times (repro.profiler);
    # resolved before validation, recorded in plan meta as ``draft_auto``
    draft_model: Optional[Any] = None           # ArchConfig|"auto" (spec)
    draft_params: Optional[Any] = None          # init'd from draft_seed if None
    draft_seed: int = 0
    draft_k: Any = 4                            # int | "auto"
    spec_inner: Optional[str] = None            # "slot" (default) | "paged"
    # HTTP front-end fields (serving/server.py): whether the model offers
    # SSE token streaming over /v1 endpoints, and an optional extra route
    # alias clients may pass as "model" (e.g. endpoint="prod-chat")
    stream: bool = True
    endpoint: Optional[str] = None
    # SLO scheduling (serving/slo.py): admission policy plus per-MODEL
    # defaults any request may override per-call.  policy="slo" degrades
    # to FIFO order when no request carries a deadline, so it is the safe
    # default; policy="fifo" pins the legacy arrival-order scan (no
    # preemption, no shedding) for A/B baselines.
    policy: str = "slo"
    deadline_ms: Optional[float] = None         # default e2e deadline budget
    priority: str = "normal"                    # default tier: high|normal|low
    max_ttft_ms: Optional[float] = None         # default first-token budget
    slo_aging_s: float = 30.0                   # starvation aging interval
    soft_overload_s: float = float("inf")       # queued-seconds: degrade spec
    hard_overload_s: float = float("inf")       # queued-seconds: shed/reject
    # Tiered memory (ROADMAP item 3; docs/serving.md "Tiered memory"):
    # ``residency`` picks how a COLD model's weights live on the device —
    # "model" (legacy: first request promotes the whole tree) or "shard"
    # (hot shards stay pinned under ``hot_bytes`` of ledger budget, cold
    # shards stream through the serve loop's double buffer exactly like
    # SHARP train shards; idle models' hot shards demote under ledger
    # pressure, LRU by last-served tick).  ``tiered_kv`` enables the
    # host-DRAM KV tier on the paged backend: preempted requests' pages
    # demote to the host pool and prefetch back (``prefetch_ticks`` engine
    # steps of latency) before resume.  ``params_from`` names a finished
    # TrainJob in the same session whose trained weights this job serves
    # straight out of the shared host store — no host round-trip through
    # user code.
    residency: str = "model"                    # "model" | "shard"
    hot_bytes: Optional[int] = None             # shard residency: pin target
    tiered_kv: bool = False                     # host-DRAM KV tier (paged)
    prefetch_ticks: int = 1                     # host->device prefetch latency
    params_from: Optional[str] = None           # TrainJob id to serve from
    kind: str = field(default="serve", init=False)

    def http_options(self) -> dict:
        """The per-model options dict the HTTP front-end consumes."""
        return {"stream": bool(self.stream), "endpoint": self.endpoint}

    def resolved_policy(self):
        """Validated scheduling policy instance for this model's engine."""
        from repro.serving.slo import POLICIES, make_policy
        if self.policy not in POLICIES:
            raise ValueError(
                f"policy={self.policy!r}: known admission policies are "
                f"{sorted(POLICIES)}")
        if self.policy != "slo":
            return make_policy(self.policy)
        if self.slo_aging_s <= 0:
            raise ValueError(
                f"slo_aging_s={self.slo_aging_s}: the starvation-aging "
                "interval is the seconds of waiting that promote a request "
                "one priority tier; it must be positive")
        if self.soft_overload_s > self.hard_overload_s:
            raise ValueError(
                f"soft_overload_s={self.soft_overload_s} > hard_overload_s="
                f"{self.hard_overload_s}: shedding (hard) must not engage "
                "before degradation (soft); order the thresholds")
        return make_policy("slo", aging_s=self.slo_aging_s,
                           soft_overload_s=self.soft_overload_s,
                           hard_overload_s=self.hard_overload_s)

    def default_slo(self):
        """Validated per-model SLO defaults, or None when all unset —
        requests merge their own fields over these (request wins)."""
        from repro.serving.slo import SLO
        if (self.deadline_ms is None and self.max_ttft_ms is None
                and self.priority == "normal"):
            return None
        return SLO(deadline_ms=self.deadline_ms, priority=self.priority,
                   max_ttft_ms=self.max_ttft_ms).validate()

    def validate_tiering(self) -> None:
        """Fail fast on tiered-memory misconfiguration (submit time, not
        mid-run): the tiering knobs only compose certain ways."""
        if self.residency not in ("model", "shard"):
            raise ValueError(
                f"residency={self.residency!r}: weight residency is "
                "'model' (whole-tree promotion on first request) or "
                "'shard' (pinned hot shards + streamed cold shards)")
        if self.residency == "shard" and not self.cold \
                and self.params_from is None:
            raise ValueError(
                "residency='shard' streams weights out of the session's "
                "host store, which only cold jobs have — set cold=True "
                "(or params_from=<train job id>, which implies it)")
        if self.hot_bytes is not None:
            if self.residency != "shard":
                raise ValueError(
                    "hot_bytes only applies to residency='shard' (it caps "
                    "the pinned hot-shard bytes); drop it or switch "
                    "residency")
            if self.hot_bytes < 0:
                raise ValueError(
                    f"hot_bytes={self.hot_bytes}: the pinned hot-shard "
                    "target must be >= 0 (0 streams every shard)")
        if self.prefetch_ticks < 1:
            raise ValueError(
                f"prefetch_ticks={self.prefetch_ticks}: host->device "
                "prefetch takes at least one engine step")
        if self.tiered_kv and self.requested_backend() != "paged":
            raise ValueError(
                f"tiered_kv=True needs the paged backend (KV pages are "
                f"the demotion unit), but this job requests "
                f"{self.requested_backend()!r}")
        if self.params_from is not None and self.params is not None:
            raise ValueError(
                "conflicting spec: params_from names a TrainJob to serve "
                "from, but explicit params were also given; drop one")
        self._validate_kv_dtype()

    def _validate_kv_dtype(self) -> None:
        """Fail fast on KV-quantization misconfiguration: int8 needs a
        paged pool and a family that declares the quantized layout."""
        if self.kv_dtype not in (None, "fp", "int8"):
            raise ValueError(
                f"kv_dtype={self.kv_dtype!r}: expected None, 'fp', or "
                "'int8'")
        req = self.requested_backend()
        has_pages = req == "paged" or (
            req == "spec" and self.resolved_spec_inner() == "paged")
        if self.kv_dtype == "int8":
            if not has_pages:
                raise ValueError(
                    "kv_dtype='int8' quantizes the paged block pool, but "
                    f"this job requests {req!r} — serve with "
                    "backend='paged' (or backend='spec', "
                    "spec_inner='paged')")
            from repro.models.registry import spec as family_spec
            fspec = family_spec(self.cfg)
            if not fspec.kv_quant:
                raise ValueError(
                    f"{self.cfg.name} ({self.cfg.family}): "
                    f"{fspec.why_not('kv_quant')}")
        if self.verify_impl is not None and req != "spec":
            raise ValueError(
                "verify_impl selects the spec backend's paged-verify "
                f"kernel, but this job requests {req!r}")

    def requested_backend(self) -> str:
        """The backend this spec asks for, before capability fallback."""
        if self.backend is not None:
            if self.backend not in ("slot", "paged", "spec"):
                raise ValueError(
                    f"backend={self.backend!r}: known decode backends are "
                    "'slot', 'paged', and 'spec'")
            if self.paged and self.backend != "paged":
                raise ValueError(
                    "conflicting spec: paged=True but backend="
                    f"{self.backend!r}; drop one of them (spec over pages "
                    "is spelled backend='spec', spec_inner='paged')")
            if self.backend == "spec":
                self._validate_draft()
            return self.backend
        return "paged" if self.paged else "slot"

    def _validate_draft(self) -> None:
        """Fail at submit/plan time — not mid-run in the backend ctor —
        when the draft side of a spec job can never execute.  (The TARGET
        lacking ``spec_draftable`` is a planned fallback, not an error;
        a bad DRAFT is a configuration mistake with no fallback.)"""
        if self.draft_model == "auto" or self.draft_k == "auto":
            raise ValueError(
                "draft_model/draft_k='auto' are resolved by Session.submit "
                "from the machine profile (repro.profiler CostModel picks "
                "them from draft-vs-target step times); outside a Session "
                "pass an explicit ArchConfig draft_model and int draft_k")
        if self.draft_model is None:
            raise ValueError(
                "backend='spec' needs a draft member model: pass "
                "draft_model=<ArchConfig> (and optionally "
                "draft_params/draft_seed, draft_k, spec_inner)")
        from repro.models.registry import spec as family_spec
        dspec = family_spec(self.draft_model)
        if not dspec.spec_draftable:
            raise ValueError(
                f"draft {self.draft_model.name} "
                f"({self.draft_model.family}): "
                f"{dspec.why_not('spec_draftable')} — pick a "
                "spec_draftable draft family")
        if self.draft_model.vocab_size != self.cfg.vocab_size:
            raise ValueError(
                f"draft vocab {self.draft_model.vocab_size} != target "
                f"vocab {self.cfg.vocab_size}: greedy-exact acceptance "
                "compares token ids, so the models must share a tokenizer")

    def resolved_spec_inner(self) -> str:
        """The inner backend a spec job wraps, before capability checks."""
        if self.spec_inner is None:
            return "slot"
        if self.spec_inner not in ("slot", "paged"):
            raise ValueError(f"spec_inner={self.spec_inner!r}: the spec "
                             "backend wraps 'slot' or 'paged'")
        return self.spec_inner

    def effective_backend(self) -> str:
        """The backend the engine will actually run, after checking the
        family's declared capabilities (mirrors the engine's fallback)."""
        from repro.models.registry import spec as family_spec
        req = self.requested_backend()
        spec = family_spec(self.cfg)
        if req == "spec" and not spec.spec_draftable:
            req = self.resolved_spec_inner()
        if req == "paged" and not spec.paging:
            return "slot"
        return req

    def effective_spec_inner(self) -> Optional[str]:
        """For an effective spec backend: the inner backend after the
        paging capability check; None when the job is not spec."""
        if self.effective_backend() != "spec":
            return None
        from repro.models.registry import spec as family_spec
        inner = self.resolved_spec_inner()
        if inner == "paged" and not family_spec(self.cfg).paging:
            return "slot"
        return inner

    def resolved_buckets(self) -> Optional[Sequence[int]]:
        if self.bucket_sizes is None:
            return None
        if isinstance(self.bucket_sizes, str):
            if self.bucket_sizes != "pow2":
                raise ValueError(
                    f"bucket_sizes={self.bucket_sizes!r}: the only named "
                    "scheme is 'pow2'; otherwise pass explicit ints")
            from repro.serving.engine import pow2_buckets
            return pow2_buckets(self.max_seq)
        buckets = [int(b) for b in self.bucket_sizes]
        if any(b < 1 for b in buckets):
            raise ValueError(f"bucket_sizes={self.bucket_sizes!r}: "
                             "buckets must be positive lengths")
        if any(b > self.max_seq for b in buckets):
            # the engine would silently drop these, making the plan's
            # bucket list diverge from the live engine's
            raise ValueError(f"bucket_sizes={self.bucket_sizes!r}: buckets "
                             f"cannot exceed max_seq={self.max_seq}")
        return buckets


@dataclass
class EvalJob(JobSpec):
    """Fixed-batch loss/perplexity over a dataloader, forward-only through
    the shard queue — a model bounded only by host DRAM evaluates on one
    device, sharing the partition/spill machinery with training."""
    dataloader: Optional[Any] = None
    n_batches: int = 1
    params: Optional[Any] = None                # init'd from seed if None
    seed: int = 0
    batch: int = 2                              # partitioning pilot shape
    seq: int = 128
    kind: str = field(default="eval", init=False)


@dataclass
class SpmdTrainJob(JobSpec):
    """Single-model pjit training over a device mesh (no spilling — the
    model fits; Hydra's multi-model layer schedules over sub-meshes of this
    substrate).  Mirrors the ``launch/train.py`` CLI surface."""
    steps: int = 100
    batch: int = 8
    seq: int = 256
    accum: int = 1
    lr: float = 3e-4
    optimizer: str = "adamw"
    seed: int = 0
    data: Optional[str] = None                  # token .bin (else synthetic)
    mesh: Any = "auto"                          # "auto" | "production" | Mesh
    multi_pod: bool = False
    log_every: int = 10
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    kind: str = field(default="spmd", init=False)
