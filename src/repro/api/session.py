"""``Session`` — one resource-managed plan/execute entrypoint for training,
serving, and eval (the API the paper's Fig. 4 implies).

A session owns the device memory model (``HydraConfig`` budgets), the
host-side model stores, and the scheduling policy; typed ``JobSpec``s are
submitted against it and planning is split from execution:

    session = Session(HydraConfig(n_devices=2, device_budget_bytes=6e6))
    t0 = session.submit(TrainJob(cfg, loader_0, lr=1e-3))
    s0 = session.submit(ServeJob(cfg, params=weights, cold=True))
    plan = session.plan()            # partitions + spill placement +
    plan.save("plan.json")           #   schedule estimate, JSON round-trips
    report = session.run(plan)       # same Plan object the dry-run inspected

``session.run`` drives SHARP training with real JAX compute, ticking serve
engines between train shard-units (one device fleet, train + serve
interleaved), then drains serving and runs eval jobs forward-only through
the shard queue.  Cold serve jobs keep their params spilled in the host
store until the first request promotes them — the SHARP-for-inference
entry point (ROADMAP).
"""

from __future__ import annotations

import enum
import itertools
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.jobs import (EvalJob, JobSpec, ServeJob, SpmdTrainJob,
                            TrainJob)
from repro.api.plan import (JobPlan, Plan, cfg_to_dict, partition_to_dict)
from repro.core import partitioner as pt
from repro.core import scheduler as sched
from repro.core import shard_graph as sg
from repro.core.sharp import (HydraConfig, ModelExec, RunReport,
                              ShardFunctions, SharpExecutor, UnitEvent)
from repro.core.spilling import DeviceMemory, HostModelStore, to_device
from repro.profiler import (CostModel, MachineFacts, load_facts)
from repro.profiler import DEFAULT_PATH as _PROFILE_PATH


class JobState(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    DONE = "done"
    CANCELLED = "cancelled"


@dataclass
class SessionReport:
    """What ``Session.run`` hands back: one record per workload kind."""
    train: Optional[RunReport] = None
    serve: dict[str, dict] = field(default_factory=dict)
    evals: dict[str, dict] = field(default_factory=dict)
    spmd: dict[str, dict] = field(default_factory=dict)
    unit_trace: list[tuple] = field(default_factory=list)
    serve_trace: list[str] = field(default_factory=list)
    wall_time: float = 0.0


@dataclass
class _EvalExec:
    """Forward-only execution state for one EvalJob."""
    cfg: Any
    plan: sg.ShardPlan
    partition: pt.PartitionResult
    store: HostModelStore
    fns: ShardFunctions
    losses: list = field(default_factory=list)
    batches_done: int = 0
    bytes_moved: int = 0
    exhausted: bool = False      # dataloader ran dry before n_batches


class Session:
    """One resource manager, many workloads (train / serve / eval / spmd)."""

    def __init__(self, hydra_cfg: Optional[HydraConfig] = None, *,
                 profile: Any = "auto"):
        self.hc = (hydra_cfg or HydraConfig()).validate()
        # measured-cost planning (repro.profiler): ``profile`` is "auto"
        # (load results/profile_latest.json when present and fresh), None
        # (force analytic pricing), a path, or a MachineFacts.  The
        # CostModel prices partitions, schedule estimates, serve TTFT
        # priors, and spec-draft auto-pick; with no facts it reproduces
        # the historical analytic constants byte-identically and tags
        # every answer source="analytic" in plan provenance.
        allow_stale = False
        if profile is None:
            facts = None
        elif isinstance(profile, MachineFacts):
            # an explicit facts object is a deliberate choice — the what-if
            # case prices against another machine's profile on purpose
            facts, allow_stale = profile, True
        elif profile == "auto":
            facts = load_facts(_PROFILE_PATH, missing_ok=True)
        elif isinstance(profile, str):
            facts = load_facts(profile)
        else:
            raise TypeError(
                f"profile={profile!r}: pass 'auto', None, a profile JSON "
                "path, or a MachineFacts")
        self.cost = CostModel(facts, allow_stale=allow_stale)
        # session-owned device ledgers: SHARP promotions, double-buffers,
        # and paged serving KV reservations all charge these same objects,
        # so one byte budget arbitrates mixed train+serve residency
        self.devices = [DeviceMemory(d, self.hc.device_budget_bytes,
                                     self.hc.buffer_frac)
                        for d in range(self.hc.n_devices)]
        self._jobs: dict[str, JobSpec] = {}
        self._state: dict[str, JobState] = {}
        self._counters: dict[str, Any] = {}
        self._model_ids = itertools.count()     # SHARP model ids, never reused
        self._pick = sched.get_scheduler(self.hc.scheduler, seed=self.hc.seed)
        # execution state, built by _materialize
        self._train_execs: dict[str, ModelExec] = {}
        self._engines: dict[str, Any] = {}          # job_id -> InferenceEngine
        self._eval_execs: dict[str, _EvalExec] = {}
        self._cold: dict[str, dict] = {}            # job_id -> spilled state
        self._serve_names: dict[str, str] = {}      # routing name -> job_id
        self._materialized: set[str] = set()
        self._results: dict[str, dict] = {}         # finished spmd/eval jobs
        self._async_run: Optional["AsyncRun"] = None
        # serializes engine construction/promotion against the run thread:
        # run_async advertises live submit_request, which may lazily build
        # an engine while serve_tick is walking the engine dict
        self._engine_lock = threading.Lock()
        # capped ring (like MultiModelServer.schedule_trace): a session
        # serving forever must not grow its tick trace without bound
        self.serve_trace: deque[str] = deque(maxlen=4096)
        self.unit_trace: list[tuple] = []
        # cross-model weight-residency LRU (serving/residency.py), built
        # lazily at the first shard-resident serve job: registers itself
        # as a device-0 ledger pressure handler, so idle models' pinned
        # shards demote when some other charge needs the bytes
        self._residency = None

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    # -- submit / poll / cancel lifecycle -----------------------------------
    def submit(self, job: JobSpec) -> str:
        """Register a job; returns its id (``train-0``, ``serve-1``, ...)."""
        if not isinstance(job, (TrainJob, ServeJob, EvalJob, SpmdTrainJob)):
            raise TypeError(f"not a JobSpec: {type(job).__name__}")
        name = None
        if isinstance(job, ServeJob):       # validate before registering
            if job.backend == "spec" and (job.draft_model == "auto"
                                          or job.draft_k == "auto"):
                # measured-cost backend selection: pick draft_model/draft_k
                # from draft-vs-target step times BEFORE draft validation
                # (the carried PR 5 follow-on; analytic priors when
                # unprofiled).  The choice record lands in plan meta.
                choice = self.cost.draft_plan(
                    job.cfg,
                    draft_cfg=(None if job.draft_model == "auto"
                               else job.draft_model),
                    draft_k=(None if job.draft_k == "auto"
                             else job.draft_k))
                job.draft_model = choice.draft_cfg
                job.draft_k = choice.draft_k
                job._draft_auto = choice.record     # read by _serve_meta
            job.resolved_buckets()          # fail fast on a bad bucket spec
            job.requested_backend()         # ... and on a bad backend name
            job.resolved_policy()           # ... and on a bad policy/knobs
            job.default_slo()               # ... and on nonsensical SLOs
            job.validate_tiering()          # ... and on tiering misuse
            if job.params_from is not None:
                src = self._jobs.get(job.params_from)
                if not isinstance(src, TrainJob):
                    have = sorted(j for j, s in self._jobs.items()
                                  if isinstance(s, TrainJob))
                    raise ValueError(
                        f"params_from={job.params_from!r}: not a TrainJob "
                        f"in this session (have {have}); submit the train "
                        "job first, then the serve job that inherits its "
                        "weights")
            name = job.name or job.cfg.name
            if name in self._serve_names:
                raise ValueError(
                    f"serve routing name {name!r} already taken by "
                    f"{self._serve_names[name]}; give replicas distinct "
                    "ServeJob.name values")
        kind = job.kind
        n = self._counters.setdefault(kind, itertools.count())
        job_id = f"{kind}-{next(n)}"
        self._jobs[job_id] = job
        self._state[job_id] = JobState.PENDING
        if name is not None:
            self._serve_names[name] = job_id
        return job_id

    def jobs(self) -> dict[str, JobSpec]:
        return dict(self._jobs)

    def poll(self, job_id: str) -> dict:
        """Status + per-kind progress for one job."""
        job = self._require(job_id)
        out: dict[str, Any] = {"job_id": job_id, "kind": job.kind,
                               "status": self._state[job_id].value}
        if job_id in self._train_execs:
            m = self._train_execs[job_id]
            out.update(losses_seen=len(m.losses), epoch=m.epoch,
                       minibatch=m.minibatch, done=m.done,
                       stopped_early=m.stopped_early)
        if isinstance(job, ServeJob):
            # effective backend/capabilities — a capability fallback must
            # be visible to pollers, not just a one-time warning
            from repro.models.registry import spec as family_spec
            spec = family_spec(job.cfg)
            out.update(backend=job.effective_backend(),
                       requested_backend=job.requested_backend(),
                       capabilities=spec.capabilities())
        if job_id in self._engines:
            eng = self._engines[job_id]
            # retired_total, not len(completed): drain-on-read serving (the
            # HTTP front-end) empties the retention deque, and a completed
            # cap evicts old entries — the counter survives both
            out.update(backend=eng.backend.name,
                       n_completed=eng.retired_total,
                       n_active=len(eng.active_requests()),
                       n_queued=len(eng.queued_requests()),
                       policy=eng.policy.name,
                       n_preempted=eng.n_preempted,
                       n_resumed=eng.n_resumed,
                       n_shed=eng.n_shed,
                       recent_requests=eng.recent_metrics())
            # tiered-memory gauges, only when the job opted in (the keys
            # exist iff the backend/param source is tiered)
            s = eng.summary()
            out.update({k: s[k] for k in
                        ("residency", "n_hot_shards", "hot_resident_bytes",
                         "stream_promoted_bytes", "kv_demoted_bytes",
                         "kv_prefetched_bytes", "prefetch_hit_rate",
                         "peak_live_requests") if k in s})
        if job_id in self._cold:
            out.update(cold=True, promoted="engine" in self._cold[job_id])
        if job_id in self._eval_execs:
            out.update(batches_done=self._eval_execs[job_id].batches_done)
        return out

    def cancel(self, job_id: str) -> None:
        """Withdraw a job: pending jobs never run; a running train job stops
        at its next shard-unit boundary; a serve job drops its queue (active
        requests finish their in-flight tokens); eval stops between batches."""
        self._require(job_id)
        if self._state[job_id] in (JobState.DONE, JobState.CANCELLED):
            return
        self._state[job_id] = JobState.CANCELLED
        # free the routing name so a replacement ServeJob can claim it
        self._serve_names = {n: j for n, j in self._serve_names.items()
                             if j != job_id}
        if job_id in self._train_execs:
            self._train_execs[job_id].done = True
        if job_id in self._engines:
            # first-class engine cancellation: entries stay queued (FIFO
            # order intact) and retire at the next admission pass without
            # being reserved or prefilled; active requests finish
            self._engines[job_id].cancel_all_queued()

    def _settle(self, job_id: str, *, done: bool) -> None:
        """Post-run state transition that never overwrites a cancel: done
        jobs finish, truncated ones return to pending (run() resumes them)."""
        if self._state[job_id] is JobState.CANCELLED:
            return
        self._state[job_id] = JobState.DONE if done else JobState.PENDING

    def _require(self, job_id: str) -> JobSpec:
        if job_id not in self._jobs:
            raise KeyError(f"no job {job_id!r} (have {sorted(self._jobs)})")
        return self._jobs[job_id]

    def _active(self, cls) -> list[str]:
        return [jid for jid, j in self._jobs.items()
                if isinstance(j, cls)
                and self._state[jid] is not JobState.CANCELLED]

    # -- planning ------------------------------------------------------------
    def plan(self, jobs: Optional[Sequence[JobSpec]] = None) -> Plan:
        """Partition + place every submitted job; returns the serializable
        Plan that ``run`` executes.  ``jobs`` is a convenience to submit and
        plan in one call."""
        for job in jobs or ():
            self.submit(job)
        self._materialize()
        plan = Plan(hydra=self._hydra_dict())
        for jid, job in self._jobs.items():
            if self._state[jid] is JobState.CANCELLED:
                continue
            plan.jobs.append(self._plan_job(jid, job))
        plan.schedule = self._schedule_estimate()
        # the *why*: which measured facts (or analytic constants) priced
        # the partitions, schedule estimate, serve priors, and draft picks
        plan.provenance = self.cost.provenance_summary()
        return plan

    def _hydra_dict(self) -> dict:
        import dataclasses
        return dataclasses.asdict(self.hc)

    def _plan_job(self, jid: str, job: JobSpec) -> JobPlan:
        jp = JobPlan(job_id=jid, kind=job.kind, arch=cfg_to_dict(job.cfg))
        partition = None
        if jid in self._train_execs:
            m = self._train_execs[jid]
            partition = m.partition
            jp.host_bytes = pt.tree_bytes(m.store.params)
            jp.meta = {"epochs": m.epochs,
                       "steps_per_epoch": m.steps_per_epoch,
                       "minibatch_time_est": m.minibatch_time()}
        elif jid in self._eval_execs:
            ev = self._eval_execs[jid]
            partition = ev.partition
            jp.host_bytes = pt.tree_bytes(ev.store.params)
            jp.meta = {"n_batches": self._jobs[jid].n_batches}
        elif jid in self._cold:
            partition = self._cold[jid]["partition"]
            jp.host_bytes = pt.tree_bytes(self._cold[jid]["store"].params)
            jp.meta = self._serve_meta(job, cold=True)
        elif isinstance(job, ServeJob):
            # warm: meta derives from the spec alone — no engine needed
            jp.meta = self._serve_meta(job, cold=False)
        elif isinstance(job, SpmdTrainJob):
            jp.meta = {"steps": job.steps, "batch": job.batch,
                       "seq": job.seq, "accum": job.accum,
                       "mesh": str(job.mesh), "optimizer": job.optimizer}
        if partition is not None:
            jp.partition = partition_to_dict(partition)
            jp.max_shard_bytes = max(
                (s.param_bytes for s in partition.shards), default=0)
        return jp

    def _serve_meta(self, job: ServeJob, *, cold: bool) -> dict:
        from repro.models.registry import spec as family_spec
        spec = family_spec(job.cfg)
        # mirror the engine's capability fallbacks: the plan records the
        # EFFECTIVE backend/buckets, never a capability the family's spec
        # does not declare, plus why each fallback happened
        buckets = job.resolved_buckets() if spec.padded_prefill else None
        backend = job.effective_backend()
        fallbacks = {}
        if job.requested_backend() != backend:
            cap = ("spec_draftable" if job.requested_backend() == "spec"
                   else "paging")
            fallbacks["backend"] = spec.why_not(cap)
        if job.bucket_sizes is not None and not spec.padded_prefill:
            fallbacks["bucket_sizes"] = spec.why_not("padded_prefill")
        meta = {"capacity": job.capacity, "max_seq": job.max_seq,
                "kv_budget_bytes": job.kv_budget_bytes,
                "slot_bytes": spec.decode_state_bytes(job.cfg, 1,
                                                      job.max_seq),
                "bucket_sizes": list(buckets) if buckets else None,
                "cold": cold,
                "stream": job.stream,
                "endpoint": job.endpoint,
                "backend": backend,
                "requested_backend": job.requested_backend(),
                "capabilities": spec.capabilities(),
                "capability_fallbacks": fallbacks,
                "policy": job.resolved_policy().name,
                "slo_defaults": (None if job.default_slo() is None else {
                    "deadline_ms": job.deadline_ms,
                    "priority": job.priority,
                    "max_ttft_ms": job.max_ttft_ms}),
                # tiered memory (ROADMAP item 3): weight residency + the
                # train job this serve job inherits weights from, if any
                "residency": job.residency,
                "params_from": job.params_from,
                # measured-cost serving prior: the per-token seconds the
                # engine's SLO slack/TTFT math starts from, and where the
                # number came from (repro.profiler)
                "cost": {
                    "tok_seconds_est": self.cost.tok_seconds(
                        job.cfg, job.max_seq),
                    "source": ("measured"
                               if self.cost.has_decode_facts(job.cfg)
                               else "analytic")}}
        if job.residency == "shard":
            meta["hot_bytes"] = job.hot_bytes
        meta["paged"] = backend == "paged"
        if backend == "paged":
            from repro.serving import blocks_for_rows
            block_bytes = spec.kv_block_bytes(job.cfg, job.block_size,
                                              job.kv_dtype)
            per_req = blocks_for_rows(job.max_seq, job.block_size)
            meta.update(
                block_size=job.block_size,
                kv_dtype=job.kv_dtype or "fp",
                block_bytes=block_bytes,
                max_blocks_per_request=per_req,
                # worst case every lane pinned at max_seq — the cap the
                # plan's memory split charges against the device budget
                kv_page_cap_bytes=job.capacity * per_req * block_bytes,
                prefix_share=job.prefix_share,
                shared_ledger=job.kv_budget_bytes is None,
                tiered_kv=job.tiered_kv,
                prefetch_ticks=job.prefetch_ticks)
        if backend == "spec":
            draft_spec = family_spec(job.draft_model)
            meta.update(
                spec_inner=job.effective_spec_inner(),
                draft_model=job.draft_model.name,
                draft_k=job.draft_k,
                # non-None iff the session auto-picked the draft spec from
                # (measured or analytic) step times at submit
                draft_auto=getattr(job, "_draft_auto", None),
                # draft state rides the same ledger as the target's KV
                # (sized for max_seq + the k-row verify headroom)
                draft_state_bytes=draft_spec.decode_state_bytes(
                    job.draft_model, 1, job.max_seq + job.draft_k),
                shared_ledger=job.kv_budget_bytes is None)
        return meta

    def _schedule_estimate(self) -> dict:
        """Compute-only makespan estimate from the same greedy list scheduler
        the executor uses (transfers excluded — the dry-run's lower bound)."""
        unit_times = []
        for jid in self._active(TrainJob):
            if jid not in self._train_execs:
                continue
            m = self._train_execs[jid]
            chain = [s.fwd_runtime for s in m.partition.shards] + \
                [s.bwd_runtime for s in reversed(m.partition.shards)]
            unit_times.append(chain * (m.epochs * m.steps_per_epoch))
        est = None
        if unit_times:
            est = sched.greedy_list_makespan(
                unit_times, self.hc.n_devices,
                scheduler=sched.get_scheduler(self.hc.scheduler,
                                              seed=self.hc.seed))
        return {"scheduler": self.hc.scheduler,
                "n_devices": self.hc.n_devices,
                "est_makespan_s": est,
                "n_train_units": sum(len(u) for u in unit_times),
                "memory": self._memory_split()}

    def _serve_kv_cap(self) -> int:
        """Worst-case bytes the session's shared-ledger serve jobs can
        reserve — paged KV pages (every lane pinned at max_seq) plus, for
        speculative jobs, the draft model's decode state and the k-row
        verify headroom — the slice of the device budget the partitioner
        must leave for decode state."""
        from repro.models.registry import spec as family_spec
        from repro.serving import blocks_for_rows
        cap = 0
        for jid in self._active(ServeJob):
            job = self._jobs[jid]
            if job.kv_budget_bytes is not None:
                continue                 # private ledger, not this budget
            backend = job.effective_backend()
            if backend == "paged":
                cap += (job.capacity
                        * blocks_for_rows(job.max_seq, job.block_size)
                        * family_spec(job.cfg).kv_block_bytes(
                            job.cfg, job.block_size))
            elif backend == "spec":
                rows = job.max_seq + job.draft_k
                if job.effective_spec_inner() == "paged":
                    target = (job.capacity
                              * blocks_for_rows(rows, job.block_size)
                              * family_spec(job.cfg).kv_block_bytes(
                                  job.cfg, job.block_size))
                else:
                    target = job.capacity * family_spec(
                        job.cfg).decode_state_bytes(job.cfg, 1, rows)
                draft = job.capacity * family_spec(
                    job.draft_model).decode_state_bytes(
                        job.draft_model, 1, rows)
                cap += target + draft
        return cap

    def _memory_split(self) -> dict:
        """One device byte budget, split: train double-buffer reservation,
        the worst-case serve KV-page cap (shared-ledger paged jobs), and
        what is left for promoted shards.  Mirrors execution exactly:
        ``_spill_setup`` partitions against ``budget - kv_cap`` and the
        partitioner carves ``buffer_frac`` of THAT, so the buffer term
        here is computed on the reduced budget too."""
        budget = self.hc.device_budget_bytes
        kv_cap = self._serve_kv_cap()
        buffer_bytes = int((budget - kv_cap) * self.hc.buffer_frac)
        return {"device_budget_bytes": budget,
                "train_buffer_bytes": buffer_bytes,
                "serve_kv_page_cap_bytes": kv_cap,
                "shard_headroom_bytes": budget - buffer_bytes - kv_cap}

    # -- materialization ------------------------------------------------------
    def _materialize(self, plan: Optional[Plan] = None,
                     only: Optional[str] = None) -> None:
        """Build execution state (params, partitions, stores, engines) for
        every submitted job — or just ``only``.  With ``plan`` given,
        partitions come from the plan instead of being recomputed — the
        dry-run and the real run consume the same object."""
        for jid, job in self._jobs.items():
            if only is not None and jid != only:
                continue
            if jid in self._materialized or \
                    self._state[jid] is JobState.CANCELLED:
                continue
            planned = self._planned_partition(plan, jid)
            if isinstance(job, TrainJob):
                self._train_execs[jid] = self._build_train(jid, job, planned)
            elif isinstance(job, EvalJob):
                self._eval_execs[jid] = self._build_eval(job, planned)
            elif isinstance(job, ServeJob):
                if not job.cold and job.params_from is None and only is None:
                    # a warm engine (param init + device-resident slot pool)
                    # is execution state a plan does not need — engine()
                    # builds it lazily at the first request or at run()
                    continue
                self._build_serve(jid, job, planned)
            # SpmdTrainJob materializes nothing up front (pjit owns placement)
            self._materialized.add(jid)

    def _verify_plan_config(self, plan: Plan) -> None:
        """Cheap checks that must run BEFORE materializing from the plan —
        rejecting a foreign plan must not leave its partitions behind as
        session state."""
        import json as _json
        # normalize both sides through JSON so a disk-reloaded plan (str
        # dict keys, lists for tuples) compares equal to a live one
        mine = _json.loads(_json.dumps(self._hydra_dict()))
        theirs = _json.loads(_json.dumps(plan.hydra))
        if theirs != mine:
            diff = sorted(k for k in set(mine) | set(theirs)
                          if mine.get(k) != theirs.get(k))
            raise ValueError(
                f"plan/session divergence: HydraConfig differs on {diff} — "
                "the plan's schedule estimate would not describe this "
                "session's execution; replan under the session's config")
        planned_ids = {jp.job_id for jp in plan.jobs}
        missing = [jid for jid, st in self._state.items()
                   if st is not JobState.CANCELLED
                   and jid not in planned_ids]
        if missing:
            raise ValueError(
                f"plan/session divergence: session jobs {missing} are not "
                "in the plan — replan so every job's placement is planned, "
                "not silently recomputed")

    def _verify_plan_partitions(self, plan: Plan) -> None:
        """Post-materialization check: every planned partition must match
        the materialized one shard-for-shard."""
        for jp in plan.jobs:
            if jp.partition is None or jp.job_id not in self._jobs:
                continue
            live = None
            if jp.job_id in self._train_execs:
                live = self._train_execs[jp.job_id].partition
            elif jp.job_id in self._eval_execs:
                live = self._eval_execs[jp.job_id].partition
            elif jp.job_id in self._cold:
                live = self._cold[jp.job_id]["partition"]
            # structural identity only — a pilot pass overwrites measured
            # runtimes in place, and re-measurement is legitimate
            def skeleton(p):
                return [(s.index, s.seg_lo, s.seg_hi) for s in p.shards]
            if live is not None and skeleton(jp.shards()) != skeleton(live):
                raise ValueError(
                    f"plan/session divergence for {jp.job_id}: the plan's "
                    "partition does not match the materialized one — replan "
                    "or rebuild the session from this plan")

    def _planned_partition(self, plan: Optional[Plan],
                           jid: str) -> Optional[pt.PartitionResult]:
        if plan is None:
            return None
        try:
            jp = plan.job(jid)
        except KeyError:
            return None
        if jp.arch["name"] != self._jobs[jid].cfg.name:
            raise ValueError(
                f"plan/job mismatch for {jid}: plan is for "
                f"{jp.arch['name']!r}, session has "
                f"{self._jobs[jid].cfg.name!r}")
        return jp.shards() if jp.partition is not None else None

    def _init_params(self, job) -> Any:
        from repro.models import api as mapi
        if job.params is not None:
            return job.params
        return mapi.init_params(job.cfg, jax.random.PRNGKey(job.seed))

    def _spill_setup(self, cfg, params, *, batch: int, seq: int,
                     train: bool, planned=None):
        """Shared partition + store + shard-fns construction."""
        shard_plan = sg.build_plan(cfg)
        host = sg.prepare_host_params(cfg, jax.tree.map(np.asarray, params))
        # shards are sized against the budget MINUS the serve KV-page cap:
        # pages charge the same ledger promotions do, so a shard planned
        # for the full budget would blow _check_budget mid-run whenever
        # serve admission is active between its units
        budget = self.hc.device_budget_bytes - self._serve_kv_cap()
        if budget <= 0:
            raise ValueError(
                f"paged serve jobs reserve {self._serve_kv_cap()} B of KV "
                f"pages, leaving no shard headroom in the "
                f"{self.hc.device_budget_bytes} B device budget — shrink "
                "ServeJob capacity/max_seq or give them kv_budget_bytes")
        partition = planned if planned is not None else pt.partition(
            cfg, host, shard_plan,
            budget_bytes=budget,
            batch=batch, seq=seq, oracle=self.hc.partition_oracle,
            buffer_frac=self.hc.buffer_frac, train=train,
            cost_model=self.cost)
        return shard_plan, partition

    def _build_train(self, jid: str, job: TrainJob, planned) -> ModelExec:
        cfg = job.cfg
        params = self._init_params(job)
        shard_plan, partition = self._spill_setup(
            cfg, params, batch=job.batch, seq=job.seq, train=True,
            planned=planned)
        ocfg = job.opt_config()
        store = HostModelStore(cfg, shard_plan, params, ocfg, partition)
        fns = ShardFunctions(cfg, shard_plan, partition, ocfg)
        # monotonic, never reused: a cancel between materializations must
        # not make a later job collide with an existing exec's id (RunReport
        # keys losses by model_id)
        model_id = next(self._model_ids)
        return ModelExec(
            model_id=model_id, cfg=cfg, plan=shard_plan,
            partition=partition, store=store, fns=fns,
            data_iter=iter(job.dataloader), epochs=job.epochs,
            steps_per_epoch=job.steps_per_epoch, early_stop=job.early_stop)

    def _build_eval(self, job: EvalJob, planned) -> _EvalExec:
        from repro.optim import optimizers as opt
        cfg = job.cfg
        params = self._init_params(job)
        shard_plan, partition = self._spill_setup(
            cfg, params, batch=job.batch, seq=job.seq, train=False,
            planned=planned)
        ocfg = opt.OptimizerConfig(grad_clip=0.0)
        store = HostModelStore(cfg, shard_plan, params, ocfg, partition)
        fns = ShardFunctions(cfg, shard_plan, partition, ocfg)
        return _EvalExec(cfg=cfg, plan=shard_plan, partition=partition,
                         store=store, fns=fns)

    def _build_serve(self, jid: str, job: ServeJob, planned) -> None:
        from repro.optim import optimizers as opt
        if job.params_from is not None:
            # train-then-serve promotion: this job serves straight out of
            # the TRAIN job's host store — no host round-trip through user
            # code.  Promotion is necessarily deferred (cold) until the
            # weights exist; _promote_cold enforces the ordering.
            tjid = job.params_from
            if tjid not in self._train_execs:
                self._materialize(only=tjid)
            m = self._train_execs[tjid]
            self._cold[jid] = {"store": m.store, "partition": m.partition,
                               "params_from": tjid,
                               "promote_bytes": 0, "promote_s": 0.0}
            return
        params = self._init_params(job)
        if not job.cold:
            self._engines[jid] = self._make_engine(job, params)
            return
        # cold: params stay spilled in the shared host store; the partition
        # records the promotion plan, the first request executes it
        shard_plan, partition = self._spill_setup(
            job.cfg, params, batch=1, seq=job.max_seq, train=False,
            planned=planned)
        store = HostModelStore(job.cfg, shard_plan, params,
                               opt.OptimizerConfig(grad_clip=0.0), partition)
        self._cold[jid] = {"store": store, "partition": partition,
                           "promote_bytes": 0, "promote_s": 0.0}

    def _make_engine(self, job: ServeJob, params, *, param_source=None):
        """Backend selection happens ONCE here: resolve the job's effective
        backend through the FamilySpec registry and hand the engine one
        backend choice — no capability branches at call sites."""
        from repro.serving import InferenceEngine
        kw: dict[str, Any] = {}
        if param_source is not None:
            kw.update(param_source=param_source)
        if self.cost.has_decode_facts(job.cfg):
            # measured per-token prior: min_slack_seconds / TTFT estimates
            # start from this host's probed decode rate instead of the
            # analytic 2e-10·params constant (the EMA still takes over
            # after the first real step)
            kw.update(tok_seconds_prior=self.cost.tok_seconds(
                job.cfg, job.max_seq))
        effective = job.effective_backend()
        if effective == "spec":
            from repro.models import api as mapi
            draft_params = job.draft_params
            if draft_params is None:
                draft_params = mapi.init_params(
                    job.draft_model, jax.random.PRNGKey(job.draft_seed))
            kw.update(draft_cfg=job.draft_model, draft_params=draft_params,
                      draft_k=job.draft_k,
                      spec_inner=job.resolved_spec_inner(),
                      block_size=job.block_size,
                      prefix_share=job.prefix_share,
                      kv_dtype=job.kv_dtype, verify_impl=job.verify_impl)
            if job.kv_budget_bytes is None:
                # target KV (incl. verify headroom) AND draft state charge
                # the session's device-0 ledger — the budget SHARP
                # promotions charge
                kw.update(ledger=self.devices[0])
            else:
                kw.update(kv_budget_bytes=job.kv_budget_bytes)
        elif effective == "paged":
            kw.update(block_size=job.block_size,
                      prefix_share=job.prefix_share,
                      kv_dtype=job.kv_dtype,
                      tiered_kv=job.tiered_kv,
                      prefetch_ticks=job.prefetch_ticks)
            if job.kv_budget_bytes is None:
                # pages charge the session's device-0 ledger — the budget
                # SHARP promotions charge — unless the job pins a private cap
                kw.update(ledger=self.devices[0])
            else:
                kw.update(kv_budget_bytes=job.kv_budget_bytes)
        else:
            kw.update(kv_budget_bytes=job.kv_budget_bytes)
        return InferenceEngine(
            job.cfg, params, capacity=job.capacity, max_seq=job.max_seq,
            window=job.window, model_name=job.name or job.cfg.name,
            backend=job.requested_backend(),
            bucket_sizes=job.resolved_buckets(),
            policy=job.resolved_policy(), default_slo=job.default_slo(),
            **kw)

    def _promote_cold(self, jid: str) -> None:
        """First request for a cold model: promote its shards out of the
        host store (core/spilling byte accounting) and build the engine.
        ``residency='shard'`` skips the whole-tree move: the engine gets a
        ``ShardResidentParams`` source instead, and residency is decided
        tick-by-tick (pinned hot shards + streamed cold shards)."""
        cold = self._cold[jid]
        job: ServeJob = self._jobs[jid]          # type: ignore[assignment]
        store, partition = cold["store"], cold["partition"]
        tjid = cold.get("params_from")
        if tjid is not None and not self._train_execs[tjid].done:
            raise RuntimeError(
                f"{jid}: params_from={tjid!r} has not finished training — "
                "its weights do not exist to serve yet; run() trains "
                "before draining serve requests")
        if job.residency == "shard":
            from repro.serving.residency import (ResidencyCoordinator,
                                                 ShardResidentParams)
            if self._residency is None:
                self._residency = ResidencyCoordinator(self.devices[0])
            src = ShardResidentParams(
                job.cfg, store, partition, self.devices[0],
                hot_bytes=job.hot_bytes, name=job.name or job.cfg.name)
            self._residency.register(src)
            cold["residency"] = src
            cold["engine"] = self._engines[jid] = self._make_engine(
                job, None, param_source=src)
            return
        t0 = time.perf_counter()
        # the transfer itself is the single to_device below; the spilling
        # store's per-shard accounting prices it shard-by-shard
        moved = sum(store.shard_transfer_bytes(s, train=False)
                    for s in partition.shards)
        params = to_device(store.model_params())
        jax.block_until_ready(jax.tree.leaves(params)[0])
        cold["promote_bytes"] = moved
        cold["promote_s"] = time.perf_counter() - t0
        cold["engine"] = self._engines[jid] = self._make_engine(job, params)

    # -- serving surface ------------------------------------------------------
    def engine(self, target: str):
        """The live engine for a serve job id or routing name (promotes a
        cold model if needed)."""
        jid = self._serve_names.get(target, target)
        job = self._require(jid)
        if not isinstance(job, ServeJob):
            raise TypeError(f"{jid} is a {job.kind} job, not serve")
        with self._engine_lock:      # one builder, even mid-async-run
            if jid not in self._materialized:
                # just this job: answering a serve request must not force
                # param init/partitioning for every pending train job
                self._materialize(only=jid)
            if jid not in self._engines:
                self._promote_cold(jid)
            return self._engines[jid]

    def submit_request(self, target: str, prompt, max_new_tokens: int, **kw):
        """Enqueue one generation request on a serve job (by id or name)."""
        jid = self._serve_names.get(target, target)
        self._require(jid)
        if self._state[jid] is JobState.CANCELLED:
            raise ValueError(f"{jid} is cancelled")
        return self.engine(jid).submit(prompt, max_new_tokens, **kw)

    def cancel_request(self, request_id: str,
                       target: Optional[str] = None) -> bool:
        """Withdraw ONE generation request (vs. ``cancel``, which withdraws
        a whole job).  Queued requests retire unreserved at the next
        admission pass; a running one frees its lane and KV reservation at
        the next tick.  ``target`` narrows the search to one serve job (id
        or routing name); otherwise every live engine is asked."""
        if target is not None:
            return self.engine(target).cancel(request_id)
        with self._engine_lock:
            engines = list(self._engines.values())
        return any(eng.cancel(request_id) for eng in engines)

    def serve_has_work(self) -> bool:
        with self._engine_lock:
            engines = list(self._engines.values())
        return any(e.has_work() for e in engines)

    def serve_tick(self) -> Optional[str]:
        """One serving tick: the session's scheduling policy picks which
        model's engine steps (LRTF keeps the model with the most outstanding
        tokens moving).  Returns the model name stepped, or None if idle.

        Deliberately not delegated to ``MultiModelServer``: that wrapper
        snapshots its engine dict at construction, while a session's engine
        set grows mid-run as cold models promote."""
        with self._engine_lock:      # snapshot: submit_request may be
            engines = list(self._engines.items())   # adding an engine now
        eligible = [(jid, eng) for jid, eng in engines
                    if eng.has_work()]
        if not eligible:
            return None
        progress = [sched.ModelProgress.from_remaining(
            i, eng.remaining_seconds())
            for i, (_, eng) in enumerate(eligible)]
        _, eng = eligible[self._pick(progress)]
        eng.step()
        self.serve_trace.append(eng.model_name)
        return eng.model_name

    def drain_serving(self, max_ticks: Optional[int] = None) -> int:
        ticks = 0
        while self.serve_tick() is not None:
            ticks += 1
            if max_ticks is not None and ticks >= max_ticks:
                break
        return ticks

    # -- execution ------------------------------------------------------------
    def run_async(self, plan: Optional[Plan] = None, *,
                  max_units: Optional[int] = None) -> "AsyncRun":
        """``run`` on a background executor thread, returning immediately.

        ``poll(job_id)`` stays live while the run is in flight (execution
        state is mutated in place), so callers can watch training epochs
        advance or serve queues drain and keep submitting requests against
        running serve jobs.  One run at a time: a second ``run_async``
        before the first finishes raises.
        """
        self._guard_single_run()
        self._async_run = AsyncRun(self, plan, max_units)
        return self._async_run

    def _guard_single_run(self) -> None:
        """Two executors over the same stores/ledgers/data iterators would
        silently corrupt each other — refuse, whether the other run is the
        async handle's or another thread's plain run()."""
        if self._async_run is not None and not self._async_run.done():
            raise RuntimeError(
                "a session run is already in flight; wait on its handle "
                "(AsyncRun.result) before starting another")

    def run(self, plan: Optional[Plan] = None, *,
            max_units: Optional[int] = None) -> SessionReport:
        """Execute a Plan: SHARP training with serve ticks between shard
        units, then serving drain, then spmd and eval jobs."""
        self._guard_single_run()
        return self._run_impl(plan, max_units)

    def _run_impl(self, plan: Optional[Plan],
                  max_units: Optional[int]) -> SessionReport:
        wall0 = time.perf_counter()
        # under the engine lock: a concurrent submit_request during an
        # async run materializes lazily via engine(), and two builders for
        # one job would double-init params and clobber cold-serve state
        with self._engine_lock:
            if plan is None:
                # no external plan to honor: materialize directly instead
                # of paying for plan serialization + schedule simulation
                self._materialize()
            else:
                self._verify_plan_config(plan)   # before any state is built
                self._materialize(plan)
                self._verify_plan_partitions(plan)
        report = SessionReport()

        train_ids = [jid for jid in self._active(TrainJob)
                     if jid in self._train_execs]
        execs = sorted((self._train_execs[j] for j in train_ids),
                       key=lambda m: m.model_id)
        for jid in train_ids:
            self._state[jid] = JobState.RUNNING

        def on_unit(ev: UnitEvent):
            self.unit_trace.append(ev.key())
            self.serve_tick()        # serve jobs tick between shard units

        if execs:
            # train residency is rebuilt from the host stores each run;
            # live KV-page reservations (in-flight serve requests) persist
            for dm in self.devices:
                dm.resident_bytes = 0
                dm.buffered_bytes = 0
            executor = SharpExecutor(self.hc, execs, devices=self.devices)
            report.train = executor.run(max_units=max_units, on_unit=on_unit)
        for jid in train_ids:
            # don't stomp a mid-run cancel, and a max_units-truncated job
            # goes back to pending (its exec state persists; run() resumes)
            self._settle(jid, done=self._train_execs[jid].done)

        for jid in self._active(SpmdTrainJob):
            if self._state[jid] is JobState.DONE:    # resumed run(): done
                report.spmd[jid] = self._results[jid]   # jobs don't re-run
                continue
            self._state[jid] = JobState.RUNNING
            report.spmd[jid] = self._results[jid] = _run_spmd(self._jobs[jid])
            self._settle(jid, done=True)

        for jid in self._active(EvalJob):
            if jid not in self._eval_execs:
                continue
            if self._state[jid] is JobState.DONE:
                report.evals[jid] = self._results[jid]
                continue
            self._state[jid] = JobState.RUNNING
            report.evals[jid] = self._results[jid] = self._run_eval(jid)
            ev = self._eval_execs[jid]
            self._settle(jid, done=ev.exhausted or ev.batches_done
                         >= self._jobs[jid].n_batches)

        self.drain_serving()
        for jid in self._active(ServeJob):
            if jid not in self._engines and jid not in self._cold:
                self.engine(jid)     # run() brings warm engines live
            eng = self._engines.get(jid)
            rec: dict[str, Any] = {}
            if eng is not None:
                rec = dict(eng.summary())
                rec["requests"] = [r.metrics() for r in eng.completed]
            if jid in self._cold:
                rec.update(cold=True,
                           promote_bytes=self._cold[jid]["promote_bytes"],
                           promote_s=round(self._cold[jid]["promote_s"], 4))
                if eng is None:
                    rec.update(promoted=False)   # never received a request
            report.serve[jid] = rec
            self._settle(jid, done=True)

        report.unit_trace = list(self.unit_trace)
        report.serve_trace = list(self.serve_trace)
        report.wall_time = time.perf_counter() - wall0
        return report

    def _run_eval(self, jid: str) -> dict:
        """Forward-only shard-queue loop: promote, apply, demote — loss per
        batch, serve ticks between shard units."""
        from repro.training.losses import softmax_xent
        job: EvalJob = self._jobs[jid]           # type: ignore[assignment]
        ev = self._eval_execs[jid]
        it = iter(job.dataloader)
        for _ in range(job.n_batches):
            if self._state[jid] is JobState.CANCELLED:
                break
            try:
                raw = next(it)
            except StopIteration:
                # a short dataloader ends the job with partial results; it
                # must not crash run() and discard every other job's report
                ev.exhausted = True
                break
            batch = jax.tree.map(jnp.asarray, raw)
            from repro.core.orchestrator import spilled_forward
            logits, moved = spilled_forward(
                ev.store, ev.fns, ev.partition, batch,
                on_shard=lambda _s: self.serve_tick())
            ev.bytes_moved += moved
            loss = float(softmax_xent(logits, batch["labels"]))
            ev.losses.append(loss)
            ev.batches_done += 1
        mean = float(np.mean(ev.losses)) if ev.losses else None
        return {"losses": ev.losses,
                "mean_loss": mean,
                "perplexity": float(np.exp(mean)) if mean is not None
                else None,
                "n_shards": len(ev.partition.shards),
                "bytes_moved": ev.bytes_moved}

    # -- introspection for thin wrappers -------------------------------------
    @property
    def train_execs(self) -> list[ModelExec]:
        """ModelExecs ordered by model_id (ModelOrchestrator compat)."""
        self._materialize()
        return sorted(self._train_execs.values(), key=lambda m: m.model_id)


class AsyncRun:
    """Handle for a background ``Session.run`` (``Session.run_async``).

    ``done()`` is non-blocking; ``result(timeout)`` joins the executor
    thread and either returns the ``SessionReport`` or re-raises whatever
    the run raised — a failed background run never disappears silently.
    """

    def __init__(self, session: Session, plan: Optional[Plan],
                 max_units: Optional[int]):
        self._report: Optional[SessionReport] = None
        self._exc: Optional[BaseException] = None

        def _main():
            try:
                # _run_impl, not run(): the single-run guard would see THIS
                # handle as the in-flight run and refuse its own execution
                self._report = session._run_impl(plan, max_units)
            except BaseException as e:          # re-raised in result()
                self._exc = e

        self._thread = threading.Thread(
            target=_main, name="hydra-session-run", daemon=True)
        self._thread.start()

    def done(self) -> bool:
        return not self._thread.is_alive()

    def result(self, timeout: Optional[float] = None) -> SessionReport:
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError(
                f"session run still executing after {timeout} s")
        if self._exc is not None:
            raise self._exc
        assert self._report is not None
        return self._report


# ---------------------------------------------------------------------------
# SPMD execution (the pjit substrate; launch/train.py is a shell over this)
# ---------------------------------------------------------------------------

def _make_mesh(job: SpmdTrainJob):
    from repro.launch.mesh import make_mesh, make_production_mesh
    if not isinstance(job.mesh, str):
        return job.mesh
    if job.mesh == "production":
        return make_production_mesh(multi_pod=job.multi_pod)
    n = len(jax.devices())
    if n == 1:
        return make_mesh((1, 1), ("data", "model"))
    nd = max(1, n // 2)
    return make_mesh((nd, n // nd), ("data", "model"))


def _run_spmd(job: SpmdTrainJob) -> dict:
    """Single-model pjit training loop (moved from launch/train.py)."""
    from repro import checkpoint as ckpt
    from repro.data import DataConfig, Prefetcher, make_dataset
    from repro.models import api
    from repro.optim import OptimizerConfig, init_state
    from repro.sharding import specs as sh
    from repro.training import make_train_step

    cfg = job.cfg
    mesh = _make_mesh(job)
    ocfg = OptimizerConfig(kind=job.optimizer, lr=job.lr,
                           schedule="linear_warmup_cosine",
                           warmup_steps=max(job.steps // 20, 1),
                           total_steps=job.steps)

    params = api.init_params(cfg, jax.random.PRNGKey(job.seed))
    opt_state = init_state(ocfg, params)

    pshard = sh.to_shardings(mesh, sh.param_specs(cfg, params, mesh))
    oshard = sh.to_shardings(mesh, sh.opt_state_specs(cfg, opt_state, mesh))
    params = jax.device_put(params, pshard)
    opt_state = jax.device_put(opt_state, oshard)

    data_cfg = DataConfig(batch_size=job.batch, seq_len=job.seq,
                          vocab_size=cfg.vocab_size, seed=job.seed,
                          path=job.data)
    from repro.models.registry import spec as family_spec
    if not family_spec(cfg).token_stream_data:
        # audio/vlm batches carry embeddings the token pipeline can't make
        def synth():
            i = 0
            while True:
                yield api.make_dummy_batch(cfg, job.batch, job.seq,
                                           key=jax.random.PRNGKey(i))
                i += 1
        it = synth()
    else:
        it = iter(Prefetcher(iter(make_dataset(data_cfg)), depth=2))

    step_fn = jax.jit(
        make_train_step(cfg, ocfg, accum_steps=job.accum),
        in_shardings=(pshard, oshard, None),
        out_shardings=(pshard, oshard, None),
        donate_argnums=(0, 1))

    history = []
    t0 = time.perf_counter()
    for step in range(job.steps):
        batch = next(it)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % job.log_every == 0 or step == job.steps - 1:
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            tok_s = job.batch * job.seq * (step + 1) / dt
            print(f"step {step:5d}  loss {loss:8.4f}  "
                  f"gnorm {float(metrics['grad_norm']):7.3f}  "
                  f"{tok_s:9.0f} tok/s")
            history.append({"step": step, "loss": loss})
        if job.ckpt_dir and step and step % job.ckpt_every == 0:
            ckpt.save(f"{job.ckpt_dir}/step_{step}", params, step=step)
    if job.ckpt_dir:
        ckpt.save(f"{job.ckpt_dir}/step_{job.steps}", params,
                  step=job.steps)
    return {"history": history,
            "final_loss": history[-1]["loss"] if history else None,
            "params": api.param_count(params)}
