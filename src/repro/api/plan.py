"""The explicit plan/execute split: ``Session.plan(jobs) -> Plan``.

A ``Plan`` is pure data — per-job partitions (shard boundaries, byte sizes,
analytic runtimes), spill placement (what stays host-resident), and a
schedule estimate from the same greedy list scheduler the executor uses.
It serializes to JSON, and ``Session.run(plan)`` consumes the *same* object
the dry-run inspected: a Plan re-loaded from disk reconstructs
byte-identical ``Shard`` lists, so the executed schedule reproduces the
planned one exactly (tests/test_api_session.py).
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Optional

import jax.numpy as jnp

from repro.core.partitioner import PartitionResult, Shard

# ArchConfig dtype fields hold jnp scalar types; JSON carries their names
_DTYPES = {
    "bfloat16": jnp.bfloat16, "float32": jnp.float32,
    "float16": jnp.float16, "float64": jnp.float64,
}


def cfg_to_dict(cfg) -> dict:
    d = dataclasses.asdict(cfg)
    for k in ("dtype", "param_dtype"):
        d[k] = jnp.dtype(d[k]).name
    return d


def cfg_from_dict(d: dict):
    from repro.configs.base import ArchConfig
    d = dict(d)
    for k in ("dtype", "param_dtype"):
        d[k] = _DTYPES[d[k]]
    return ArchConfig(**d)


def partition_to_dict(p: PartitionResult) -> dict:
    return {
        "shards": [dataclasses.asdict(s) for s in p.shards],
        "shared_bytes": p.shared_bytes,
        "budget_bytes": p.budget_bytes,
        "oracle": p.oracle,
    }


def partition_from_dict(d: dict) -> PartitionResult:
    return PartitionResult(
        shards=[Shard(**s) for s in d["shards"]],
        shared_bytes=d["shared_bytes"],
        budget_bytes=d["budget_bytes"],
        oracle=d["oracle"])


@dataclass
class JobPlan:
    """Planned placement for one job."""
    job_id: str
    kind: str                                   # train | serve | eval | spmd
    arch: dict                                  # cfg_to_dict(cfg)
    partition: Optional[dict] = None            # train/eval/cold-serve
    # spill placement: bytes resident on host vs. promoted per unit
    host_bytes: int = 0
    max_shard_bytes: int = 0
    # workload shape
    meta: dict = field(default_factory=dict)

    def shards(self) -> PartitionResult:
        if self.partition is None:
            raise ValueError(f"{self.job_id}: no partition in plan")
        return partition_from_dict(self.partition)

    def cfg(self):
        return cfg_from_dict(self.arch)


@dataclass
class Plan:
    """Everything ``Session.run`` needs, and nothing it recomputes."""
    hydra: dict                                 # HydraConfig fields
    jobs: list[JobPlan] = field(default_factory=list)
    schedule: dict = field(default_factory=dict)
    # which cost facts priced which decision (repro.profiler.CostModel
    # provenance_summary): {"profile": ... | None, "n_measured", "queries"}
    # — the *why* behind every estimate above, so `dryrun --plan --profile`
    # is a real what-if tool
    provenance: dict = field(default_factory=dict)
    version: int = 1

    def job(self, job_id: str) -> JobPlan:
        for jp in self.jobs:
            if jp.job_id == job_id:
                return jp
        raise KeyError(f"no job {job_id!r} in plan "
                       f"(have {[j.job_id for j in self.jobs]})")

    # -- serialization ------------------------------------------------------
    def to_json(self, **kw) -> str:
        return json.dumps({
            "version": self.version,
            "hydra": self.hydra,
            "schedule": self.schedule,
            "provenance": self.provenance,
            "jobs": [dataclasses.asdict(j) for j in self.jobs],
        }, **kw)

    @classmethod
    def from_json(cls, text: str) -> "Plan":
        d = json.loads(text)
        if d.get("version") != 1:
            raise ValueError(f"unsupported plan version {d.get('version')!r}")
        # .get: pre-profiler plans on disk carry no provenance block
        return cls(hydra=d["hydra"], schedule=d["schedule"],
                   provenance=d.get("provenance", {}),
                   jobs=[JobPlan(**j) for j in d["jobs"]],
                   version=d["version"])

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(indent=1))

    @classmethod
    def load(cls, path: str) -> "Plan":
        with open(path) as f:
            return cls.from_json(f.read())

    # -- reporting ----------------------------------------------------------
    def summary(self) -> dict:
        out: dict[str, Any] = {
            "n_jobs": len(self.jobs),
            "n_devices": self.hydra.get("n_devices"),
            "scheduler": self.schedule.get("scheduler"),
            "est_makespan_s": self.schedule.get("est_makespan_s"),
            "jobs": {},
        }
        if self.provenance:
            out["cost_source"] = ("measured"
                                  if self.provenance.get("n_measured")
                                  else "analytic")
            out["n_measured_queries"] = self.provenance.get("n_measured", 0)
        for jp in self.jobs:
            rec: dict[str, Any] = {"kind": jp.kind, "arch": jp.arch["name"]}
            if jp.partition is not None:
                rec["n_shards"] = len(jp.partition["shards"])
                rec["host_mb"] = round(jp.host_bytes / 1e6, 1)
                rec["max_shard_mb"] = round(jp.max_shard_bytes / 1e6, 1)
            rec.update(jp.meta)
            out["jobs"][jp.job_id] = rec
        return out
