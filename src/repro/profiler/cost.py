"""``CostModel`` — one query surface for every price the planner needs.

Each query answers exactly the question an analytic call site used to
answer inline, and records *how* it answered in ``self.provenance``:

* ``shard_runtimes``       — the partitioner's initial per-shard runtime
  estimates (``core/partitioner.py``); analytic fallback reproduces
  ``flops_weight × param_bytes × 1e-12`` byte-identically.
* ``tok_seconds``          — the engine's per-token decode prior
  (``serving/engine.py``); analytic fallback is
  ``2e-10 × n_active_params``, measured answers interpolate the probe
  grid.
* ``prefill_seconds`` / ``decode_step_seconds`` — TTFT-style estimates
  over the measured (batch, seq) grid.
* ``transfer_seconds``     — host↔device movement cost from the measured
  bandwidth rows (latency + bytes/bw fit).
* ``hardware``             — the roofline constants via
  ``facts.hardware_constants`` (mesh/roofline satellite).
* ``draft_plan``           — auto-pick ``draft_model``/``draft_k`` for
  speculative decoding from measured draft-vs-target step times (the
  carried PR 5 follow-on).

Monotonicity: measured grids are clamped to a running max along both
axes before interpolation, so *more tokens are never cheaper* even when
a noisy probe says otherwise; bilinear interpolation preserves that
ordering between grid points and clamps flat beyond the grid.

Everything recorded in ``provenance`` is JSON-primitive (str/int/float/
list/dict), so a Plan carrying it round-trips byte-identically.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Any, Optional

from repro.profiler.facts import (ANALYTIC_HARDWARE, MachineFacts,
                                  StaleProfileWarning, hardware_constants)

# the two analytic priors the CostModel must reproduce byte-identically
# when unprofiled (see core/partitioner.py and serving/engine.py)
ANALYTIC_SHARD_SECONDS_PER_WEIGHTED_BYTE = 1e-12
ANALYTIC_TOK_SECONDS_PER_PARAM = 2e-10


def _monotone_grid(grid: list[list[float]]) -> list[list[float]]:
    """Running max along both axes: more batch / more seq never cheaper."""
    out = [list(row) for row in grid]
    for i in range(len(out)):
        for j in range(len(out[i])):
            if i > 0:
                out[i][j] = max(out[i][j], out[i - 1][j])
            if j > 0:
                out[i][j] = max(out[i][j], out[i][j - 1])
    return out


def _interp_1d(xs: list[float], x: float) -> tuple[int, int, float]:
    """Clamped segment + fraction for piecewise-linear interpolation."""
    if x <= xs[0]:
        return 0, 0, 0.0
    if x >= xs[-1]:
        return len(xs) - 1, len(xs) - 1, 0.0
    for i in range(len(xs) - 1):
        if xs[i] <= x <= xs[i + 1]:
            span = xs[i + 1] - xs[i]
            return i, i + 1, (x - xs[i]) / span if span else 0.0
    return len(xs) - 1, len(xs) - 1, 0.0


def _bilinear(batches: list[float], seqs: list[float],
              grid: list[list[float]], b: float, s: float) -> float:
    i0, i1, fb = _interp_1d(batches, b)
    j0, j1, fs = _interp_1d(seqs, s)
    top = grid[i0][j0] * (1 - fs) + grid[i0][j1] * fs
    bot = grid[i1][j0] * (1 - fs) + grid[i1][j1] * fs
    return top * (1 - fb) + bot * fb


@dataclass
class DraftChoice:
    """What ``draft_plan`` picked and why (plan-meta friendly)."""
    draft_cfg: Any
    draft_k: int
    record: dict


class CostModel:
    """Measured-when-possible, analytic-otherwise pricing with provenance."""

    def __init__(self, facts: Optional[MachineFacts] = None, *,
                 allow_stale: bool = False):
        """``allow_stale=True`` keeps a fingerprint-mismatched profile —
        the what-if case (``dryrun --plan --profile <other-machine.json>``
        deliberately prices against foreign facts); the default drops it
        with a warning so nothing silently plans with wrong numbers."""
        if facts is not None and not allow_stale and facts.is_stale():
            warnings.warn(
                "CostModel given stale MachineFacts (fingerprint mismatch); "
                "falling back to analytic pricing", StaleProfileWarning,
                stacklevel=2)
            facts = None
        self.facts = facts
        self.provenance: dict[str, dict] = {}
        # monotone-clamped interpolation tables, built once per family
        self._decode_tables: dict[str, dict] = {}

    # -- bookkeeping --------------------------------------------------------
    @property
    def measured(self) -> bool:
        return self.facts is not None

    def _note(self, key: str, source: str, value: float, **detail) -> None:
        rec = {"source": source, "value": value}
        rec.update(detail)
        self.provenance[key] = rec

    def provenance_summary(self) -> dict:
        """The Plan's ``provenance`` block: which facts priced what."""
        srcs = [r.get("source") for r in self.provenance.values()]
        return {
            "profile": None if self.facts is None else {
                "created_unix": self.facts.created_unix,
                "fingerprint": dict(self.facts.fingerprint),
                "decode_families": sorted(self.facts.decode),
            },
            "n_measured": srcs.count("measured"),
            "n_analytic": srcs.count("analytic"),
            "queries": dict(self.provenance),
        }

    # -- decode/prefill grids -----------------------------------------------
    def _family_table(self, cfg) -> Optional[dict]:
        """Monotone interpolation table for the cfg's family, scaled to the
        cfg's active-param count relative to the probed arch."""
        if self.facts is None:
            return None
        rec = self.facts.decode.get(cfg.family)
        if not rec:
            return None
        t = self._decode_tables.get(cfg.family)
        if t is None:
            batches = [float(b) for b in rec["batches"]]
            seqs = [float(s) for s in rec["seqs"]]
            step = _monotone_grid(rec["decode_step_s"])
            # prefill: monotone in TOTAL seconds (per-token cost may
            # legitimately fall with batch; total work may not)
            pre_total = _monotone_grid(
                [[rec["prefill_s_per_token"][i][j] * batches[i] * seqs[j]
                  for j in range(len(seqs))] for i in range(len(batches))])
            t = {"batches": batches, "seqs": seqs, "step": step,
                 "prefill_total": pre_total,
                 "probe_arch": rec.get("arch"),
                 "probe_params": max(1, int(rec.get("n_active_params", 1)))}
            self._decode_tables[cfg.family] = t
        return t

    def has_decode_facts(self, cfg) -> bool:
        return self._family_table(cfg) is not None

    def _scale(self, cfg, table: dict) -> float:
        return max(1, cfg.n_active_params) / table["probe_params"]

    def decode_step_seconds(self, cfg, batch: int, seq: int) -> float:
        """Seconds for one pooled decode step at (batch, seq)."""
        t = self._family_table(cfg)
        key = f"decode_step:{cfg.name}"
        if t is None:
            val = ANALYTIC_TOK_SECONDS_PER_PARAM \
                * max(1, cfg.n_active_params) * batch
            self._note(key, "analytic", val, batch=batch, seq=seq)
            return val
        val = _bilinear(t["batches"], t["seqs"], t["step"],
                        float(batch), float(seq)) * self._scale(cfg, t)
        self._note(key, "measured", val, batch=batch, seq=seq,
                   probe_arch=t["probe_arch"], family=cfg.family)
        return val

    def prefill_seconds(self, cfg, batch: int, seq: int) -> float:
        """Seconds to prefill ``batch`` prompts of ``seq`` tokens."""
        t = self._family_table(cfg)
        key = f"prefill:{cfg.name}"
        if t is None:
            val = ANALYTIC_TOK_SECONDS_PER_PARAM \
                * max(1, cfg.n_active_params) * batch * seq
            self._note(key, "analytic", val, batch=batch, seq=seq)
            return val
        val = _bilinear(t["batches"], t["seqs"], t["prefill_total"],
                        float(batch), float(seq)) * self._scale(cfg, t)
        self._note(key, "measured", val, batch=batch, seq=seq,
                   probe_arch=t["probe_arch"], family=cfg.family)
        return val

    def tok_seconds(self, cfg, max_seq: int = 256) -> float:
        """Per-token decode seconds — the engine's pre-EMA prior and the
        scheduler's TTFT/slack multiplier (serving/slo.py reads it through
        ``engine.tok_seconds_estimate``)."""
        t = self._family_table(cfg)
        key = f"tok_seconds:{cfg.name}"
        if t is None:
            val = ANALYTIC_TOK_SECONDS_PER_PARAM * max(1, cfg.n_active_params)
            self._note(key, "analytic", val)
            return val
        val = _bilinear(t["batches"], t["seqs"], t["step"],
                        1.0, float(max_seq)) * self._scale(cfg, t)
        self._note(key, "measured", val, max_seq=max_seq,
                   probe_arch=t["probe_arch"], family=cfg.family)
        return val

    # -- partitioner runtimes -----------------------------------------------
    def shard_runtimes(self, cfg, weights: list[float], *,
                       batch: int, seq: int) -> list[tuple[float, float]]:
        """Per-shard (fwd, bwd) runtime estimates for the partitioner.

        ``weights`` are the shards' ``flops_weight × param_bytes`` sums —
        the exact quantity the historical analytic estimate multiplied by
        1e-12.  Measured facts distribute a probed whole-model forward
        over the shards by the same weights, keeping relative shard order
        (what Sharded-LRTF ranks on) while fixing the absolute scale.
        """
        key = f"partition:{cfg.name}"
        t = self._family_table(cfg)
        if t is None:
            out = [(w * ANALYTIC_SHARD_SECONDS_PER_WEIGHTED_BYTE,
                    2 * (w * ANALYTIC_SHARD_SECONDS_PER_WEIGHTED_BYTE))
                   for w in weights]
            self._note(key, "analytic",
                       sum(f + b for f, b in out),
                       n_shards=len(weights), batch=batch, seq=seq)
            return out
        total_fwd = self.prefill_seconds(cfg, batch, seq)
        wsum = sum(weights) or 1.0
        out = [(total_fwd * w / wsum, 2 * total_fwd * w / wsum)
               for w in weights]
        self._note(key, "measured", sum(f + b for f, b in out),
                   n_shards=len(weights), batch=batch, seq=seq,
                   total_fwd_s=total_fwd, probe_arch=t["probe_arch"])
        return out

    # -- transfers + roofline constants --------------------------------------
    def transfer_seconds(self, nbytes: int, direction: str = "h2d") -> float:
        """Host↔device movement time for ``nbytes`` (latency + bw fit)."""
        key = f"transfer:{direction}"
        rows = (self.facts.transfer.get(direction)
                if self.facts is not None else None)
        if not rows:
            val = nbytes / ANALYTIC_HARDWARE["h2d_bw"]
            self._note(key, "analytic", val, nbytes=nbytes)
            return val
        rows = sorted(rows, key=lambda r: r["bytes"])
        lat = rows[0]["seconds"]
        big = rows[-1]
        if big["bytes"] > rows[0]["bytes"] and big["seconds"] > lat:
            bw = (big["bytes"] - rows[0]["bytes"]) / (big["seconds"] - lat)
        else:
            bw = big["bytes"] / max(big["seconds"], 1e-12)
        val = lat + nbytes / max(bw, 1.0)
        self._note(key, "measured", val, nbytes=nbytes,
                   fitted_bw_bytes_s=bw, latency_s=lat)
        return val

    def hardware(self) -> dict:
        """Roofline constants (+ source tag) through the facts schema."""
        hw = hardware_constants(self.facts)
        self._note("hardware", hw["source"],
                   hw["peak_flops_bf16"], **{
                       k: v for k, v in hw.items() if k != "source"})
        return hw

    # -- speculative-decode auto-pick -----------------------------------------
    def draft_plan(self, target_cfg, draft_cfg=None,
                   draft_k: Optional[int] = None,
                   accept_prior: float = 0.8,
                   max_k: int = 8) -> DraftChoice:
        """Pick ``draft_model``/``draft_k`` from draft-vs-target step times.

        With acceptance probability α per drafted token (greedy-exact
        acceptance), a round of k drafts yields E = (1-α^(k+1))/(1-α)
        tokens and costs k draft steps plus one batched target verify, so
        expected throughput is E / (k·t_draft + t_target) — maximized
        over candidates × k.  α prefers the machine profile's MEASURED
        per-family acceptance rate (``probe_accept_rates``); the fixed
        ``accept_prior`` is the provenance-tagged fallback for hosts that
        never probed (or probed before the probe existed).
        """
        t_target = self.tok_seconds(target_cfg)
        src = "measured" if self.has_decode_facts(target_cfg) else "analytic"
        accept_src, accept_meta = "prior", None
        if self.facts is not None:
            rec = (self.facts.accept_rates or {}).get(target_cfg.family)
            if rec and rec.get("accept_rate") is not None:
                accept_prior = float(rec["accept_rate"])
                accept_src = "measured"
                accept_meta = {k: rec.get(k)
                               for k in ("target", "draft", "draft_k",
                                         "rounds")}

        if draft_cfg is not None and draft_cfg != "auto":
            candidates = [draft_cfg]
        else:
            candidates = self._draft_candidates(target_cfg)
        ks = [draft_k] if isinstance(draft_k, int) else \
            list(range(1, max_k + 1))

        def expected_tokens(k: int) -> float:
            a = accept_prior
            return (1 - a ** (k + 1)) / (1 - a) if a < 1 else k + 1

        best = None
        considered = []
        for cand in candidates:
            t_draft = self.tok_seconds(cand)
            for k in ks:
                tput = expected_tokens(k) / (k * t_draft + t_target)
                considered.append({"draft": cand.name, "k": k,
                                   "tok_per_s": tput})
                if best is None or tput > best[0]:
                    best = (tput, cand, k, t_draft)
        assert best is not None
        _, cand, k, t_draft = best
        rec = {"source": src, "draft_model": cand.name, "draft_k": k,
               "t_target_s": t_target, "t_draft_s": t_draft,
               "accept_prior": accept_prior,
               "accept_source": accept_src,
               "accept_probe": accept_meta,
               "expected_tok_per_s": best[0],
               "n_candidates": len(candidates)}
        self.provenance[f"draft:{target_cfg.name}"] = rec
        return DraftChoice(draft_cfg=cand, draft_k=k, record=rec)

    def _draft_candidates(self, target_cfg) -> list:
        """Spec-draftable, vocab-compatible, no-bigger-than-target configs:
        registered archs first, then a shrunk clone of the target, then the
        target itself (self-draft — always valid)."""
        from repro.configs import ARCH_REGISTRY, SMOKE_REGISTRY
        from repro.models.registry import spec as family_spec
        out = []
        seen = set()
        for reg in (ARCH_REGISTRY, SMOKE_REGISTRY):
            for cfg in reg.values():
                if cfg.name in seen or cfg.name == target_cfg.name:
                    continue
                seen.add(cfg.name)
                if cfg.vocab_size != target_cfg.vocab_size:
                    continue
                if cfg.n_active_params > target_cfg.n_active_params:
                    continue
                if not family_spec(cfg).spec_draftable:
                    continue
                out.append(cfg)
        if family_spec(target_cfg).spec_draftable:
            if target_cfg.n_layers > 1:
                out.append(target_cfg.replace(
                    name=f"{target_cfg.name}-draft",
                    n_layers=max(1, target_cfg.n_layers // 4)))
            out.append(target_cfg)     # self-draft: the always-valid floor
        if not out:
            raise ValueError(
                f"no spec-draftable draft candidate shares "
                f"{target_cfg.name}'s vocab ({target_cfg.vocab_size}); pass "
                "draft_model=<ArchConfig> explicitly")
        return out
