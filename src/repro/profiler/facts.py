"""``MachineFacts`` — the versioned, JSON-serializable record of what the
profiler measured on THIS host (the doctor-facts pattern: probe once,
persist to ``results/profile_latest.json``, plan against the cached facts).

The schema is deliberately small and flat:

* ``fingerprint``  — platform/device identity; a loaded profile whose
  fingerprint no longer matches the running host is *stale* and every
  consumer falls back to the analytic constants (with a
  ``StaleProfileWarning``) rather than pricing plans with another
  machine's numbers.
* ``hardware``     — the roofline constants.  Defaults are the analytic
  v5e numbers that used to live in ``launch/mesh.py``; a profile may
  override them, and ``hardware_constants()`` is the one accessor both
  ``launch/mesh.py`` and ``launch/roofline.py`` read through.
* ``transfer``     — host↔device bandwidth rows (both directions, a few
  payload sizes) from ``probes.probe_transfer``.
* ``decode``       — per-family prefill/decode step latency over a small
  rectangular (batch, seq) grid from ``probes.probe_decode``.
* ``kernels``      — Pallas-vs-jnp-fallback micro-throughput from
  ``probes.probe_kernels``.

``CostModel`` (cost.py) interpolates these; everything here is pure data
plus (de)serialization, so importing this module never touches jax device
state (``current_fingerprint`` does, but only when called).
"""

from __future__ import annotations

import dataclasses
import json
import os
import platform
import time
import warnings
from dataclasses import dataclass, field
from typing import Optional

SCHEMA_VERSION = 1

DEFAULT_PATH = os.path.join("results", "profile_latest.json")

# -- analytic defaults ------------------------------------------------------
# v5e hardware constants (roofline).  These are THE analytic numbers: with
# no profile on disk, launch/mesh.py, launch/roofline.py, and CostModel all
# read exactly these values, so unprofiled plans reproduce the historical
# analytic plans byte-identically.
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
H2D_BW = 16e9                   # host<->device analytic prior (PCIe-class)

ANALYTIC_HARDWARE = {
    "peak_flops_bf16": PEAK_FLOPS_BF16,
    "hbm_bw": HBM_BW,
    "ici_bw": ICI_BW,
    "h2d_bw": H2D_BW,
}


class StaleProfileWarning(UserWarning):
    """A persisted profile's fingerprint no longer matches this host."""


def current_fingerprint() -> dict:
    """Identity of the running host+device, compared on profile load."""
    import jax
    devs = jax.devices()
    return {
        "backend": jax.default_backend(),
        "device_kind": devs[0].device_kind if devs else "none",
        "n_devices": len(devs),
        "jax": jax.__version__,
        "machine": platform.machine(),
        "system": platform.system(),
    }


@dataclass
class MachineFacts:
    """Everything the probes measured, ready to price a plan."""
    fingerprint: dict
    created_unix: float = 0.0
    schema_version: int = SCHEMA_VERSION
    hardware: dict = field(default_factory=lambda: dict(ANALYTIC_HARDWARE))
    transfer: dict = field(default_factory=dict)    # {"h2d":[rows],"d2h":[..]}
    decode: dict = field(default_factory=dict)      # family -> grid record
    kernels: dict = field(default_factory=dict)     # name -> timing record
    # family -> measured draft-acceptance record from probe_accept_rates
    # ({"target","draft","draft_k","accept_rate","rounds"}); absent for
    # profiles written before the probe existed (from_dict defaults it)
    accept_rates: dict = field(default_factory=dict)
    notes: dict = field(default_factory=dict)       # probe provenance/knobs

    # -- identity -----------------------------------------------------------
    def is_stale(self, fingerprint: Optional[dict] = None) -> bool:
        fp = fingerprint if fingerprint is not None else current_fingerprint()
        return fp != self.fingerprint

    # -- (de)serialization --------------------------------------------------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: dict) -> "MachineFacts":
        v = d.get("schema_version")
        if v != SCHEMA_VERSION:
            raise ValueError(
                f"unsupported MachineFacts schema_version {v!r} (this build "
                f"reads version {SCHEMA_VERSION}); re-run "
                "`python -m repro.profiler` to regenerate the profile")
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    @classmethod
    def from_json(cls, text: str) -> "MachineFacts":
        return cls.from_dict(json.loads(text))

    def save(self, path: str = DEFAULT_PATH) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(self.to_json(indent=1))
        return path

    @classmethod
    def load(cls, path: str = DEFAULT_PATH) -> "MachineFacts":
        with open(path) as f:
            return cls.from_json(f.read())

    # -- summaries ----------------------------------------------------------
    def age_seconds(self) -> float:
        return max(0.0, time.time() - self.created_unix)

    def summary(self) -> dict:
        return {
            "created_unix": self.created_unix,
            "fingerprint": self.fingerprint,
            "hardware": self.hardware,
            "transfer_points": {d: len(rows)
                                for d, rows in self.transfer.items()},
            "decode_families": sorted(self.decode),
            "kernels": sorted(self.kernels),
            "accept_rate_families": sorted(self.accept_rates),
        }


def load_facts(path: str = DEFAULT_PATH, *, missing_ok: bool = False,
               require_fresh: bool = True) -> Optional[MachineFacts]:
    """Load + staleness-gate a persisted profile.

    Returns None (never raises) when ``missing_ok`` and the file does not
    exist — the Session auto-load path, where "no profile yet" is normal.
    A stale profile returns None with a ``StaleProfileWarning`` so callers
    fall back to analytic pricing instead of trusting another machine's
    measurements.
    """
    if missing_ok and not os.path.exists(path):
        return None
    facts = MachineFacts.load(path)
    if require_fresh and facts.is_stale():
        warnings.warn(
            f"profile {path} was measured on "
            f"{facts.fingerprint.get('device_kind')!r} "
            f"({facts.fingerprint.get('backend')}/"
            f"{facts.fingerprint.get('jax')}) but this host is "
            f"{current_fingerprint().get('device_kind')!r} — ignoring it; "
            "re-run `python -m repro.profiler` to refresh",
            StaleProfileWarning, stacklevel=2)
        return None
    return facts


def hardware_constants(facts: Optional[MachineFacts] = None) -> dict:
    """The roofline constants, with their provenance tag.

    With no facts (or facts that never overrode hardware), this IS the
    analytic default table — byte-identical to the historical
    ``launch/mesh.py`` constants.
    """
    out = dict(ANALYTIC_HARDWARE)
    source = "analytic"
    if facts is not None:
        for k, v in (facts.hardware or {}).items():
            if k in out and v != out[k]:
                out[k] = v
                source = "measured"
    out["source"] = source
    return out
