"""``repro.profiler`` — measure the machine, price the plan.

Probes (``probes.py``) measure host↔device bandwidth, per-family
prefill/decode step latency, and kernel-vs-fallback throughput; the
results persist as a versioned ``MachineFacts`` JSON (``facts.py``,
``results/profile_latest.json`` by default); ``CostModel`` (``cost.py``)
answers the planner's pricing queries from the measured grids and falls
back to the historical analytic constants — byte-identically — when no
(fresh) profile exists.

    python -m repro.profiler            # probe + persist
    Session(..., profile="auto")        # plan against the cached facts

``build_facts`` is imported lazily: the probes pull in the serving stack,
which ``launch/mesh.py`` (a facts consumer) must never do at import time.
"""

from repro.profiler.cost import CostModel, DraftChoice
from repro.profiler.facts import (ANALYTIC_HARDWARE, DEFAULT_PATH,
                                  MachineFacts, StaleProfileWarning,
                                  current_fingerprint, hardware_constants,
                                  load_facts)

__all__ = ["ANALYTIC_HARDWARE", "CostModel", "DEFAULT_PATH", "DraftChoice",
           "MachineFacts", "StaleProfileWarning", "build_facts",
           "current_fingerprint", "hardware_constants", "load_facts"]


def build_facts(**kw):
    from repro.profiler.probes import build_facts as _build
    return _build(**kw)
