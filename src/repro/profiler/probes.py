"""Microbenchmark probes — the measurements behind ``MachineFacts``.

Three probe families, each with a ``quick`` mode sized for CI smoke:

* ``probe_transfer`` — host↔device bandwidth both directions at a few
  payload sizes (``jax.device_put`` / ``jax.device_get``), the number
  ZeRO-Infinity-style offload schedules live or die on.
* ``probe_decode``   — per-family prefill + pooled-decode step latency on
  a small rectangular (batch, seq) grid, driven through the real
  ``InferenceEngine``/``DecodeBackend`` surface (so the measurement
  includes admission, cache writes, and token materialization — the
  seconds a serving plan actually pays).  Timed steps exclude jit
  compilation: the first engine step compiles, later steps are timed via
  the engine's own ``decode_s``/``decode_steps`` counters; warm prefill
  is measured on a second admission wave that reuses the compiled
  (n, plen) prefill.
* ``probe_kernels``  — Pallas-kernel vs pure-jnp-fallback throughput for
  the ops with ``kernels/ref.py`` oracles (flash attention, rms_norm,
  swiglu), at tiny shapes (the Pallas interpreter is faithful but slow on
  CPU; on TPU the same probe times the Mosaic kernels).

``build_facts`` assembles a ``MachineFacts``; ``python -m repro.profiler``
is the CLI.
"""

from __future__ import annotations

import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.profiler.facts import MachineFacts, current_fingerprint

# one servable smoke arch per probe family (mirrors the backend smoke's
# map; encoder-decoder families are not servable, vlm shares the dense
# transformer decode path)
PROBE_FAMILY_ARCHS = {"dense": "qwen3-0.6b", "ssm": "xlstm-350m",
                      "hybrid": "zamba2-1.2b", "moe": "mixtral-8x22b"}


def _time_call(fn, *args, iters: int = 5) -> float:
    """Seconds per call, first (compiling) call excluded."""
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


# ---------------------------------------------------------------------------
# host <-> device transfer
# ---------------------------------------------------------------------------

def probe_transfer(*, quick: bool = False, iters: int = 3) -> dict:
    """Bandwidth rows per direction: [{"bytes", "seconds", "gbytes_per_s"}]."""
    sizes = [1 << 16, 1 << 20, 1 << 22] if quick else \
        [1 << 16, 1 << 20, 1 << 24, 1 << 26]
    dev = jax.devices()[0]
    h2d, d2h = [], []
    for n in sizes:
        host = np.ones(n, np.uint8)
        put = lambda: jax.block_until_ready(jax.device_put(host, dev))
        put()                                    # warm the path
        t0 = time.perf_counter()
        for _ in range(iters):
            put()
        s = (time.perf_counter() - t0) / iters
        h2d.append({"bytes": n, "seconds": s,
                    "gbytes_per_s": n / s / 1e9 if s else None})
        on_dev = jax.device_put(host, dev)
        jax.block_until_ready(on_dev)
        jax.device_get(on_dev)
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.device_get(on_dev)
        s = (time.perf_counter() - t0) / iters
        d2h.append({"bytes": n, "seconds": s,
                    "gbytes_per_s": n / s / 1e9 if s else None})
    return {"h2d": h2d, "d2h": d2h}


# ---------------------------------------------------------------------------
# per-family decode / prefill grid
# ---------------------------------------------------------------------------

def _probe_family_grid(cfg, params, batches: Sequence[int],
                       seqs: Sequence[int], iters: int) -> dict:
    from repro.serving import InferenceEngine
    step_grid = [[0.0] * len(seqs) for _ in batches]
    prefill_grid = [[0.0] * len(seqs) for _ in batches]
    for i, b in enumerate(batches):
        for j, s in enumerate(seqs):
            eng = InferenceEngine(cfg, params, capacity=b, max_seq=s,
                                  model_name=f"probe-{cfg.name}")
            plen = max(4, s // 4)
            prompts = [np.asarray(jax.random.randint(
                jax.random.PRNGKey(1000 + 17 * i + j * 3 + r), (plen,),
                0, cfg.vocab_size, jnp.int32)) for r in range(b)]
            # wave 1: first step compiles prefill+decode, later steps timed
            for p in prompts:
                eng.submit(p, iters + 2)
            eng.step()                           # compile, not timed
            d0, n0 = eng.decode_s, eng.decode_steps
            for _ in range(iters):
                eng.step()
            dn = eng.decode_steps - n0
            step_grid[i][j] = (eng.decode_s - d0) / max(1, dn)
            eng.run()                            # drain stragglers
            # wave 2: same (n, plen) group -> compiled prefill, warm timing
            p0, t0 = eng.prefill_s, eng.prefill_tokens
            for p in prompts:
                eng.submit(p, 1)
            eng.step()
            new_tok = eng.prefill_tokens - t0
            prefill_grid[i][j] = (eng.prefill_s - p0) / max(1, new_tok)
            eng.run()
    return {"arch": cfg.name,
            "n_active_params": int(cfg.n_active_params),
            "batches": list(batches), "seqs": list(seqs),
            "decode_step_s": step_grid,
            "prefill_s_per_token": prefill_grid}


def probe_decode(*, quick: bool = False,
                 families: Optional[Sequence[str]] = None,
                 iters: Optional[int] = None) -> dict:
    """Per-family (batch, seq) latency grids via the live engine surface.

    A family whose probe fails (unservable on this build, OOM, ...) is
    simply absent from the result — the CostModel falls back to analytic
    pricing for it, which is the contract everywhere else too.
    """
    from repro.models import api as mapi
    if families is None:
        families = ["dense"] if quick else list(PROBE_FAMILY_ARCHS)
    batches = [1, 2] if quick else [1, 2, 4]
    seqs = [32, 64] if quick else [64, 128, 256]
    iters = iters if iters is not None else (2 if quick else 5)
    out: dict[str, dict] = {}
    errors: dict[str, str] = {}
    for fam in families:
        arch = PROBE_FAMILY_ARCHS.get(fam)
        if arch is None:
            errors[fam] = f"no probe arch registered for family {fam!r}"
            continue
        try:
            from repro.configs import get_config
            cfg = get_config(arch, smoke=True)
            params = mapi.init_params(cfg, jax.random.PRNGKey(0))
            out[fam] = _probe_family_grid(cfg, params, batches, seqs, iters)
        except Exception as e:      # record, don't abort the whole profile
            errors[fam] = f"{type(e).__name__}: {e}"
    if errors:
        out["_errors"] = errors
    return out


# ---------------------------------------------------------------------------
# kernel vs jnp fallback
# ---------------------------------------------------------------------------

def probe_kernels(*, quick: bool = False, iters: int = 3) -> dict:
    """Per-kernel {ref_us, kernel_us, fallback_delta, rows_per_s} pairs.

    ``kernel_us`` times the ``kernels/ops.py`` entry point under its
    default impl for this backend (Mosaic on TPU, interpret elsewhere);
    ``ref_us`` times the pure-jnp oracle the engine falls back to.
    ``fallback_delta = ref_us / kernel_us`` (> 1 means the kernel wins).
    """
    from repro.kernels import ops, ref
    key = jax.random.PRNGKey(0)
    out: dict[str, dict] = {}

    # flash attention: ref layout (b, nh, s, hd); ops layout (b, s, nh, hd)
    b, s, nh, nkv, hd = (1, 32, 4, 2, 32) if quick else (1, 128, 8, 2, 64)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, nh, s, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, nkv, s, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, nkv, s, hd), jnp.float32)
    ref_s = _time_call(jax.jit(lambda q, k, v: ref.flash_attention_ref(
        q, k, v, causal=True)), q, k, v, iters=iters)
    kern_s = _time_call(
        lambda q, k, v: ops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True,
            block_q=min(32, s), block_k=min(32, s)),
        q, k, v, iters=iters)
    out["flash_attention"] = _kernel_row(ref_s, kern_s, rows=b * s)

    # rms_norm
    m, d = (64, 128) if quick else (512, 512)
    x = jax.random.normal(key, (m, d), jnp.float32)
    w = jnp.ones((d,), jnp.float32)
    ref_s = _time_call(jax.jit(ref.rms_norm_ref), x, w, iters=iters)
    kern_s = _time_call(lambda x, w: ops.rms_norm(x, w), x, w, iters=iters)
    out["rms_norm"] = _kernel_row(ref_s, kern_s, rows=m)

    # swiglu
    m, d, f = (64, 128, 256) if quick else (512, 512, 1024)
    ks = jax.random.split(key, 4)
    xm = jax.random.normal(ks[0], (m, d))
    wg = jax.random.normal(ks[1], (d, f)) * 0.05
    wu = jax.random.normal(ks[2], (d, f)) * 0.05
    wd = jax.random.normal(ks[3], (f, d)) * 0.05
    ref_s = _time_call(jax.jit(ref.swiglu_ref), xm, wg, wu, wd, iters=iters)
    kern_s = _time_call(lambda *a: ops.swiglu(*a), xm, wg, wu, wd,
                        iters=iters)
    out["swiglu"] = _kernel_row(ref_s, kern_s, rows=m)

    # paged decode hot path: shared block-table fixture for the four
    # paged kernels (decode attention, multi-query verify, fused layer,
    # int8-dequant attention)
    impl = "pallas" if jax.default_backend() == "tpu" else "pallas_interpret"
    n, nkv, g, hd, bs, B = (4, 2, 2, 32, 8, 4) if quick \
        else (8, 2, 4, 64, 16, 8)
    kk, P = 3, n * B + 1
    ks = jax.random.split(key, 4)
    kp = jax.random.normal(ks[0], (P, bs, nkv, hd), jnp.float32)
    vp = jax.random.normal(ks[1], (P, bs, nkv, hd), jnp.float32)
    qd = jax.random.normal(ks[2], (n, nkv * g, hd), jnp.float32)
    qv = jax.random.normal(ks[3], (n, kk, nkv * g, hd), jnp.float32)
    rng = np.random.default_rng(0)
    tables = jnp.asarray(
        (rng.permutation(P - 1)[: n * B] + 1).reshape(n, B), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, B * bs - kk, n), jnp.int32)

    ref_s = _time_call(jax.jit(ref.paged_attention_ref),
                       qd, kp, vp, tables, lengths, iters=iters)
    kern_s = _time_call(lambda *a: ops.paged_attention(*a, impl=impl),
                        qd, kp, vp, tables, lengths, iters=iters)
    out["paged_attention"] = _kernel_row(ref_s, kern_s, rows=n)

    ref_s = _time_call(jax.jit(ref.paged_verify_ref),
                       qv, kp, vp, tables, lengths, iters=iters)
    kern_s = _time_call(lambda *a: ops.paged_verify(*a, impl=impl),
                        qv, kp, vp, tables, lengths, iters=iters)
    out["paged_verify"] = _kernel_row(ref_s, kern_s, rows=n * kk)

    kq, ksc = ref.quantize_kv(kp)
    vq, vsc = ref.quantize_kv(vp)
    ref_s = _time_call(jax.jit(ref.paged_attention_quant_ref),
                       qd, kq, vq, ksc, vsc, tables, lengths, iters=iters)
    kern_s = _time_call(
        lambda *a: ops.paged_attention_quant(*a, impl=impl),
        qd, kq, vq, ksc, vsc, tables, lengths, iters=iters)
    out["paged_attention_quant"] = _kernel_row(ref_s, kern_s, rows=n)

    d = nkv * g * hd
    f = 2 * d
    ks = jax.random.split(key, 7)
    h = jax.random.normal(ks[0], (n, d))
    wo = jax.random.normal(ks[1], (nkv * g * hd, d)) * 0.05
    mscale = jax.random.normal(ks[2], (d,)) * 0.1 + 1.0
    wg2 = jax.random.normal(ks[3], (d, f)) * 0.05
    wu2 = jax.random.normal(ks[4], (d, f)) * 0.05
    wd2 = jax.random.normal(ks[5], (f, d)) * 0.05
    args = (h, qd, kp, vp, tables, lengths, wo, mscale, wg2, wu2, wd2)
    ref_s = _time_call(jax.jit(ref.fused_decode_layer_ref), *args,
                       iters=iters)
    kern_s = _time_call(lambda *a: ops.fused_decode_layer(*a, impl=impl),
                        *args, iters=iters)
    out["fused_decode_layer"] = _kernel_row(ref_s, kern_s, rows=n)
    return out


def _kernel_row(ref_s: float, kern_s: float, *, rows: int) -> dict:
    return {"ref_us": ref_s * 1e6, "kernel_us": kern_s * 1e6,
            "fallback_delta": ref_s / max(kern_s, 1e-12),
            "ref_rows_per_s": rows / max(ref_s, 1e-12),
            "kernel_rows_per_s": rows / max(kern_s, 1e-12),
            "default_impl": "pallas" if jax.default_backend() == "tpu"
            else "interpret"}


# ---------------------------------------------------------------------------
# draft-acceptance rates (speculative decode priors)
# ---------------------------------------------------------------------------

def probe_accept_rates(*, quick: bool = False) -> dict:
    """Measured greedy-exact draft-acceptance rate per spec-draftable
    family: a tiny spec workload with the canonical shrunk draft (the
    family's smoke arch at half depth, same vocab) through the real
    ``SpecDecodeBackend``.  ``CostModel.draft_plan`` prefers these over
    its fixed 0.8 prior — acceptance is a property of THIS model family's
    logit landscape, not a universal constant.

    A family whose probe fails is simply absent (the prior stays), the
    same degrade-to-analytic contract as ``probe_decode``.
    """
    from repro.configs import get_config
    from repro.models import api as mapi
    from repro.models.registry import spec as family_spec
    from repro.serving import InferenceEngine
    out: dict[str, dict] = {}
    errors: dict[str, str] = {}
    n_req, gen = (3, 6) if quick else (6, 12)
    for fam, arch in PROBE_FAMILY_ARCHS.items():
        fspec = family_spec(fam)
        if not (fspec.spec_draftable and fspec.servable):
            continue
        try:
            cfg = get_config(arch, smoke=True)
            draft_cfg = cfg.replace(n_layers=max(1, cfg.n_layers // 2),
                                    name=f"{cfg.name}-draft-probe")
            params = mapi.init_params(cfg, jax.random.PRNGKey(0))
            draft_params = mapi.init_params(draft_cfg, jax.random.PRNGKey(0))
            eng = InferenceEngine(cfg, params, capacity=min(4, n_req),
                                  max_seq=64, backend="spec",
                                  draft_cfg=draft_cfg,
                                  draft_params=draft_params, draft_k=3,
                                  model_name=f"accept-probe-{cfg.name}")
            for r in range(n_req):
                prompt = np.asarray(jax.random.randint(
                    jax.random.PRNGKey(7000 + r), (4 + r,), 0,
                    cfg.vocab_size, jnp.int32))
                eng.submit(prompt, gen)
            eng.run()
            s = eng.summary()
            out[fam] = {"target": cfg.name, "draft": draft_cfg.name,
                        "draft_k": 3,
                        "accept_rate": s["draft_accept_rate"],
                        "rounds": s["spec_rounds"]}
        except Exception as e:      # record, don't abort the profile
            errors[fam] = f"{type(e).__name__}: {e}"
    if errors:
        out["_errors"] = errors
    return out


# ---------------------------------------------------------------------------
# assembly
# ---------------------------------------------------------------------------

def build_facts(*, quick: bool = False,
                families: Optional[Sequence[str]] = None,
                skip_kernels: bool = False,
                skip_decode: bool = False) -> MachineFacts:
    """Run every probe and assemble one ``MachineFacts``."""
    facts = MachineFacts(fingerprint=current_fingerprint(),
                         created_unix=time.time())
    facts.notes = {"quick": bool(quick)}
    facts.transfer = probe_transfer(quick=quick)
    if not skip_decode:
        decode = probe_decode(quick=quick, families=families)
        facts.notes["decode_errors"] = decode.pop("_errors", {})
        facts.decode = decode
        accept = probe_accept_rates(quick=quick)
        facts.notes["accept_errors"] = accept.pop("_errors", {})
        facts.accept_rates = accept
    if not skip_kernels:
        facts.kernels = probe_kernels(quick=quick)
    return facts
