"""CLI: ``python -m repro.profiler`` — probe this machine, persist facts.

    python -m repro.profiler                      # full probes -> results/
    python -m repro.profiler --quick              # capped CI-sized probes
    python -m repro.profiler --show               # summarize cached profile
    python -m repro.profiler --smoke              # the `make profile-smoke`
        A/B: quick probes, then plan ONE workload twice (without and with
        the fresh facts), assert the plans' provenance differs (analytic
        vs measured pricing) while both executions stay token-identical —
        measured costs change estimates and explanations, never results.

The smoke prints one JSON line last (CI re-asserts from it, the repo's
self-asserting smoke pattern).
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.profiler import DEFAULT_PATH, MachineFacts, build_facts


def _smoke(out_path: str) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.api import ServeJob, Session, TrainJob
    from repro.core.sharp import HydraConfig
    from repro.configs import get_config

    facts = build_facts(quick=True, families=["dense"])
    facts.save(out_path)

    cfg = get_config("qwen3-0.6b", smoke=True)

    def loader():
        class L:
            def __iter__(self):
                def gen():
                    i = 0
                    while True:
                        from repro.models import api as mapi
                        yield mapi.make_dummy_batch(
                            cfg, 2, 32, key=jax.random.PRNGKey(i))
                        i += 1
                return gen()
        return L()

    prompts = [np.asarray(jax.random.randint(
        jax.random.PRNGKey(7 + i), (8,), 0, cfg.vocab_size, jnp.int32))
        for i in range(3)]

    def plan_and_run(profile):
        session = Session(HydraConfig(n_devices=2,
                                      device_budget_bytes=18 * 10**6),
                          profile=profile)
        session.submit(TrainJob(cfg, loader(), epochs=1, steps_per_epoch=2,
                                seed=0, batch=2, seq=32))
        sid = session.submit(ServeJob(cfg, seed=0, capacity=3, max_seq=64))
        plan = session.plan()
        # provenance must survive the wire: plan -> JSON -> plan
        from repro.api import Plan
        rt = Plan.from_json(plan.to_json())
        assert rt.provenance == plan.provenance, "provenance lost in JSON"
        reqs = [session.submit_request(sid, p, 5) for p in prompts]
        session.run(rt)
        toks = [list(map(int, r.generated)) for r in reqs]
        return plan, toks

    plan_a, toks_a = plan_and_run(None)          # unprofiled: analytic
    plan_b, toks_b = plan_and_run(facts)         # profiled: measured

    prov_a, prov_b = plan_a.provenance, plan_b.provenance
    assert prov_a["n_measured"] == 0, prov_a
    assert prov_a["profile"] is None, prov_a
    assert prov_b["n_measured"] > 0, prov_b
    assert prov_b["profile"] is not None, prov_b
    assert prov_a != prov_b, "profiled plan cites no different facts"
    assert toks_a == toks_b, (
        "measured-cost planning changed generated tokens — cost facts may "
        "only change estimates, never execution")

    rec = {
        "ok": True,
        "profile_path": out_path,
        "decode_families": sorted(facts.decode),
        "transfer_points": len(facts.transfer.get("h2d", [])),
        "kernels": sorted(facts.kernels),
        "analytic_queries_a": prov_a["n_analytic"],
        "measured_queries_b": prov_b["n_measured"],
        "provenance_differs": prov_a != prov_b,
        "tokens_identical": toks_a == toks_b,
        "est_makespan_analytic_s": plan_a.schedule.get("est_makespan_s"),
        "est_makespan_measured_s": plan_b.schedule.get("est_makespan_s"),
    }
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.profiler",
        description="measure this machine; persist MachineFacts JSON")
    ap.add_argument("--quick", action="store_true",
                    help="capped probe grids (CI-sized)")
    ap.add_argument("--out", default=DEFAULT_PATH,
                    help=f"facts path (default {DEFAULT_PATH})")
    ap.add_argument("--families", default=None,
                    help="comma list of decode-probe families "
                    "(default: all in full mode, dense in --quick)")
    ap.add_argument("--skip-kernels", action="store_true")
    ap.add_argument("--skip-decode", action="store_true")
    ap.add_argument("--show", action="store_true",
                    help="summarize an existing profile and exit")
    ap.add_argument("--smoke", action="store_true",
                    help="profile-smoke A/B (see module docstring)")
    args = ap.parse_args(argv)

    if args.show:
        facts = MachineFacts.load(args.out)
        print(json.dumps(facts.summary(), indent=1))
        return 0

    if args.smoke:
        out = args.out if args.out != DEFAULT_PATH \
            else "results/profile_smoke.json"
        rec = _smoke(out)
        print(json.dumps({"profile_smoke": rec}))
        return 0

    fams = [f.strip() for f in args.families.split(",")] \
        if args.families else None
    facts = build_facts(quick=args.quick, families=fams,
                        skip_kernels=args.skip_kernels,
                        skip_decode=args.skip_decode)
    path = facts.save(args.out)
    print(json.dumps(facts.summary(), indent=1))
    print(f"profile -> {path}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
