"""Fixed-capacity slot pool over stacked per-request decode states.

The pool pytree holds every leaf of a batch=1 ``api.init_decode_state`` tree
with an extra leading slot axis ``(S, ...)``; slot ``s`` is bit-for-bit the
state of a lone batch=1 request.  The engine vmaps the decode step over the
slot axis, so continuous batching is numerically identical to running each
request alone (tests/test_serving.py checks exact token equality), while
still compiling to ONE fixed-shape program — joins and evictions never
retrace the decode step.

Slot writes go through ``.at[slots].set`` scatters; a freed slot keeps its
stale state until the next admission overwrites the whole slice with a
freshly prefilled one, so nothing ever leaks between occupants.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import api


def stack_trees(trees):
    """[tree, ...] -> one tree with a new leading axis on every leaf."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


@partial(jax.jit, donate_argnums=(0,))
def _scatter_slots(pool, sub, idx):
    return jax.tree.map(lambda p, s: p.at[idx].set(s.astype(p.dtype)),
                        pool, sub)


def write_slots(pool, sub, slot_ids):
    """Scatter ``sub`` (leading axis n) into ``pool`` rows ``slot_ids``.

    Jitted with the pool donated so XLA updates the slot rows in place —
    un-jitted, every ``.at[].set`` would copy the whole stacked KV cache
    once per admission group."""
    return _scatter_slots(pool, sub, jnp.asarray(slot_ids, jnp.int32))


class SlotPool:
    """Free-list of decode-state slots + the stacked state itself."""

    def __init__(self, cfg, capacity: int, max_seq: int):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.max_seq = max_seq
        self._fresh = api.init_decode_state(cfg, 1, max_seq)
        self.state = stack_trees([self._fresh] * capacity)
        # pop() hands out low slot ids first (stable layouts in tests)
        self._free = list(range(capacity - 1, -1, -1))
        self.occupant: dict[int, str] = {}          # slot -> request_id

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.capacity - len(self._free)

    def alloc(self, request_id: str) -> int:
        if not self._free:
            # without this guard an exhausted pool surfaces as a bare
            # IndexError from list.pop — useless at the admission call site
            raise RuntimeError(
                f"SlotPool exhausted: all {self.capacity} slots occupied "
                f"({len(self.occupant)} active requests); admission must "
                "check n_free before alloc")
        slot = self._free.pop()
        self.occupant[slot] = request_id
        return slot

    def free(self, slot: int) -> None:
        del self.occupant[slot]
        self._free.append(slot)

    def fresh_states(self, n: int):
        """Stacked zero states for ``n`` requests about to be prefilled."""
        return stack_trees([self._fresh] * n)
