"""Continuous-batching inference engine: fixed slot pool or paged KV cache.

One engine serves one loaded model.  Per tick (``step()``):

  1. retire finished requests (free slot/blocks, release KV budget),
  2. admit queued requests while the KV budget allows — each admission
     group is prefilled in ONE jitted call (``make_prefill_into_cache``
     vmapped over same-length prompts) and scattered into the pool,
  3. run ONE pooled decode step so every active request advances a token.

Requests therefore join and leave between decode steps without ever
retracing or perturbing in-flight lanes; outputs are token-identical to
running each request alone (tests/test_serving.py).

Two decode-state layouts share this lifecycle:

* **Slot pool** (default): every request owns a ``max_seq``-sized stacked
  decode state; admission charges a constant ``slot_bytes``.  Works for
  every servable family.
* **Paged** (``paged=True``, dense/vlm): K/V lives in a ``BlockPool`` of
  fixed-size blocks; admission reserves only the blocks the request's
  actual prompt + decode budget can touch (against a ``DeviceMemory``
  ledger — shareable with SHARP training), prefill scatters into pages,
  and the decode step reads K/V through per-lane block tables
  (``kernels/paged_attention.py`` on TPU, pure-jnp gather elsewhere).
  Short-prompt workloads admit strictly more concurrency under the same
  byte budget.  Families the paged step cannot cover token-identically
  (recurrent: O(1) state, nothing to page; moe: expert capacity couples
  lanes) silently keep the slot pool, mirroring the bucketing fallback.
"""

from __future__ import annotations

import time
from functools import lru_cache
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.serving.paging import (BlockPool, blocks_for_rows,
                                  default_n_blocks)
from repro.serving.queue import KVBudget, PagedKVBudget, RequestQueue
from repro.serving.request import Request, Status
from repro.serving.slots import SlotPool, stack_trees, write_slots
from repro.training.train_loop import (make_decode_step,
                                       make_paged_decode_step,
                                       make_padded_prefill_into_cache,
                                       make_prefill_into_cache)


@lru_cache(maxsize=None)
def _compiled_steps(cfg, window):
    """Per-(cfg, window) jitted programs, shared across engine instances so
    a fresh engine for an already-loaded model never recompiles.  The state
    argument is donated: the pre-step pool state is dead after each call,
    and donation lets XLA update the KV cache in place instead of copying
    the whole pool every tick."""
    decode = jax.jit(jax.vmap(make_decode_step(cfg, window=window),
                              in_axes=(None, 0, 0)), donate_argnums=(1,))
    prefill = jax.jit(jax.vmap(make_prefill_into_cache(cfg, window=window),
                               in_axes=(None, 0, 0)), donate_argnums=(1,))
    return decode, prefill


@lru_cache(maxsize=None)
def _compiled_padded_prefill(cfg, window):
    """Bucketed prefill: tokens padded to a bucket length, per-request true
    lengths passed alongside.  Retraces per (n, bucket), not per (n, plen)."""
    return jax.jit(jax.vmap(make_padded_prefill_into_cache(cfg, window=window),
                            in_axes=(None, 0, 0, 0)), donate_argnums=(1,))


@lru_cache(maxsize=None)
def _compiled_paged_decode(cfg, window, impl):
    """One-token decode through block tables, pages donated in place."""
    return jax.jit(make_paged_decode_step(cfg, window=window, impl=impl),
                   donate_argnums=(1,))


@lru_cache(maxsize=None)
def _compiled_page_scatter(block_size):
    """Scatter freshly prefilled contiguous KV rows into physical blocks.

    k/v_new: (n, L, 1, W, nkv, hd) stacked prefill output, W a multiple of
    ``block_size``; ids: (n * W/bs,) physical block per logical block, all
    requests concatenated.  Pages are donated — the scatter updates the
    pool in place instead of copying every page per admission."""
    def scatter(kp, vp, k_new, v_new, ids):
        n, L, _, W, nkv, hd = k_new.shape
        nb = W // block_size

        def resh(a):
            a = a[:, :, 0].transpose(1, 0, 2, 3, 4)        # (L, n, W, kv, hd)
            return a.reshape(L, n * nb, block_size, nkv, hd)

        kp = kp.at[:, ids].set(resh(k_new).astype(kp.dtype))
        vp = vp.at[:, ids].set(resh(v_new).astype(vp.dtype))
        return kp, vp

    return jax.jit(scatter, donate_argnums=(0, 1))


def pow2_buckets(max_seq: int) -> tuple[int, ...]:
    """Power-of-two length buckets covering [1, max_seq]."""
    out, b = [], 1
    while b < max_seq:
        out.append(b)
        b *= 2
    out.append(max_seq)
    return tuple(out)


class InferenceEngine:
    def __init__(self, cfg, params, *, capacity: int = 8,
                 max_seq: int = 256, kv_budget_bytes: Optional[int] = None,
                 window: Optional[int] = None,
                 model_name: Optional[str] = None,
                 bucket_sizes: Optional[Sequence[int]] = None,
                 paged: bool = False, block_size: int = 16,
                 n_blocks: Optional[int] = None, ledger=None,
                 paged_impl: Optional[str] = None,
                 clock=time.perf_counter):
        if cfg.is_encoder_decoder:
            # encdec decode states need real encoder output; init_decode_state
            # with enc_out=None zero-fills the cross-attn cache and every
            # generated token would silently condition on nothing
            raise ValueError(
                f"{cfg.name}: encoder-decoder families are not servable "
                "through InferenceEngine (no encoder-output path yet)")
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.cfg = cfg
        self.params = params
        self.model_name = model_name or cfg.name
        self.clock = clock
        self.capacity = capacity
        self.max_seq = max_seq
        self.queue = RequestQueue(clock=clock)
        self.slot_bytes = api.decode_state_bytes(cfg, 1, max_seq)
        self._decode, self._prefill = _compiled_steps(cfg, window)
        # families whose decode state is not a pure lane-independent KV
        # cache silently keep the slot pool (mirrors the bucketing fallback)
        self.paged = bool(paged) and api.supports_paging(cfg)
        if self.paged:
            self._init_paged(kv_budget_bytes, block_size, n_blocks, ledger,
                             paged_impl, window)
        else:
            self.pool = SlotPool(cfg, capacity, max_seq)
            self.budget = KVBudget(kv_budget_bytes, self.slot_bytes)
            self.ledger = None
        # length-bucketed admission: pad prompt groups to the next bucket so
        # prefill retraces are bounded per (n, bucket) instead of per
        # (n, plen).  Families whose padded prefill is not token-identical
        # (recurrent: no rewind; moe: pad tokens steal expert capacity)
        # silently keep exact-length groups.
        if bucket_sizes is not None and not api.supports_padded_prefill(cfg):
            bucket_sizes = None
        if bucket_sizes is not None:
            # a bucket cannot outsize the cache; overlong prompts fall back
            # to exact-length groups via _bucket
            bucket_sizes = [b for b in bucket_sizes if 0 < b <= max_seq]
        self.bucket_sizes = (tuple(sorted(set(bucket_sizes)))
                             if bucket_sizes else None)
        self._padded_prefill = (_compiled_padded_prefill(cfg, window)
                                if self.bucket_sizes else None)
        self._active: dict[int, Request] = {}       # lane -> request
        self._tokens = np.zeros((capacity, 1, 1), np.int32)
        self.completed: list[Request] = []
        # engine-level counters (JSON summary)
        self.decode_steps = 0
        self.decode_tokens = 0       # tokens from decode steps (not prefill)
        self.prefill_calls = 0
        self.prefill_tokens = 0
        self.decode_s = 0.0
        self.prefill_s = 0.0
        self.peak_concurrency = 0
        self._tok_s_ema: Optional[float] = None     # per-token decode seconds

    def _init_paged(self, kv_budget_bytes, block_size, n_blocks, ledger,
                    paged_impl, window) -> None:
        from repro.core.spilling import DeviceMemory
        from repro.kernels import ops as kops
        if ledger is not None and kv_budget_bytes is not None:
            raise ValueError(
                "pass either a shared DeviceMemory ledger or a private "
                "kv_budget_bytes, not both")
        self.block_size = block_size
        self.max_blocks = blocks_for_rows(self.max_seq, block_size)
        block_bytes = api.kv_block_bytes(self.cfg, block_size)
        worst = default_n_blocks(self.capacity, self.max_seq, block_size,
                                 n_blocks)
        if ledger is None:
            budget = (kv_budget_bytes if kv_budget_bytes is not None
                      else (worst - 1) * block_bytes)
            if budget < block_bytes:
                raise ValueError(
                    f"KV budget {budget} B below one block "
                    f"({block_bytes} B): nothing could ever be admitted")
            ledger = DeviceMemory(-1, budget)
        self.ledger = ledger
        if n_blocks is None:
            # never materialize pages the byte budget can't admit anyway:
            # cap the physical pool at the budget's worth of blocks
            worst = max(2, min(worst,
                               int(ledger.budget) // block_bytes + 1))
        self.pool = BlockPool(self.cfg, worst, block_size)
        self.budget = PagedKVBudget(ledger, self.pool.block_bytes)
        self.paged_impl = paged_impl or kops.default_paged_impl()
        self._paged_decode = _compiled_paged_decode(self.cfg, window,
                                                    self.paged_impl)
        self._page_scatter = _compiled_page_scatter(block_size)
        self._tables = np.full((self.capacity, self.max_blocks),
                               BlockPool.GARBAGE, np.int32)
        self._lengths = np.zeros((self.capacity,), np.int32)
        self._lane_free = list(range(self.capacity - 1, -1, -1))
        self._lane_blocks: dict[int, list[int]] = {}
        self._committed_blocks = 0   # sum of active reservations, in blocks
        self._fresh_by_width: dict[int, object] = {}

    # -- submission ---------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int, *,
               request_id: str = "", eos_id: Optional[int] = None,
               arrival_time: Optional[float] = None) -> Request:
        req = Request(prompt=prompt, max_new_tokens=max_new_tokens,
                      request_id=request_id, eos_id=eos_id,
                      model=self.model_name, arrival_time=arrival_time)
        # rows actually written: plen at prefill + one per decode step; the
        # final generated token is sampled but never fed back into the cache
        if req.prompt_len + req.max_new_tokens - 1 > self.max_seq:
            raise ValueError(
                f"prompt+generation exceeds engine max_seq={self.max_seq}")
        if self.paged:
            # a reservation that can NEVER fit would sit at the head of the
            # FIFO forever and livelock admission — reject it up front
            nb = self._blocks_for(req)
            if nb > self.pool.n_allocatable \
                    or nb * self.pool.block_bytes > self.ledger.budget:
                raise ValueError(
                    f"request needs {nb} KV blocks "
                    f"({nb * self.pool.block_bytes} B) but the engine can "
                    f"never admit more than {self.pool.n_allocatable} "
                    f"blocks / {self.ledger.budget} B — raise the KV "
                    "budget or lower max_new_tokens")
        return self.queue.push(req)

    # -- introspection ------------------------------------------------------
    def active_requests(self) -> Sequence[Request]:
        return list(self._active.values())

    def queued_requests(self) -> Sequence[Request]:
        return list(self.queue)

    def has_work(self) -> bool:
        return bool(self._active or self.queue)

    @property
    def n_free_lanes(self) -> int:
        return len(self._lane_free) if self.paged else self.pool.n_free

    def tok_seconds_estimate(self) -> float:
        """Measured per-token decode seconds (EMA); cost-model prior until
        the first step so multi-model LRTF can rank engines immediately."""
        if self._tok_s_ema is not None:
            return self._tok_s_ema
        return 2e-10 * max(self.cfg.n_active_params, 1)

    def remaining_seconds(self) -> float:
        """LRTF input: remaining decode work (active + queued), seconds."""
        rem = sum(r.remaining_tokens() for r in self._active.values())
        # queued requests also owe their prefill; charge it as tokens
        rem += sum(r.max_new_tokens + r.prompt_len for r in self.queue)
        return rem * self.tok_seconds_estimate()

    # -- engine tick --------------------------------------------------------
    def _retire_finished(self) -> None:
        for lane, req in list(self._active.items()):
            if req.done:
                req.status = Status.FINISHED
                req.finish_time = self.clock()
                req.slot = None
                if self.paged:
                    self.pool.free(self._lane_blocks.pop(lane))
                    self._tables[lane, :] = BlockPool.GARBAGE
                    self._lengths[lane] = 0
                    self.budget.release(req.reserved_blocks)
                    self._committed_blocks -= req.reserved_blocks
                    self._lane_free.append(lane)
                else:
                    self.pool.free(lane)
                    self.budget.release()
                del self._active[lane]
                self.completed.append(req)

    def _bucket(self, plen: int) -> int:
        """Admission group key: smallest bucket >= plen (exact length when
        bucketing is off or the prompt outgrows every bucket)."""
        if self.bucket_sizes:
            for b in self.bucket_sizes:
                if b >= plen:
                    return b
        return plen

    # -- paged admission sizing ---------------------------------------------
    def _prefill_rows(self, plen: int) -> int:
        """Contiguous rows the prefill writes, rounded up to whole blocks
        (the scatter moves whole blocks; the round-up tail is masked)."""
        return blocks_for_rows(self._bucket(plen),
                               self.block_size) * self.block_size

    def _blocks_for(self, req: Request) -> int:
        """Reservation: blocks for the WORST CASE this request can touch —
        its prefill footprint or its full decode extent, whichever is
        larger.  Reserved up front so lazy growth can never fail; pages are
        only physically allocated as decode crosses block boundaries."""
        rows = max(self._prefill_rows(req.prompt_len),
                   req.prompt_len + req.max_new_tokens - 1)
        return blocks_for_rows(rows, self.block_size)

    def _admit(self) -> list[Request]:
        admitted: list[Request] = []
        while self.queue and self.n_free_lanes:
            if self.paged:
                req = self.queue.peek()
                nb = self._blocks_for(req)
                # both guarantees up front: ledger bytes AND physical
                # blocks, so mid-flight growth can never fail
                if self._committed_blocks + nb > self.pool.n_allocatable:
                    break
                if not self.budget.reserve(nb):
                    break
                self.queue.pop()
                req.reserved_blocks = nb
                self._committed_blocks += nb
                lane = self._lane_free.pop()
                nb0 = self._prefill_rows(req.prompt_len) // self.block_size
                ids = self.pool.alloc(nb0)
                self._lane_blocks[lane] = ids
                self._tables[lane, :] = BlockPool.GARBAGE
                self._tables[lane, :nb0] = ids
                self._lengths[lane] = 0
                req.peak_blocks = nb0
                req.slot = lane
            else:
                if not self.budget.reserve():
                    break
                req = self.queue.pop()
                req.slot = self.pool.alloc(req.request_id)
            req.admit_time = self.clock()
            req.status = Status.RUNNING
            admitted.append(req)
        if not admitted:
            return admitted
        # one jitted prefill per same-length group — (n, 1, plen) tokens over
        # n stacked fresh batch=1 states — or per same-*bucket* group when
        # length bucketing is on (mixed plens share one padded call)
        by_len: dict[int, list[Request]] = {}
        for req in admitted:
            by_len.setdefault(self._bucket(req.prompt_len), []).append(req)
        for plen, group in sorted(by_len.items()):
            states = self._fresh_states(len(group), plen)
            t0 = self.clock()
            if self.bucket_sizes:
                tokens = jnp.asarray(np.stack(
                    [np.pad(r.prompt, (0, plen - r.prompt_len))
                     for r in group])[:, None, :])
                lengths = jnp.asarray([r.prompt_len for r in group], jnp.int32)
                logits, states = self._padded_prefill(
                    self.params, states, tokens, lengths)
            else:
                tokens = jnp.asarray(
                    np.stack([r.prompt for r in group])[:, None, :])
                logits, states = self._prefill(self.params, states, tokens)
            logits = jax.block_until_ready(logits)
            self.prefill_s += self.clock() - t0
            self.prefill_calls += 1
            # true prompt tokens, not the padded bucket width — keeps
            # prefill_tok_per_s comparable between bucketed and exact modes
            self.prefill_tokens += sum(r.prompt_len for r in group)
            if self.paged:
                self._scatter_prefill(group, states)
            else:
                slots = [r.slot for r in group]
                self.pool.state = write_slots(self.pool.state, states, slots)
            first = np.asarray(jnp.argmax(logits, axis=-1), np.int32)  # (n, 1)
            now = self.clock()
            for i, req in enumerate(group):
                tok = int(first[i, 0])
                req.generated.append(tok)
                req.first_token_time = now
                self._tokens[req.slot, 0, 0] = tok
                self._active[req.slot] = req
                if self.paged:
                    self._lengths[req.slot] = req.prompt_len
        return admitted

    def _fresh_states(self, n: int, width_key: int):
        """Stacked zero states for ``n`` requests about to be prefilled.

        Slot mode: full ``max_seq``-wide slots (scattered into the pool).
        Paged mode: transient block-aligned width — just wide enough for
        the prompt group; the rows are scattered into pages and the
        temporary is dropped, so peak transient bytes stay O(prompt)."""
        if not self.paged:
            return self.pool.fresh_states(n)
        width = blocks_for_rows(width_key, self.block_size) * self.block_size
        tmpl = self._fresh_by_width.get(width)
        if tmpl is None:
            tmpl = api.init_decode_state(self.cfg, 1, width)
            self._fresh_by_width[width] = tmpl
        return stack_trees([tmpl] * n)

    def _scatter_prefill(self, group, states) -> None:
        """Move a prefilled contiguous group into the block pool pages."""
        ids = np.concatenate([self._lane_blocks[r.slot] for r in group])
        kp, vp = self._page_scatter(
            self.pool.pages["k"], self.pool.pages["v"],
            states["kv"]["k"], states["kv"]["v"],
            jnp.asarray(ids, jnp.int32))
        self.pool.pages = {"k": kp, "v": vp}

    def _grow_tables(self) -> None:
        """Allocate the block the next decode row lands in, lane by lane —
        the admission reservation guarantees this can never fail."""
        for lane in self._active:
            need = int(self._lengths[lane]) // self.block_size + 1
            blocks = self._lane_blocks[lane]
            while len(blocks) < need:
                (bid,) = self.pool.alloc(1)
                self._tables[lane, len(blocks)] = bid
                blocks.append(bid)
                req = self._active[lane]
                req.peak_blocks = max(req.peak_blocks or 0, len(blocks))

    def step(self) -> bool:
        """One engine tick; returns True while there is work left."""
        self._retire_finished()
        self._admit()
        self._retire_finished()      # single-token requests finish at prefill
        self.peak_concurrency = max(self.peak_concurrency, len(self._active))
        if self._active:
            t0 = self.clock()
            if self.paged:
                self._grow_tables()
                ntoks, self.pool.pages = self._paged_decode(
                    self.params, self.pool.pages,
                    jnp.asarray(self._tables), jnp.asarray(self._lengths),
                    jnp.asarray(self._tokens[:, 0, :]))
                ntoks = np.array(jax.block_until_ready(ntoks),
                                 np.int32)[:, None, :]
            else:
                toks = jnp.asarray(self._tokens)
                ntoks, self.pool.state = self._decode(self.params,
                                                      self.pool.state, toks)
                # np.array (copy): asarray of a jax array is a read-only
                # view, and admission writes freshly prefilled tokens into
                # this buffer
                ntoks = np.array(jax.block_until_ready(ntoks), np.int32)
            dt = self.clock() - t0
            self.decode_s += dt
            self.decode_steps += 1
            self.decode_tokens += len(self._active)
            per_tok = dt / max(len(self._active), 1)
            self._tok_s_ema = (per_tok if self._tok_s_ema is None
                               else 0.8 * self._tok_s_ema + 0.2 * per_tok)
            self._tokens = ntoks
            for lane, req in self._active.items():
                req.generated.append(int(ntoks[lane, 0, 0]))
                if self.paged:
                    self._lengths[lane] += 1
        return self.has_work()

    def run(self, max_steps: Optional[int] = None) -> list[Request]:
        """Drive to completion; returns requests completed during the call."""
        done_before = len(self.completed)
        steps = 0
        while self.step():
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        self._retire_finished()
        return self.completed[done_before:]

    # -- metrics ------------------------------------------------------------
    def summary(self) -> dict:
        out = {
            "model": self.model_name,
            "capacity": self.capacity,
            "max_seq": self.max_seq,
            "paged": self.paged,
            "bucket_sizes": list(self.bucket_sizes)
                if self.bucket_sizes else None,
            "slot_bytes": self.slot_bytes,
            "kv_budget_bytes": self.budget.budget_bytes,
            "kv_peak_bytes": self.budget.peak_bytes,
            "peak_concurrency": self.peak_concurrency,
            "n_completed": len(self.completed),
            "decode_steps": self.decode_steps,
            "prefill_calls": self.prefill_calls,
            "prefill_tok_per_s": round(
                self.prefill_tokens / self.prefill_s, 1)
                if self.prefill_s else None,
            "decode_tok_per_s": round(self.decode_tokens / self.decode_s, 1)
                if self.decode_s else None,
        }
        if self.paged:
            out.update(
                block_size=self.block_size,
                block_bytes=self.pool.block_bytes,
                n_blocks=self.pool.n_blocks,
                kv_page_peak_bytes=self.pool.peak_bytes(),
                kv_block_allocs=self.pool.total_allocs,
                paged_impl=self.paged_impl,
            )
        return out
