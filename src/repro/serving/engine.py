"""Continuous-batching inference engine over a fixed slot pool.

One engine serves one loaded model.  Per tick (``step()``):

  1. retire finished requests (free slot, release KV budget),
  2. admit queued requests into free slots while the KV budget allows —
     each admission group is prefilled in ONE jitted call
     (``make_prefill_into_cache`` vmapped over same-length prompts) and
     scattered into the pool,
  3. run ONE pooled decode step: the greedy decode step vmapped over the
     slot axis, so every active request advances one token.

Requests therefore join and leave between decode steps without ever
retracing or perturbing in-flight slots; outputs are token-identical to
running each request alone (tests/test_serving.py).
"""

from __future__ import annotations

import time
from functools import lru_cache
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.serving.queue import KVBudget, RequestQueue
from repro.serving.request import Request, Status
from repro.serving.slots import SlotPool, write_slots
from repro.training.train_loop import (make_decode_step,
                                       make_padded_prefill_into_cache,
                                       make_prefill_into_cache)


@lru_cache(maxsize=None)
def _compiled_steps(cfg, window):
    """Per-(cfg, window) jitted programs, shared across engine instances so
    a fresh engine for an already-loaded model never recompiles.  The state
    argument is donated: the pre-step pool state is dead after each call,
    and donation lets XLA update the KV cache in place instead of copying
    the whole pool every tick."""
    decode = jax.jit(jax.vmap(make_decode_step(cfg, window=window),
                              in_axes=(None, 0, 0)), donate_argnums=(1,))
    prefill = jax.jit(jax.vmap(make_prefill_into_cache(cfg, window=window),
                               in_axes=(None, 0, 0)), donate_argnums=(1,))
    return decode, prefill


@lru_cache(maxsize=None)
def _compiled_padded_prefill(cfg, window):
    """Bucketed prefill: tokens padded to a bucket length, per-request true
    lengths passed alongside.  Retraces per (n, bucket), not per (n, plen)."""
    return jax.jit(jax.vmap(make_padded_prefill_into_cache(cfg, window=window),
                            in_axes=(None, 0, 0, 0)), donate_argnums=(1,))


def pow2_buckets(max_seq: int) -> tuple[int, ...]:
    """Power-of-two length buckets covering [1, max_seq]."""
    out, b = [], 1
    while b < max_seq:
        out.append(b)
        b *= 2
    out.append(max_seq)
    return tuple(out)


class InferenceEngine:
    def __init__(self, cfg, params, *, capacity: int = 8,
                 max_seq: int = 256, kv_budget_bytes: Optional[int] = None,
                 window: Optional[int] = None,
                 model_name: Optional[str] = None,
                 bucket_sizes: Optional[Sequence[int]] = None,
                 clock=time.perf_counter):
        if cfg.is_encoder_decoder:
            # encdec decode states need real encoder output; init_decode_state
            # with enc_out=None zero-fills the cross-attn cache and every
            # generated token would silently condition on nothing
            raise ValueError(
                f"{cfg.name}: encoder-decoder families are not servable "
                "through InferenceEngine (no encoder-output path yet)")
        self.cfg = cfg
        self.params = params
        self.model_name = model_name or cfg.name
        self.clock = clock
        self.pool = SlotPool(cfg, capacity, max_seq)
        self.queue = RequestQueue(clock=clock)
        self.slot_bytes = api.decode_state_bytes(cfg, 1, max_seq)
        self.budget = KVBudget(kv_budget_bytes, self.slot_bytes)
        self._decode, self._prefill = _compiled_steps(cfg, window)
        # length-bucketed admission: pad prompt groups to the next bucket so
        # prefill retraces are bounded per (n, bucket) instead of per
        # (n, plen).  Families whose padded prefill is not token-identical
        # (recurrent: no rewind; moe: pad tokens steal expert capacity)
        # silently keep exact-length groups.
        if bucket_sizes is not None and not api.supports_padded_prefill(cfg):
            bucket_sizes = None
        if bucket_sizes is not None:
            # a bucket cannot outsize the cache; overlong prompts fall back
            # to exact-length groups via _bucket
            bucket_sizes = [b for b in bucket_sizes if 0 < b <= max_seq]
        self.bucket_sizes = (tuple(sorted(set(bucket_sizes)))
                             if bucket_sizes else None)
        self._padded_prefill = (_compiled_padded_prefill(cfg, window)
                                if self.bucket_sizes else None)
        self._active: dict[int, Request] = {}       # slot -> request
        self._tokens = np.zeros((capacity, 1, 1), np.int32)
        self.completed: list[Request] = []
        # engine-level counters (JSON summary)
        self.decode_steps = 0
        self.decode_tokens = 0       # tokens from decode steps (not prefill)
        self.prefill_calls = 0
        self.prefill_tokens = 0
        self.decode_s = 0.0
        self.prefill_s = 0.0
        self._tok_s_ema: Optional[float] = None     # per-token decode seconds

    # -- submission ---------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int, *,
               request_id: str = "", eos_id: Optional[int] = None,
               arrival_time: Optional[float] = None) -> Request:
        req = Request(prompt=prompt, max_new_tokens=max_new_tokens,
                      request_id=request_id, eos_id=eos_id,
                      model=self.model_name, arrival_time=arrival_time)
        # rows actually written: plen at prefill + one per decode step; the
        # final generated token is sampled but never fed back into the cache
        if req.prompt_len + req.max_new_tokens - 1 > self.pool.max_seq:
            raise ValueError(
                f"prompt+generation exceeds engine max_seq={self.pool.max_seq}")
        return self.queue.push(req)

    # -- introspection ------------------------------------------------------
    def active_requests(self) -> Sequence[Request]:
        return list(self._active.values())

    def queued_requests(self) -> Sequence[Request]:
        return list(self.queue)

    def has_work(self) -> bool:
        return bool(self._active or self.queue)

    def tok_seconds_estimate(self) -> float:
        """Measured per-token decode seconds (EMA); cost-model prior until
        the first step so multi-model LRTF can rank engines immediately."""
        if self._tok_s_ema is not None:
            return self._tok_s_ema
        return 2e-10 * max(self.cfg.n_active_params, 1)

    def remaining_seconds(self) -> float:
        """LRTF input: remaining decode work (active + queued), seconds."""
        rem = sum(r.remaining_tokens() for r in self._active.values())
        # queued requests also owe their prefill; charge it as tokens
        rem += sum(r.max_new_tokens + r.prompt_len for r in self.queue)
        return rem * self.tok_seconds_estimate()

    # -- engine tick --------------------------------------------------------
    def _retire_finished(self) -> None:
        for slot, req in list(self._active.items()):
            if req.done:
                req.status = Status.FINISHED
                req.finish_time = self.clock()
                req.slot = None
                self.pool.free(slot)
                self.budget.release()
                del self._active[slot]
                self.completed.append(req)

    def _bucket(self, plen: int) -> int:
        """Admission group key: smallest bucket >= plen (exact length when
        bucketing is off or the prompt outgrows every bucket)."""
        if self.bucket_sizes:
            for b in self.bucket_sizes:
                if b >= plen:
                    return b
        return plen

    def _admit(self) -> list[Request]:
        admitted: list[Request] = []
        while self.queue and self.pool.n_free and self.budget.reserve():
            req = self.queue.pop()
            req.slot = self.pool.alloc(req.request_id)
            req.admit_time = self.clock()
            req.status = Status.RUNNING
            admitted.append(req)
        if not admitted:
            return admitted
        # one jitted prefill per same-length group — (n, 1, plen) tokens over
        # n stacked fresh batch=1 states — or per same-*bucket* group when
        # length bucketing is on (mixed plens share one padded call)
        by_len: dict[int, list[Request]] = {}
        for req in admitted:
            by_len.setdefault(self._bucket(req.prompt_len), []).append(req)
        for plen, group in sorted(by_len.items()):
            slots = [r.slot for r in group]
            states = self.pool.fresh_states(len(group))
            t0 = self.clock()
            if self.bucket_sizes:
                tokens = jnp.asarray(np.stack(
                    [np.pad(r.prompt, (0, plen - r.prompt_len))
                     for r in group])[:, None, :])
                lengths = jnp.asarray([r.prompt_len for r in group], jnp.int32)
                logits, states = self._padded_prefill(
                    self.params, states, tokens, lengths)
            else:
                tokens = jnp.asarray(
                    np.stack([r.prompt for r in group])[:, None, :])
                logits, states = self._prefill(self.params, states, tokens)
            logits = jax.block_until_ready(logits)
            self.prefill_s += self.clock() - t0
            self.prefill_calls += 1
            # true prompt tokens, not the padded bucket width — keeps
            # prefill_tok_per_s comparable between bucketed and exact modes
            self.prefill_tokens += sum(r.prompt_len for r in group)
            self.pool.state = write_slots(self.pool.state, states, slots)
            first = np.asarray(jnp.argmax(logits, axis=-1), np.int32)  # (n, 1)
            now = self.clock()
            for i, req in enumerate(group):
                tok = int(first[i, 0])
                req.generated.append(tok)
                req.first_token_time = now
                self._tokens[req.slot, 0, 0] = tok
                self._active[req.slot] = req
        return admitted

    def step(self) -> bool:
        """One engine tick; returns True while there is work left."""
        self._retire_finished()
        self._admit()
        self._retire_finished()      # single-token requests finish at prefill
        if self._active:
            toks = jnp.asarray(self._tokens)
            t0 = self.clock()
            ntoks, self.pool.state = self._decode(self.params,
                                                  self.pool.state, toks)
            # np.array (copy): asarray of a jax array is a read-only view,
            # and admission writes freshly prefilled tokens into this buffer
            ntoks = np.array(jax.block_until_ready(ntoks), np.int32)
            dt = self.clock() - t0
            self.decode_s += dt
            self.decode_steps += 1
            self.decode_tokens += len(self._active)
            per_tok = dt / max(len(self._active), 1)
            self._tok_s_ema = (per_tok if self._tok_s_ema is None
                               else 0.8 * self._tok_s_ema + 0.2 * per_tok)
            self._tokens = ntoks
            for slot, req in self._active.items():
                req.generated.append(int(ntoks[slot, 0, 0]))
        return self.has_work()

    def run(self, max_steps: Optional[int] = None) -> list[Request]:
        """Drive to completion; returns requests completed during the call."""
        done_before = len(self.completed)
        steps = 0
        while self.step():
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        self._retire_finished()
        return self.completed[done_before:]

    # -- metrics ------------------------------------------------------------
    def summary(self) -> dict:
        return {
            "model": self.model_name,
            "capacity": self.pool.capacity,
            "max_seq": self.pool.max_seq,
            "bucket_sizes": list(self.bucket_sizes)
                if self.bucket_sizes else None,
            "slot_bytes": self.slot_bytes,
            "kv_budget_bytes": self.budget.budget_bytes,
            "kv_peak_bytes": self.budget.peak_bytes,
            "n_completed": len(self.completed),
            "decode_steps": self.decode_steps,
            "prefill_calls": self.prefill_calls,
            "prefill_tok_per_s": round(
                self.prefill_tokens / self.prefill_s, 1)
                if self.prefill_s else None,
            "decode_tok_per_s": round(self.decode_tokens / self.decode_s, 1)
                if self.decode_s else None,
        }
