"""Continuous-batching inference engine over a pluggable DecodeBackend.

One engine serves one loaded model.  Per tick (``step()``):

  1. retire finished requests (backend releases lanes + KV reservation),
  2. apply overload pressure (``serving/slo.py``: degrade spec drafts at
     soft, shed the lowest waiting tier at hard) and, when the queue head
     strictly outranks a running request, preempt one victim,
  3. admit queued requests in POLICY order (EDF + priority tiers +
     starvation aging by default; strict FIFO with ``policy="fifo"``)
     while the backend's byte budget allows — each admission group is
     prefilled in ONE jitted call (``make_prefill_into_cache`` vmapped
     over same-length prompts) and handed to the backend
     (``write_prefill``); preempted requests resume with prefill skipped,
  4. run ONE pooled decode step so every active request advances a token.

Requests therefore join and leave between decode steps without ever
retracing or perturbing in-flight lanes; outputs are token-identical to
running each request alone (tests/test_serving.py).

Online-serving surface (serving/server.py sits on top of this):

* ``submit(..., stream=True)`` attaches a ``TokenStream`` that receives
  every token the moment it exists and closes with the request's
  terminal status at retirement.
* ``cancel(request_id)`` withdraws a request wherever it lives: a queued
  request is skipped and retired at the next admission pass (never
  reserved or prefilled), a running one keeps its CANCELLED status
  through retirement while its lane and KV reservation release through
  the normal ``backend.release`` path (paged refcounts/orphans
  included) — both within one tick (tests/test_cancel.py).
* ``completed`` is a deque with optional ``completed_cap`` retention and
  ``drain_completed()`` for server loops, so a long-running engine holds
  steady memory instead of accumulating every request ever served.

Where decode state lives — and what a request's residency costs — is the
**backend's** concern (``serving/backends.py``): ``SlotBackend`` (default;
every servable family), ``PagedBackend`` (block-granular admission with
copy-on-write prefix sharing; families whose ``FamilySpec`` declares
``paging``), or ``SpecDecodeBackend`` (speculative decoding with a draft
member model over either inner; ``spec_draftable`` families).  The engine
selects the backend once at construction — from the family's declared
capabilities — and never branches on layout again.  Requesting a backend
the family cannot support falls back (spec -> its inner -> slot) with a
structured ``CapabilityFallbackWarning`` (mirrored by the bucketing
fallback), and the effective backend is recorded in ``summary()`` / plan
metadata / ``session.poll()``.
"""

from __future__ import annotations

import math
import time
import warnings
from collections import deque
from functools import lru_cache
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import CapabilityFallbackWarning
from repro.models.registry import spec as family_spec
from repro.serving.backends import DecodeBackend, make_backend
from repro.serving.queue import RequestQueue
from repro.serving.request import Request, Status
from repro.serving.slo import SLO, OverloadedError, make_policy
from repro.training.train_loop import (make_padded_prefill_into_cache,
                                       make_prefill_into_cache)


@lru_cache(maxsize=None)
def _compiled_prefill(cfg, window):
    """Per-(cfg, window) jitted prefill, shared across engine instances so
    a fresh engine for an already-loaded model never recompiles.  The state
    argument is donated: the pre-prefill fresh states are dead after each
    call, letting XLA write the prompt rows in place."""
    return jax.jit(jax.vmap(make_prefill_into_cache(cfg, window=window),
                            in_axes=(None, 0, 0)), donate_argnums=(1,))


@lru_cache(maxsize=None)
def _compiled_padded_prefill(cfg, window):
    """Bucketed prefill: tokens padded to a bucket length, per-request true
    lengths passed alongside.  Retraces per (n, bucket), not per (n, plen)."""
    return jax.jit(jax.vmap(make_padded_prefill_into_cache(cfg, window=window),
                            in_axes=(None, 0, 0, 0)), donate_argnums=(1,))


def pow2_buckets(max_seq: int) -> tuple[int, ...]:
    """Power-of-two length buckets covering [1, max_seq]."""
    out, b = [], 1
    while b < max_seq:
        out.append(b)
        b *= 2
    out.append(max_seq)
    return tuple(out)


class InferenceEngine:
    def __init__(self, cfg, params, *, capacity: int = 8,
                 max_seq: int = 256, kv_budget_bytes: Optional[int] = None,
                 window: Optional[int] = None,
                 model_name: Optional[str] = None,
                 bucket_sizes: Optional[Sequence[int]] = None,
                 backend: Union[str, DecodeBackend, None] = None,
                 paged: bool = False, block_size: int = 16,
                 n_blocks: Optional[int] = None, ledger=None,
                 paged_impl: Optional[str] = None,
                 prefix_share: bool = True, kv_dtype: Optional[str] = None,
                 draft_cfg=None, draft_params=None, draft_k: int = 4,
                 spec_inner: Optional[str] = None,
                 verify_impl: Optional[str] = None,
                 completed_cap: Optional[int] = None,
                 policy: Union[str, object] = "slo",
                 default_slo: Optional[SLO] = None,
                 tiered_kv: bool = False, prefetch_ticks: int = 1,
                 param_source=None,
                 tok_seconds_prior: Optional[float] = None,
                 clock=time.perf_counter):
        spec = family_spec(cfg)
        if not spec.servable:
            # e.g. encoder-decoder decode states need real encoder output:
            # init_decode_state(enc_out=None) zero-fills the cross-attn
            # cache and every generated token would condition on nothing
            raise ValueError(
                f"{cfg.name} ({cfg.family}): not servable through "
                f"InferenceEngine — {spec.why_not('servable')}")
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.cfg = cfg
        # shard-granular residency (serving/residency.py): a param source
        # assembles the device tree per tick — hot shards stay pinned,
        # cold ones stream through the double buffer; `self.params` is
        # refreshed at the top of every step
        self._param_source = param_source
        if param_source is not None and params is not None:
            raise ValueError("pass params or param_source, not both")
        self.params = params
        self.model_name = model_name or cfg.name
        self.clock = clock
        self.capacity = capacity
        self.max_seq = max_seq
        self.queue = RequestQueue(clock=clock)
        self.slot_bytes = spec.decode_state_bytes(cfg, 1, max_seq)
        self._prefill = _compiled_prefill(cfg, window)
        # -- backend selection: once, from declared capabilities ------------
        if paged and isinstance(backend, str) and backend != "paged":
            raise ValueError(
                f"conflicting arguments: paged=True but backend="
                f"{backend!r}; drop one of them")
        requested = backend if backend is not None else \
            ("paged" if paged else "slot")
        if isinstance(requested, str):
            self.requested_backend = requested
            effective = requested
            spec_inner = spec_inner or "slot"
            if spec_inner not in ("slot", "paged"):
                raise ValueError(f"spec_inner={spec_inner!r}: the spec "
                                 "backend wraps 'slot' or 'paged'")
            if requested == "spec" and not spec.spec_draftable:
                warnings.warn(
                    f"{cfg.name} ({cfg.family}): speculative decode "
                    f"requested but the family does not declare "
                    f"spec_draftable ({spec.why_not('spec_draftable')}); "
                    f"falling back to the {spec_inner!r} backend",
                    CapabilityFallbackWarning, stacklevel=2)
                effective = spec_inner
            if effective in ("paged",) or \
                    (effective == "spec" and spec_inner == "paged"):
                if not spec.paging:
                    warnings.warn(
                        f"{cfg.name} ({cfg.family}): paged backend "
                        f"requested but the family does not declare paging "
                        f"({spec.why_not('paging')}); falling back to the "
                        "slot backend", CapabilityFallbackWarning,
                        stacklevel=2)
                    effective = "slot" if effective == "paged" else effective
                    spec_inner = "slot"
            self.backend: DecodeBackend = make_backend(
                effective, cfg, capacity, max_seq, window=window,
                kv_budget_bytes=kv_budget_bytes, ledger=ledger,
                block_size=block_size, n_blocks=n_blocks,
                paged_impl=paged_impl, prefix_share=prefix_share,
                kv_dtype=kv_dtype, verify_impl=verify_impl,
                draft_cfg=draft_cfg, draft_params=draft_params,
                draft_k=draft_k, inner=spec_inner,
                tiered=tiered_kv, prefetch_ticks=prefetch_ticks)
        else:
            if paged and requested.name != "paged":
                raise ValueError(
                    "conflicting arguments: paged=True but the injected "
                    f"backend is {requested.name!r}; drop one of them")
            for attr in ("capacity", "max_seq"):
                if getattr(requested, attr, None) != getattr(self, attr):
                    raise ValueError(
                        f"injected {requested.name!r} backend has "
                        f"{attr}={getattr(requested, attr, None)} but the "
                        f"engine was built with {attr}="
                        f"{getattr(self, attr)}; they must match — the "
                        "engine sizes its token buffer and admission "
                        "checks from its own values")
            self.backend = requested
            self.requested_backend = requested.name
        # length-bucketed admission: pad prompt groups to the next bucket so
        # prefill retraces are bounded per (n, bucket) instead of per
        # (n, plen).  Families whose padded prefill is not token-identical
        # fall back to exact-length groups, with a structured warning.
        if bucket_sizes is not None and not spec.padded_prefill:
            warnings.warn(
                f"{cfg.name} ({cfg.family}): bucket_sizes requested but "
                f"the family does not declare padded_prefill "
                f"({spec.why_not('padded_prefill')}); falling back to "
                "exact-length admission groups", CapabilityFallbackWarning,
                stacklevel=2)
            bucket_sizes = None
        if bucket_sizes is not None:
            # a bucket cannot outsize the cache; overlong prompts fall back
            # to exact-length groups via _bucket
            bucket_sizes = [b for b in bucket_sizes if 0 < b <= max_seq]
        self.bucket_sizes = (tuple(sorted(set(bucket_sizes)))
                             if bucket_sizes else None)
        self._padded_prefill = (_compiled_padded_prefill(cfg, window)
                                if self.bucket_sizes else None)
        self._active: dict[int, Request] = {}       # lane -> request
        self._tokens = np.zeros((capacity, 1, 1), np.int32)
        # retired requests: bounded when completed_cap is set (a server
        # surviving millions of requests must hold steady memory — the
        # serving loop drains this every tick; the cap is the backstop)
        self.completed: deque[Request] = deque(maxlen=completed_cap)
        self.completed_cap = completed_cap
        self.retired_total = 0       # monotonic, survives drains/evictions
        self._recent_metrics: deque[dict] = deque(maxlen=32)
        # engine-level counters (JSON summary)
        self.decode_steps = 0
        self.decode_tokens = 0       # tokens from decode steps (not prefill)
        self.prefill_calls = 0
        self.prefill_tokens = 0
        self.decode_s = 0.0
        self.prefill_s = 0.0
        self.peak_concurrency = 0
        self._tok_s_ema: Optional[float] = None     # per-token decode seconds
        # measured-profile prior (repro.profiler CostModel): used until the
        # first real decode step seeds the EMA; None keeps the analytic
        # 2e-10·params constant
        self._tok_s_prior = tok_seconds_prior
        # -- SLO-aware admission (serving/slo.py) ---------------------------
        # "slo" with no SLOs declared degrades EXACTLY to FIFO (infinite
        # deadlines tie, arrival_seq breaks the tie), so it is the default
        self.policy = (make_policy(policy) if isinstance(policy, str)
                       else policy)
        self.default_slo = default_slo.validate() if default_slo else None
        self.n_preempted = 0    # RUNNING requests descheduled
        self.n_resumed = 0      # preempted requests re-attached
        self.n_shed = 0         # requests rejected under hard overload
        # -- tiered KV (host-DRAM page demotion, serving/backends.py) -------
        self._tiered = bool(getattr(self.backend, "tiered", False))
        self._demote_on_preempt = self._tiered and bool(
            getattr(self.policy, "demote_on_preempt", True))
        # active lanes + parked snapshot holders: the live-request
        # concurrency one byte budget sustains — tiering's headline metric
        self.peak_live_requests = 0

    # -- backend introspection (compat delegates) ----------------------------
    @property
    def paged(self) -> bool:
        return self.backend.name == "paged"

    @property
    def pool(self):
        return self.backend.pool

    @property
    def budget(self):
        return self.backend.budget

    @property
    def ledger(self):
        return getattr(self.backend, "ledger", None)

    @property
    def block_size(self):
        return getattr(self.backend, "block_size", None)

    @property
    def paged_impl(self):
        return getattr(self.backend, "paged_impl", None)

    # -- submission ---------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int, *,
               request_id: str = "", eos_id: Optional[int] = None,
               arrival_time: Optional[float] = None,
               deadline_ms: Optional[float] = None,
               priority: Optional[str] = None,
               max_ttft_ms: Optional[float] = None,
               stream: bool = False) -> Request:
        # request-level SLO fields win; unset ones inherit the engine's
        # per-model default (ServeJob deadline_ms/priority/max_ttft_ms).
        # Request.__post_init__ validates — nonsense SLOs raise ValueError
        # here, at submit time (HTTP maps it to 400)
        slo = SLO(deadline_ms=deadline_ms,
                  priority=priority if priority is not None else "normal",
                  max_ttft_ms=max_ttft_ms).merged(self.default_slo)
        req = Request(prompt=prompt, max_new_tokens=max_new_tokens,
                      request_id=request_id, eos_id=eos_id,
                      model=self.model_name, arrival_time=arrival_time,
                      slo=slo)
        # rows actually written: plen at prefill + one per decode step; the
        # final generated token is sampled but never fed back into the cache
        if req.prompt_len + req.max_new_tokens - 1 > self.max_seq:
            raise ValueError(
                f"prompt+generation exceeds engine max_seq={self.max_seq}")
        # a request that can NEVER fit would sit at the head of the queue
        # forever and livelock admission — the backend rejects it up front
        self.backend.admission_check(req, self._bucket(req.prompt_len))
        # hard overload: refuse at the door rather than queue work the
        # shed pass would reject anyway — but only when this request is in
        # (or below) the tier being shed; higher-priority traffic still
        # lands and preempts/outranks its way in
        if self.policy.pressure(self.queued_seconds()) >= 2 \
                and hasattr(self.policy, "shed_tier"):
            waiting = [r for r in self.queue if not r.done]
            shed = self.policy.shed_tier(waiting + [req])
            if shed is not None and req.slo.tier >= shed:
                req.status = Status.REJECTED
                req.shed_reason = (
                    "hard overload: queued work exceeds "
                    f"{self.policy.hard_overload_s:.4g}s; "
                    f"{req.slo.priority!r} is the lowest waiting tier")
                self.n_shed += 1
                self._finish(req)   # rejected requests hit the metrics ring
                raise OverloadedError(
                    f"{req.request_id}: {req.shed_reason}",
                    payload={"request_id": req.request_id,
                             "model": self.model_name,
                             "priority": req.slo.priority,
                             "queued_seconds":
                                 round(self.queued_seconds(), 3),
                             "reason": req.shed_reason})
        if stream:
            from repro.serving.stream import TokenStream
            req.stream = TokenStream(req.request_id)
        return self.queue.push(req)

    # -- cancellation -------------------------------------------------------
    def cancel(self, request_id: str) -> bool:
        """Withdraw a request by id, wherever it lives.

        Queued: marked CANCELLED in place — the next admission pass skips
        and retires it without ever reserving a lane or running its
        prefill.  Running: marked CANCELLED so ``done`` turns true and the
        next ``_retire_finished`` releases its lane and KV reservation
        through the normal backend path (paged refcounts and orphan
        charges included) while PRESERVING the cancelled status.  Returns
        False when no live request has that id.
        """
        req = self.queue.find(request_id)
        if req is not None and req.status in (Status.QUEUED,
                                              Status.PREEMPTED):
            # a preempted request still holds its KV snapshot; the sweep
            # in the next admission pass discards it through the backend
            req.status = Status.CANCELLED
            return True
        for req in self._active.values():
            if req.request_id == request_id \
                    and req.status is Status.RUNNING:
                req.status = Status.CANCELLED
                return True
        return False

    def cancel_all_queued(self) -> int:
        """Withdraw every still-queued request (job-level cancel)."""
        n = 0
        for req in self.queue:
            if req.status in (Status.QUEUED, Status.PREEMPTED):
                req.status = Status.CANCELLED
                n += 1
        return n

    # -- introspection ------------------------------------------------------
    def active_requests(self) -> Sequence[Request]:
        return list(self._active.values())

    def queued_requests(self) -> Sequence[Request]:
        return list(self.queue)

    def has_work(self) -> bool:
        return bool(self._active or self.queue)

    @property
    def n_free_lanes(self) -> int:
        return self.backend.free_lanes

    def tok_seconds_estimate(self) -> float:
        """Measured per-token decode seconds (EMA); cost-model prior until
        the first step so multi-model LRTF can rank engines immediately.
        The prior is this host's probed decode rate when a machine profile
        supplied one (``tok_seconds_prior``), else the analytic constant."""
        if self._tok_s_ema is not None:
            return self._tok_s_ema
        if self._tok_s_prior is not None:
            return self._tok_s_prior
        return 2e-10 * max(self.cfg.n_active_params, 1)

    def remaining_seconds(self) -> float:
        """LRTF input: remaining decode work (active + queued), seconds."""
        rem = sum(r.remaining_tokens() for r in self._active.values())
        # queued requests also owe their prefill; charge it as tokens —
        # except preempted ones, whose prompt rows are already in KV
        rem += sum(r.remaining_tokens()
                   + (0 if r.status is Status.PREEMPTED else r.prompt_len)
                   for r in self.queue if not r.done)
        return rem * self.tok_seconds_estimate()

    def queued_seconds(self) -> float:
        """Estimated seconds of work WAITING (not yet on a lane) — the
        overload signal the shed policy gates on."""
        rem = sum(r.remaining_tokens()
                  + (0 if r.status is Status.PREEMPTED else r.prompt_len)
                  for r in self.queue if not r.done)
        return rem * self.tok_seconds_estimate()

    def resume_cost_seconds(self, req: Request) -> float:
        """Extra latency a preempted request pays before its next token:
        pages demoted to the host pool must prefetch back — an async
        transfer of ``prefetch_ticks`` engine ticks plus the resume tick,
        each roughly one pooled decode step at current occupancy.  Zero
        for device-resident snapshots (resume is a table re-attach)."""
        if not self._tiered or self.backend.demoted_blocks(req) == 0:
            return 0.0
        per_tick = self.tok_seconds_estimate() * max(1, len(self._active))
        return (self.backend.prefetch_ticks + 1) * per_tick

    def min_slack_seconds(self, now: Optional[float] = None
                          ) -> Optional[float]:
        """Tightest deadline slack across live requests (negative = a
        deadline is already doomed at the current decode rate), or None
        when nothing declares a deadline.  The SLO-aware multi-model
        router ranks engines by this instead of raw remaining work.
        Preempted-and-demoted requests owe their resume/prefetch latency
        on top of remaining decode — without it the router overpromises
        on engines whose parked work lives in host DRAM."""
        now = self.clock() if now is None else now
        tok_s = self.tok_seconds_estimate()
        best: Optional[float] = None
        for r in list(self._active.values()) + list(self.queue):
            if r.done:
                continue
            arrival = r.arrival_time if r.arrival_time is not None else now
            # running requests only owe their end-to-end deadline; waiting
            # ones are also racing their TTFT budget
            dl = (r.slo.deadline_abs(arrival)
                  if r.status is Status.RUNNING
                  else r.slo.admission_deadline(arrival))
            if not math.isfinite(dl):
                continue
            est = r.remaining_tokens() * tok_s
            if r.status is Status.QUEUED:
                est += r.prompt_len * tok_s
            elif r.status is Status.PREEMPTED:
                est += self.resume_cost_seconds(r)
            slack = dl - now - est
            best = slack if best is None else min(best, slack)
        return best

    # -- engine tick --------------------------------------------------------
    def _finish(self, req: Request) -> None:
        """Shared retirement bookkeeping (finished AND cancelled)."""
        req.finish_time = self.clock()
        self.completed.append(req)
        self.retired_total += 1
        self._recent_metrics.append(req.metrics())
        if req.stream is not None:
            req.stream.close(req.status)

    def _retire_finished(self) -> None:
        for lane, req in list(self._active.items()):
            if req.done:
                # a cancel must survive retirement: stomping it to
                # FINISHED here made Status.CANCELLED unreachable for
                # running requests (the original lifecycle bug)
                if req.status is not Status.CANCELLED:
                    req.status = Status.FINISHED
                self.backend.release(req)
                req.slot = None
                del self._active[lane]
                self._finish(req)

    def _bucket(self, plen: int) -> int:
        """Admission group key: smallest bucket >= plen (exact length when
        bucketing is off or the prompt outgrows every bucket)."""
        if self.bucket_sizes:
            for b in self.bucket_sizes:
                if b >= plen:
                    return b
        return plen

    def _sweep_terminal_queued(self) -> None:
        """Retire queued entries that went terminal in place (cancelled,
        or rejected by the shed pass) — admitting one would reserve a
        lane, burn a jitted prefill, and stomp the status to RUNNING.  A
        cancelled PREEMPTED request still holds a KV snapshot; discard it
        through the backend so refcounts and bytes settle."""
        for req in [r for r in self.queue
                    if r.status in (Status.CANCELLED, Status.REJECTED)]:
            self.queue.remove(req)
            if getattr(self.backend, "preemptible", False):
                self.backend.discard_preempted(req)
            self._finish(req)

    def _admit(self) -> list[Request]:
        self._sweep_terminal_queued()
        admitted: list[Request] = []
        now = self.clock()
        # policy-ordered walk (EDF + tiers + aging for "slo", arrival
        # order for "fifo"); stop at the first request that cannot take a
        # lane — skipping past a blocked head would starve it
        for req in self.policy.order(list(self.queue), now):
            if not self.backend.free_lanes:
                break
            if req.status is Status.PREEMPTED:
                if self._tiered:
                    # resume barrier for demoted snapshots: pages must be
                    # back on device before the lane re-attaches
                    state = self.backend.parked_state(req)
                    if state == "demoted":
                        # start the async fetch; a failed byte reservation
                        # blocks admission AT THE HEAD (no skipping —
                        # running work retiring is what frees the bytes,
                        # and they were part of this request's original
                        # reservation, so the wait is bounded)
                        if not self.backend.start_prefetch(req):
                            break
                        continue    # in flight; revisit next tick
                    if state == "inflight":
                        # demoted-but-prefetching: the lane stays
                        # schedulable — others admit past it this tick
                        self.backend.note_prefetch_wait(req)
                        continue
                # resume: the KV snapshot re-attaches to a lane, prefill
                # is skipped, and decode restarts from the last generated
                # token — its KV row was never written (engine invariant:
                # the newest token lives only in the feed buffer), so the
                # continuation is token-identical to an uninterrupted run
                if not self.backend.resume(req):
                    break
                self.queue.remove(req)
                req.status = Status.RUNNING
                req.resume_generated = len(req.generated)
                self.n_resumed += 1
                self._tokens[req.slot, 0, 0] = req.generated[-1]
                self._active[req.slot] = req
                continue
            if not self.backend.reserve(req, self._bucket(req.prompt_len)):
                break
            self.queue.remove(req)
            req.admit_time = self.clock()
            req.status = Status.RUNNING
            admitted.append(req)
        if not admitted:
            return admitted
        # one jitted prefill per same-length group — (n, 1, plen) tokens over
        # n stacked fresh batch=1 states — or per same-*bucket* group when
        # length bucketing is on (mixed plens share one padded call)
        by_len: dict[int, list[Request]] = {}
        for req in admitted:
            by_len.setdefault(self._bucket(req.prompt_len), []).append(req)
        for plen, group in sorted(by_len.items()):
            states = self.backend.fresh_states(len(group), plen)
            t0 = self.clock()
            if self.bucket_sizes:
                tokens = jnp.asarray(np.stack(
                    [np.pad(r.prompt, (0, plen - r.prompt_len))
                     for r in group])[:, None, :])
                lengths = jnp.asarray([r.prompt_len for r in group], jnp.int32)
                logits, states = self._padded_prefill(
                    self.params, states, tokens, lengths)
            else:
                tokens = jnp.asarray(
                    np.stack([r.prompt for r in group])[:, None, :])
                logits, states = self._prefill(self.params, states, tokens)
            logits = jax.block_until_ready(logits)
            self.prefill_s += self.clock() - t0
            self.prefill_calls += 1
            # true prompt tokens, not the padded bucket width — keeps
            # prefill_tok_per_s comparable between bucketed and exact modes
            self.prefill_tokens += sum(r.prompt_len for r in group)
            self.backend.write_prefill(group, states)
            first = np.asarray(jnp.argmax(logits, axis=-1), np.int32)  # (n, 1)
            now = self.clock()
            for i, req in enumerate(group):
                tok = int(first[i, 0])
                req.generated.append(tok)
                req.first_token_time = now
                if req.stream is not None:
                    req.stream.put(tok)
                self._tokens[req.slot, 0, 0] = tok
                self._active[req.slot] = req
        return admitted

    def _maybe_preempt(self) -> None:
        """Deschedule one running victim when the queue head strictly
        outranks it (SLO policy + preemptible backend only).  Guards: a
        free lane means admission needs no help, and evicting is useless
        when the head is blocked on BYTES rather than a lane."""
        if self.backend.free_lanes or not self.queue:
            return
        if not getattr(self.backend, "preemptible", False) \
                or not getattr(self.policy, "preempt", False):
            return
        now = self.clock()
        waiting = [r for r in self.queue if not r.done]
        if not waiting:
            return
        head = self.policy.order(waiting, now)[0]
        # bytes guard: evicting is useless when the head is blocked on
        # BYTES rather than a lane — unless eager demotion is on, in
        # which case the victim's parked pages leave the device and the
        # freed bytes are exactly what admits the head
        if head.status is not Status.PREEMPTED \
                and not self._demote_on_preempt \
                and not self.backend.can_admit_bytes(
                    head, self._bucket(head.prompt_len)):
            return
        running = [r for r in self._active.values()
                   if r.status is Status.RUNNING and not r.done]
        victim = self.policy.pick_victim(head, running, now)
        if victim is None:
            return
        lane = victim.slot
        self.backend.preempt(victim)
        if self._demote_on_preempt:
            # PR 7 follow-on: a parked request stops pinning device bytes
            self.backend.demote_parked(victim)
        del self._active[lane]
        victim.slot = None
        victim.status = Status.PREEMPTED
        victim.preemptions += 1
        self.n_preempted += 1
        # rejoins the queue with its ORIGINAL arrival time/seq: aging and
        # EDF keep ranking it as the old request it is
        self.queue.push(victim)

    def _apply_pressure(self) -> None:
        """Overload response, in declared shed order: soft -> degrade the
        spec backend's draft model (compute-only, still token-identical);
        hard -> reject the lowest-priority WAITING tier (preempted
        requests are exempt: they hold KV and finished work)."""
        press = self.policy.pressure(self.queued_seconds())
        if hasattr(self.backend, "set_degraded"):
            self.backend.set_degraded(press >= 1)
        if press < 2 or not hasattr(self.policy, "shed_tier"):
            return
        waiting = [r for r in self.queue if r.status is Status.QUEUED]
        shed = self.policy.shed_tier(waiting)
        if shed is None:
            return
        now = self.clock()
        # worst-ranked first, and stop as soon as pressure clears hard —
        # shed the minimum, not the whole tier
        for req in reversed(self.policy.order(waiting, now)):
            if req.slo.tier != shed:
                continue
            if self.policy.pressure(self.queued_seconds()) < 2:
                break
            req.status = Status.REJECTED
            req.shed_reason = (
                "hard overload: queued work exceeds "
                f"{self.policy.hard_overload_s:.4g}s; shed lowest waiting "
                f"tier ({req.slo.priority!r})")
            self.n_shed += 1

    def step(self) -> bool:
        """One engine tick; returns True while there is work left."""
        if self._param_source is not None and self.has_work():
            # assemble the shard-resident param tree for this tick (hot
            # shards reuse their device copies; cold shards stream)
            self.params = self._param_source.begin_tick()
        try:
            return self._step_inner()
        finally:
            if self._param_source is not None:
                self._param_source.end_tick()

    def _step_inner(self) -> bool:
        if self._tiered:
            self.backend.poll_prefetches()   # async-transfer completions
        self._retire_finished()
        self._apply_pressure()
        self._maybe_preempt()        # freed lane is re-used this same tick
        self._admit()
        self._retire_finished()      # single-token requests finish at prefill
        self.peak_concurrency = max(self.peak_concurrency, len(self._active))
        parked = sum(1 for r in self.queue if r.status is Status.PREEMPTED)
        self.peak_live_requests = max(self.peak_live_requests,
                                      len(self._active) + parked)
        if self._active:
            t0 = self.clock()
            ntoks = self.backend.decode(self.params, self._tokens,
                                        self._active)
            dt = self.clock() - t0
            self.decode_s += dt
            self.decode_steps += 1
            self.decode_tokens += len(self._active)
            per_tok = dt / max(len(self._active), 1)
            self._tok_s_ema = (per_tok if self._tok_s_ema is None
                               else 0.8 * self._tok_s_ema + 0.2 * per_tok)
            self._tokens = ntoks
            for lane, req in self._active.items():
                tok = int(ntoks[lane, 0, 0])
                req.generated.append(tok)
                if req.stream is not None:
                    req.stream.put(tok)
                self.backend.advance(lane)
        return self.has_work()

    def run(self, max_steps: Optional[int] = None) -> list[Request]:
        """Drive to completion; returns requests completed during the call."""
        done_before = self.retired_total
        steps = 0
        while self.step():
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        self._retire_finished()
        return self.completed_since(done_before)

    def completed_since(self, retired_before: int) -> list[Request]:
        """Requests retired after ``retired_before`` (a ``retired_total``
        snapshot) that are still retained in ``completed``."""
        n = self.retired_total - retired_before
        if n <= 0:
            return []
        n = min(n, len(self.completed))
        return list(self.completed)[len(self.completed) - n:]

    def drain_completed(self) -> list[Request]:
        """Pop and return every retained completed request — the serving
        loop's drain-on-read, so completions never accumulate forever."""
        out = list(self.completed)
        self.completed.clear()
        return out

    def recent_metrics(self) -> list[dict]:
        """Per-request metrics of the most recently retired requests
        (bounded ring; survives ``drain_completed`` for ``poll()``)."""
        return list(self._recent_metrics)

    # -- metrics ------------------------------------------------------------
    def summary(self) -> dict:
        out = {
            "model": self.model_name,
            "capacity": self.capacity,
            "max_seq": self.max_seq,
            "backend": self.backend.name,
            "requested_backend": self.requested_backend,
            "paged": self.paged,
            "policy": self.policy.name,
            "preemptible": bool(getattr(self.backend, "preemptible",
                                        False)),
            "n_preempted": self.n_preempted,
            "n_resumed": self.n_resumed,
            "n_shed": self.n_shed,
            "bucket_sizes": list(self.bucket_sizes)
                if self.bucket_sizes else None,
            "slot_bytes": self.slot_bytes,
            "kv_budget_bytes": self.backend.budget.budget_bytes,
            "kv_reserved_bytes": self.backend.budget.reserved_bytes,
            "kv_peak_bytes": self.backend.budget.peak_bytes,
            "free_lanes": self.backend.free_lanes,
            "peak_concurrency": self.peak_concurrency,
            # active lanes + parked (preempted) snapshot holders: the
            # admitted concurrency one byte budget sustains — with tiered
            # KV, parked pages live in host DRAM so this exceeds what
            # device bytes alone could hold
            "peak_live_requests": self.peak_live_requests,
            # retired_total, not len(completed): drain_completed/-cap
            # eviction must not make a long-running server report zero
            "n_completed": self.retired_total,
            "decode_steps": self.decode_steps,
            "prefill_calls": self.prefill_calls,
            "prefill_tok_per_s": round(
                self.prefill_tokens / self.prefill_s, 1)
                if self.prefill_s else None,
            "decode_tok_per_s": round(self.decode_tokens / self.decode_s, 1)
                if self.decode_s else None,
        }
        out.update(self.backend.summary())
        if self._param_source is not None:
            out.update(self._param_source.summary())
        return out
