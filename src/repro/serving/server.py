"""Online serving front-end: HTTP + SSE streaming over MultiModelServer.

Two layers, both stdlib-only (``http.server``/``socketserver`` threads —
no new runtime deps):

* ``ServingFrontend`` — the tick loop that turns the library engine into
  a live service.  Engines are NOT thread-safe, so every engine mutation
  happens on ONE background thread: HTTP handler threads enqueue ops
  (submit / cancel / summary) and block on a tiny future while the loop
  interleaves them with ``MultiModelServer.step()`` — continuous
  arrivals admit and retire between decode steps, exactly the join
  semantics the engine already guarantees token-identity for.  The loop
  drains completions every tick (``drain_completed``), so a server
  surviving millions of requests holds steady memory.
* ``HydraHTTPServer`` — an OpenAI-compatible wire surface on top:
  ``POST /v1/completions`` and ``POST /v1/chat/completions`` (with
  ``"stream": true`` for SSE token streaming), ``POST /v1/cancel`` and
  ``DELETE /v1/requests/<id>`` for first-class cancellation, plus
  ``GET /v1/models`` / ``GET /v1/metrics`` / ``GET /health``.  A client
  that disconnects mid-stream triggers the same ``cancel`` path — the
  SSE writer probes the socket with keep-alive comments while decode is
  quiet, so a dead peer frees its lane and KV reservation within a tick
  even when no token is flowing.

The models here have no tokenizer, so the wire speaks token ids:
``prompt`` accepts a list of ints (used verbatim) or a string (byte-level
stand-in encoding, ``byte % vocab_size``); completions stream each token
id as the text chunk ``" <id>"`` plus a structured ``token_id`` field.
"""

from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, field
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from queue import Empty, Queue
from typing import Any, Callable, Optional

import numpy as np

from repro.serving.multi import MultiModelServer
from repro.serving.request import Request, Status
from repro.serving.slo import OverloadedError

_FINISH_REASON = {Status.FINISHED: "stop", Status.CANCELLED: "cancelled",
                  Status.REJECTED: "rejected"}


def encode_prompt(prompt: Any, vocab_size: int) -> np.ndarray:
    """Token ids pass through; strings get the byte-level stand-in
    encoding (documented in docs/serving.md — the repo has no tokenizer)."""
    if isinstance(prompt, str):
        if not prompt:
            raise ValueError("empty prompt")
        return (np.frombuffer(prompt.encode("utf-8"), np.uint8)
                .astype(np.int32) % vocab_size)
    arr = np.asarray(prompt, np.int32).reshape(-1)
    if arr.size == 0:
        raise ValueError("empty prompt")
    if (arr < 0).any() or (arr >= vocab_size).any():
        raise ValueError(f"prompt token ids must be in [0, {vocab_size})")
    return arr


@dataclass
class _Op:
    """One engine mutation shipped to the tick thread; a minimal future."""
    fn: Callable[[], Any]
    done: threading.Event = field(default_factory=threading.Event)
    result: Any = None
    error: Optional[BaseException] = None


class ServingFrontend:
    """Single-threaded engine loop + thread-safe submit/cancel surface.

    ``model_options`` (per routing name) carries the ServeJob-level HTTP
    fields: ``{"stream": bool, "endpoint": str | None}`` — whether SSE
    streaming is offered for the model, and an optional extra alias
    clients may pass as ``"model"``.
    """

    def __init__(self, server: MultiModelServer, *,
                 model_options: Optional[dict[str, dict]] = None,
                 idle_wait_s: float = 0.002, op_timeout_s: float = 120.0):
        self.server = server
        self.model_options = dict(model_options or {})
        self.idle_wait_s = idle_wait_s
        self.op_timeout_s = op_timeout_s
        self._aliases: dict[str, str] = {}
        for name, opts in self.model_options.items():
            alias = (opts or {}).get("endpoint")
            if not alias:
                continue
            if alias in server.engines or \
                    self._aliases.get(alias, name) != name:
                raise ValueError(
                    f"endpoint alias {alias!r} collides with an existing "
                    "model name or alias")
            self._aliases[alias] = name
        self._ops: Queue[_Op] = Queue()
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # counters (one writer: the tick thread)
        self.n_submitted = 0
        self.n_completed = 0
        self.n_cancelled = 0
        self.ticks = 0

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "ServingFrontend":
        if self._thread is not None:
            raise RuntimeError("frontend already started")
        self._thread = threading.Thread(target=self._loop,
                                        name="hydra-serve-tick", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None

    def __enter__(self) -> "ServingFrontend":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False

    # -- tick loop (the ONLY thread that touches engines) --------------------
    def _loop(self) -> None:
        while not self._stop.is_set():
            ran_op = self._drain_ops()
            stepped = self.server.step()
            if stepped is not None:
                self.ticks += 1
            for done in self.server.drain_completed().values():
                for req in done:
                    self.n_completed += 1
                    if req.status is Status.CANCELLED:
                        self.n_cancelled += 1
            if stepped is None and not ran_op:
                self._wake.wait(self.idle_wait_s)
                self._wake.clear()
        self._drain_ops()        # never strand a blocked handler thread

    def _drain_ops(self) -> bool:
        ran = False
        while True:
            try:
                op = self._ops.get_nowait()
            except Empty:
                return ran
            ran = True
            try:
                op.result = op.fn()
            except BaseException as e:      # delivered to the caller
                op.error = e
            op.done.set()

    def _call(self, fn: Callable[[], Any]) -> Any:
        if self._thread is None or not self._thread.is_alive():
            raise RuntimeError("serving frontend is not running")
        op = _Op(fn)
        self._ops.put(op)
        self._wake.set()
        if not op.done.wait(self.op_timeout_s):
            raise TimeoutError(
                f"engine loop did not pick up the request within "
                f"{self.op_timeout_s}s")
        if op.error is not None:
            raise op.error
        return op.result

    # -- public surface (any thread) -----------------------------------------
    def resolve_model(self, name: str) -> str:
        target = self._aliases.get(name, name)
        if target not in self.server.engines:
            known = sorted(self.server.engines) + sorted(self._aliases)
            raise KeyError(f"unknown model {name!r} (serving {known})")
        return target

    def streaming_allowed(self, model: str) -> bool:
        return bool(self.model_options.get(model, {}).get("stream", True))

    def engine_cfg(self, model: str):
        return self.server.engines[model].cfg

    def submit(self, model: str, prompt, max_new_tokens: int, *,
               request_id: str = "", eos_id: Optional[int] = None,
               deadline_ms: Optional[float] = None,
               priority: Optional[str] = None,
               max_ttft_ms: Optional[float] = None) -> Request:
        """Thread-safe submit; always attaches a TokenStream (the HTTP
        layer consumes it even for non-streaming responses)."""
        def _do():
            req = self.server.submit(model, prompt, max_new_tokens,
                                     request_id=request_id, eos_id=eos_id,
                                     deadline_ms=deadline_ms,
                                     priority=priority,
                                     max_ttft_ms=max_ttft_ms,
                                     stream=True)
            self.n_submitted += 1
            return req
        return self._call(_do)

    def cancel(self, request_id: str) -> bool:
        return self._call(lambda: self.server.cancel(request_id))

    def metrics(self) -> dict:
        def _do():
            return {
                "n_submitted": self.n_submitted,
                "n_completed": self.n_completed,
                "n_cancelled": self.n_cancelled,
                # SLO outcomes, aggregated across engines (per-request
                # deadline_met/preemptions ride in recent_requests)
                "n_preempted": sum(e.n_preempted
                                   for e in self.server.engines.values()),
                "n_resumed": sum(e.n_resumed
                                 for e in self.server.engines.values()),
                "n_shed": sum(e.n_shed
                              for e in self.server.engines.values()),
                "ticks": self.ticks,
                "engines": {name: eng.summary()
                            for name, eng in self.server.engines.items()},
                "recent_requests": {
                    name: eng.recent_metrics()
                    for name, eng in self.server.engines.items()},
            }
        return self._call(_do)


# ---------------------------------------------------------------------------
# HTTP layer (OpenAI-compatible wire shape + SSE)
# ---------------------------------------------------------------------------

class _Handler(BaseHTTPRequestHandler):
    """One request per connection (HTTP/1.0 close-delimited — SSE needs
    no chunked framing that way).  ``frontend`` is bound by the server."""

    frontend: ServingFrontend = None        # type: ignore[assignment]
    server_version = "hydra-serve/1.0"
    # SSE keep-alive probe period: with no token flowing, a comment line
    # is written this often — a dead socket raises and cancels the request
    ping_every_s = 0.25

    def log_message(self, fmt, *args):      # quiet by default
        pass

    # -- helpers -------------------------------------------------------------
    def _json(self, status: int, obj: dict) -> None:
        body = json.dumps(obj).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _error(self, status: int, message: str) -> None:
        self._json(status, {"error": {"message": message,
                                      "type": "invalid_request_error"}})

    def _body(self) -> dict:
        length = int(self.headers.get("Content-Length", 0))
        raw = self.rfile.read(length) if length else b"{}"
        obj = json.loads(raw.decode("utf-8"))
        if not isinstance(obj, dict):
            raise ValueError("request body must be a JSON object")
        return obj

    # -- routing -------------------------------------------------------------
    def do_GET(self):
        if self.path == "/health":
            self._json(200, {"status": "ok"})
        elif self.path == "/v1/models":
            fe = self.frontend
            data = [{"id": name, "object": "model", "owned_by": "hydra",
                     "backend": eng.backend.name,
                     **{k: v for k, v in
                        fe.model_options.get(name, {}).items()}}
                    for name, eng in fe.server.engines.items()]
            self._json(200, {"object": "list", "data": data})
        elif self.path == "/v1/metrics":
            self._json(200, self.frontend.metrics())
        else:
            self._error(404, f"no route {self.path!r}")

    def do_DELETE(self):
        if self.path.startswith("/v1/requests/"):
            rid = self.path[len("/v1/requests/"):]
            found = self.frontend.cancel(rid)
            self._json(200 if found else 404,
                       {"request_id": rid, "cancelled": found})
        else:
            self._error(404, f"no route {self.path!r}")

    def do_POST(self):
        try:
            body = self._body()
        except (ValueError, json.JSONDecodeError) as e:
            return self._error(400, f"bad JSON body: {e}")
        if self.path == "/v1/completions":
            self._completion(body, chat=False)
        elif self.path == "/v1/chat/completions":
            self._completion(body, chat=True)
        elif self.path == "/v1/cancel":
            rid = str(body.get("request_id", ""))
            found = self.frontend.cancel(rid)
            self._json(200 if found else 404,
                       {"request_id": rid, "cancelled": found})
        else:
            self._error(404, f"no route {self.path!r}")

    # -- completions ---------------------------------------------------------
    def _completion(self, body: dict, *, chat: bool) -> None:
        fe = self.frontend
        try:
            model = fe.resolve_model(str(body.get("model", "")))
        except KeyError as e:
            return self._error(404, str(e))
        want_stream = bool(body.get("stream", False))
        if want_stream and not fe.streaming_allowed(model):
            return self._error(
                400, f"model {model!r} is served with stream=False "
                "(ServeJob.stream); request a non-streaming completion")
        try:
            if chat:
                messages = body.get("messages")
                if not isinstance(messages, list) or not messages:
                    raise ValueError("chat needs a non-empty 'messages'")
                raw: Any = "".join(str(m.get("content", ""))
                                   for m in messages)
            else:
                raw = body.get("prompt")
            vocab = fe.engine_cfg(model).vocab_size
            prompt = encode_prompt(raw, vocab)
            max_tokens = int(body.get("max_tokens", 16))
            eos_id = body.get("eos_id")
            # SLO fields (serving/slo.py): nonsense values raise
            # ValueError from SLO.validate -> HTTP 400 with the
            # actionable message, same as every other body error
            deadline_ms = body.get("deadline_ms")
            max_ttft_ms = body.get("max_ttft_ms")
            priority = body.get("priority")
            req = fe.submit(model, prompt, max_tokens,
                            request_id=str(body.get("request_id", "")),
                            eos_id=None if eos_id is None else int(eos_id),
                            deadline_ms=(None if deadline_ms is None
                                         else float(deadline_ms)),
                            priority=(None if priority is None
                                      else str(priority)),
                            max_ttft_ms=(None if max_ttft_ms is None
                                         else float(max_ttft_ms)))
        except OverloadedError as e:
            # shed at the door: structured 429 so clients can back off
            # or retry at a higher priority
            return self._json(429, {"error": {
                "message": str(e), "type": "overloaded",
                "code": 429, **e.payload}})
        except (TypeError, ValueError) as e:
            return self._error(400, str(e))
        if want_stream:
            self._stream_sse(req, model, chat=chat)
        else:
            self._respond_full(req, model, chat=chat)

    @staticmethod
    def _chunk(req: Request, model: str, *, chat: bool, tok: Optional[int],
               finish: Optional[str]) -> dict:
        piece = "" if tok is None else f" {tok}"
        choice: dict[str, Any] = {"index": 0, "finish_reason": finish}
        if tok is not None:
            choice["token_id"] = tok
        if chat:
            choice["delta"] = ({"content": piece} if tok is not None else {})
            obj = "chat.completion.chunk"
        else:
            choice["text"] = piece
            obj = "text_completion"
        return {"id": req.request_id, "object": obj, "model": model,
                "choices": [choice]}

    def _stream_sse(self, req: Request, model: str, *, chat: bool) -> None:
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("Connection", "close")
        self.end_headers()
        stream = req.stream
        try:
            while True:
                try:
                    tok = stream.get(timeout=self.ping_every_s)
                except StopIteration:
                    break
                if tok is None:             # no token yet: probe the socket
                    self.wfile.write(b": ping\n\n")
                    self.wfile.flush()
                    continue
                data = json.dumps(self._chunk(req, model, chat=chat,
                                              tok=tok, finish=None))
                self.wfile.write(f"data: {data}\n\n".encode())
                self.wfile.flush()
            final = self._chunk(req, model, chat=chat, tok=None,
                                finish=self._finish_reason(req))
            final["usage"] = {"prompt_tokens": req.prompt_len,
                              "completion_tokens": len(req.generated),
                              "total_tokens": req.prompt_len
                              + len(req.generated)}
            final["metrics"] = req.metrics()
            self.wfile.write(f"data: {json.dumps(final)}\n\n".encode())
            self.wfile.write(b"data: [DONE]\n\n")
            self.wfile.flush()
        except (BrokenPipeError, ConnectionResetError, OSError):
            # client went away mid-stream: withdraw the request so its
            # lane + KV reservation free within one tick
            self.frontend.cancel(req.request_id)

    def _respond_full(self, req: Request, model: str, *, chat: bool) -> None:
        toks = list(req.stream)             # blocks until the stream closes
        text = "".join(f" {t}" for t in toks)
        finish = self._finish_reason(req)
        choice: dict[str, Any] = {"index": 0, "finish_reason": finish,
                                  "token_ids": toks}
        if chat:
            choice["message"] = {"role": "assistant", "content": text}
            obj = "chat.completion"
        else:
            choice["text"] = text
            obj = "text_completion"
        self._json(200, {
            "id": req.request_id, "object": obj, "model": model,
            "choices": [choice],
            "usage": {"prompt_tokens": req.prompt_len,
                      "completion_tokens": len(toks),
                      "total_tokens": req.prompt_len + len(toks)},
            "metrics": req.metrics()})

    @staticmethod
    def _finish_reason(req: Request) -> str:
        reason = _FINISH_REASON.get(req.status, "length")
        if reason == "stop" and req.eos_id is not None and req.generated \
                and req.generated[-1] == req.eos_id:
            return "stop"
        return "length" if reason == "stop" else reason


class HydraHTTPServer:
    """The deployable wrapper: frontend tick loop + threaded HTTP server.

        server = HydraHTTPServer(MultiModelServer({...}), port=8000)
        with server:                     # or .start() / .stop()
            print(server.url)            # http://127.0.0.1:8000
            ...

    ``port=0`` binds an ephemeral port (tests / benches); ``url`` reports
    the bound address either way.
    """

    def __init__(self, server: MultiModelServer, *, host: str = "127.0.0.1",
                 port: int = 0,
                 model_options: Optional[dict[str, dict]] = None):
        self.frontend = ServingFrontend(server, model_options=model_options)
        handler = type("BoundHandler", (_Handler,),
                       {"frontend": self.frontend})
        self.httpd = ThreadingHTTPServer((host, port), handler)
        self.httpd.daemon_threads = True
        self._http_thread: Optional[threading.Thread] = None

    @property
    def address(self) -> tuple[str, int]:
        host, port = self.httpd.server_address[:2]
        return str(host), int(port)

    @property
    def url(self) -> str:
        host, port = self.address
        return f"http://{host}:{port}"

    def start(self) -> "HydraHTTPServer":
        self.frontend.start()
        self._http_thread = threading.Thread(
            target=self.httpd.serve_forever, kwargs={"poll_interval": 0.05},
            name="hydra-serve-http", daemon=True)
        self._http_thread.start()
        return self

    def stop(self) -> None:
        self.httpd.shutdown()
        self.httpd.server_close()
        if self._http_thread is not None:
            self._http_thread.join(timeout=10)
            self._http_thread = None
        self.frontend.stop()

    def serve_forever(self) -> None:
        """Blocking entry point for the CLI (Ctrl-C stops cleanly)."""
        self.start()
        try:
            while True:
                time.sleep(3600)
        except KeyboardInterrupt:
            pass
        finally:
            self.stop()

    def __enter__(self) -> "HydraHTTPServer":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False
