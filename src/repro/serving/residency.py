"""Shard-granular weight residency for serving (ROADMAP item 3a).

``ServeJob(cold=True)`` used to mean *whole-model* promotion: the first
request paid one big host->device transfer and the model stayed fully
resident forever, uncharged.  This module completes SHARP-for-inference:
a served model's weights live in its ``HostModelStore`` and reach the
device **per shard**, charged to the one ``DeviceMemory`` ledger.

Two residency classes per shard:

* **hot** — pinned across serve ticks (``DeviceMemory.reserve_weights``),
  up to the job's ``hot_bytes`` target.  Hot shards are what make a model
  "resident"; many models' hot sets pack into one budget.
* **streamed** — everything else is promoted *through the double buffer*
  each tick, exactly the ``SharpExecutor`` train pattern
  (``DeviceMemory.promote_through_buffer`` -> compute -> demotion), so the
  ledger peak is hot + one in-flight shard rather than the whole model.

Under ledger pressure a ``ResidencyCoordinator`` demotes hot shards of
the least-recently-served models first (LRU over last-served tick); a
demoted model keeps serving — its shards simply stream until the budget
drains and ``_ensure_hot`` re-pins them.

On this CPU dev container promotion is physically host->host and the
assembled decode tree is retained between ticks; the *mechanics* (per
shard transfer work, buffer lifecycle, budget enforcement, LRU demotion,
byte/traffic accounting) are identical to a real fleet and fully
exercised — the same contract ``core/spilling.py`` declares.  Decode
outputs are token-identical to a warm engine by construction: weights
are read-only and ``to_device`` round-trips are exact.
"""

from __future__ import annotations

import time
from typing import Optional

from repro.core.spilling import DeviceMemory, HostModelStore, to_device


class ShardResidentParams:
    """Param source for one served model: assembles the decode tree each
    engine tick from hot (pinned) + streamed (per-tick) weight shards.

    The engine calls ``begin_tick()`` before prefill/decode and
    ``end_tick()`` after; between ticks only the hot set is charged.
    """

    def __init__(self, cfg, store: HostModelStore, partition,
                 ledger: DeviceMemory, *, hot_bytes: Optional[int] = None,
                 double_buffer: bool = True, name: Optional[str] = None,
                 clock=time.monotonic):
        self.cfg = cfg
        self.store = store
        self.partition = partition
        self.ledger = ledger
        self.hot_bytes = hot_bytes      # None -> pin everything that fits
        self.double_buffer = double_buffer
        self.name = name or getattr(cfg, "name", "model")
        self.clock = clock
        self.shards = list(partition.shards)
        self.shard_bytes = {
            s.index: store.shard_transfer_bytes(s, train=False)
            for s in self.shards}
        self.total_bytes = sum(self.shard_bytes.values())
        self.last_used = float("-inf")  # LRU key: last-served tick time
        self._hot: dict[int, int] = {}  # shard index -> charged bytes
        self._assembled = None          # device tree, built on first tick
        self._tail_bytes = 0            # last streamed shard, demoted at end
        self._in_tick = False
        # traffic accounting (reported via summary())
        self.stream_promoted_bytes = 0
        self.n_stream_promotions = 0
        self.n_hot_demotions = 0
        self.promote_s = 0.0

    # -- tick protocol (driven by InferenceEngine) --------------------------
    def begin_tick(self):
        """Assemble the device param tree for one prefill/decode tick."""
        self.last_used = self.clock()
        self._in_tick = True
        self._ensure_hot()
        cold = [s for s in self.shards if s.index not in self._hot]
        prev = 0
        for s in cold:
            b = self.shard_bytes[s.index]
            if prev:
                self.ledger.charge_demotion(prev)
            self.ledger.promote_through_buffer(
                b, double_buffer=self.double_buffer)
            t0 = time.perf_counter()
            self.store.promote_shard_params(s)  # real host->device transfer
            self.promote_s += time.perf_counter() - t0
            self.stream_promoted_bytes += b
            self.n_stream_promotions += 1
            prev = b
        # the last streamed shard stays charged through the decode call
        self._tail_bytes = prev
        if self._assembled is None:
            t0 = time.perf_counter()
            self._assembled = to_device(self.store.model_params())
            self.promote_s += time.perf_counter() - t0
        return self._assembled

    def end_tick(self) -> None:
        if self._tail_bytes:
            self.ledger.charge_demotion(self._tail_bytes)
            self._tail_bytes = 0
        self._in_tick = False

    # -- residency ----------------------------------------------------------
    def _ensure_hot(self) -> None:
        """Greedily (re-)pin shards up to the hot-bytes target.  Runs every
        tick, so a model demoted under pressure re-warms once the ledger
        drains.  The pin set must leave enough budget headroom to stream
        the LARGEST remaining cold shard — otherwise the tick itself would
        blow ``_check_budget`` mid-stream; pins yield (own shards last,
        after cross-model pressure relief) until streaming fits."""
        target = self.total_bytes if self.hot_bytes is None else self.hot_bytes
        hot_total = sum(self._hot.values())
        for s in self.shards:
            if s.index in self._hot:
                continue
            b = self.shard_bytes[s.index]
            if hot_total + b > target:
                continue
            if not self.ledger.reserve_weights(b):
                break       # budget full even after pressure demotion
            self._hot[s.index] = b
            hot_total += b
        cold = [s.index for s in self.shards if s.index not in self._hot]
        if not cold:
            return
        need = max(self.shard_bytes[i] for i in cold)
        headroom = self.ledger.budget - self.ledger.used_bytes()
        if headroom < need:
            # other models' idle pins go first (LRU via the ledger's
            # pressure handlers; our own demote() is a no-op mid-tick)
            self.ledger._relieve(need - headroom)
        while self._hot and \
                self.ledger.budget - self.ledger.used_bytes() < need:
            idx = max(self._hot)
            b = self._hot.pop(idx)
            self.ledger.release_weights(b)
            self.n_hot_demotions += 1
            need = max(need, b)     # the unpinned shard now streams too

    def demote(self, need_bytes: int) -> int:
        """Pressure handler: unpin hot shards until ``need_bytes`` are
        freed (or nothing is left).  Never demotes mid-tick — the charges
        are load-bearing while the model is decoding."""
        if self._in_tick:
            return 0
        freed = 0
        for idx in sorted(self._hot, reverse=True):
            if freed >= need_bytes:
                break
            b = self._hot.pop(idx)
            self.ledger.release_weights(b)
            self.n_hot_demotions += 1
            freed += b
        return freed

    def demote_all(self) -> int:
        """Teardown: release every pinned shard (drain-to-baseline)."""
        return self.demote(self.total_bytes + 1)

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def hot_resident_bytes(self) -> int:
        return sum(self._hot.values())

    @property
    def n_hot_shards(self) -> int:
        return len(self._hot)

    def summary(self) -> dict:
        return {
            "residency": "shard",
            "n_shards": len(self.shards),
            "n_hot_shards": self.n_hot_shards,
            "weight_bytes": self.total_bytes,
            "hot_resident_bytes": self.hot_resident_bytes,
            "stream_promoted_bytes": self.stream_promoted_bytes,
            "n_stream_promotions": self.n_stream_promotions,
            "n_hot_demotions": self.n_hot_demotions,
            "promote_s": round(self.promote_s, 6),
        }


class ResidencyCoordinator:
    """Cross-model LRU demotion: one per session ledger.  Registered as a
    ``DeviceMemory`` pressure handler; under pressure the least-recently-
    served models' hot shards leave the device first."""

    def __init__(self, ledger: DeviceMemory):
        self.ledger = ledger
        self.models: list[ShardResidentParams] = []
        ledger.on_pressure(self.relieve)

    def register(self, src: ShardResidentParams) -> None:
        if src not in self.models:
            self.models.append(src)

    def relieve(self, need_bytes: int) -> int:
        freed = 0
        for src in sorted(self.models, key=lambda s: s.last_used):
            if freed >= need_bytes:
                break
            freed += src.demote(need_bytes - freed)
        return freed
