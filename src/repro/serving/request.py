r"""Request objects + per-request latency/throughput metrics.

Lifecycle (see docs/serving.md):

    QUEUED --admit--> RUNNING --last token--> FINISHED
      |  ^  \          |  |      \
      |  |   cancel    |  |       cancel (released next tick)
      |  |      \      |  |          \
      |  |       +-----+--|------> CANCELLED
      |  +---resume----+  +--preempt--> PREEMPTED (back in queue,
      |   (prefill skipped)              KV blocks snapshot-held)
      +--shed (hard overload)---> REJECTED
      arrival_time       admit_time / first_token_time ... finish_time

``cancel`` is first-class (``InferenceEngine.cancel``): a queued request
is retired at the next admission pass without ever being reserved or
prefilled; a running one keeps CANCELLED through retirement while its
lane and KV reservation release normally.  PREEMPTED is the one
non-terminal detour: a paged request descheduled by the SLO policy keeps
its refcounted KV blocks (and its byte reservation) in a backend-side
snapshot and rejoins the queue; resume needs only a free lane and skips
prefill, so its output stays token-identical to an uninterrupted run.
REJECTED is terminal: shed under hard overload before ever running.
All timestamps come from the engine's injectable clock so tests can
freeze time; durations are derived lazily in ``metrics()``.
"""

from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

from repro.serving.slo import SLO

_ids = itertools.count()


class Status(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    FINISHED = "finished"
    CANCELLED = "cancelled"      # withdrawn (queued or mid-decode)
    PREEMPTED = "preempted"      # descheduled, KV held; NOT terminal
    REJECTED = "rejected"        # shed under hard overload; terminal


@dataclass(eq=False)
class Request:
    """One generation request: prompt tokens + a decode budget.

    Identity semantics (``eq=False``): requests live in queues and
    completion rings that remove/compare by object, and field equality
    would compare the prompt array elementwise.
    """
    prompt: np.ndarray                       # (plen,) int32
    max_new_tokens: int
    request_id: str = ""
    model: Optional[str] = None              # routing key (multi-model)
    eos_id: Optional[int] = None             # optional early stop
    arrival_time: Optional[float] = None     # stamped by the queue
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    status: Status = Status.QUEUED
    slot: Optional[int] = None               # pool slot / decode lane
    generated: list[int] = field(default_factory=list)
    # SLO-aware scheduling (serving/slo.py): the request's declared
    # objective, the queue's monotonic arrival stamp (deterministic
    # tie-break), how often it was preempted, how many tokens it had at
    # its last admit/resume (anti-thrash floor), and — if shed — why
    slo: Optional[SLO] = None                # defaulted in __post_init__
    arrival_seq: Optional[int] = None        # stamped by the queue
    preemptions: int = 0
    resume_generated: int = 0
    shed_reason: Optional[str] = None
    # online serving: a TokenStream the engine feeds as tokens appear and
    # closes (with the terminal status) at retirement; None for batch use
    stream: Optional[Any] = None
    # paged engines only: blocks reserved at admission (the byte guarantee),
    # the high-water mark of blocks actually allocated while running, and
    # how many physical blocks were aliased from a prompt-prefix donor
    reserved_blocks: Optional[int] = None
    peak_blocks: Optional[int] = None
    shared_blocks: Optional[int] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if not self.request_id:
            self.request_id = f"req-{next(_ids)}"
        if self.slo is None:
            self.slo = SLO()
        self.slo.validate()

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        if self.status in (Status.CANCELLED, Status.REJECTED):
            return True
        if len(self.generated) >= self.max_new_tokens:
            return True
        return bool(self.generated and self.eos_id is not None
                    and self.generated[-1] == self.eos_id)

    def remaining_tokens(self) -> int:
        return max(0, self.max_new_tokens - len(self.generated))

    def metrics(self) -> dict:
        """JSON-ready per-request latency/throughput record."""
        out = {
            "request_id": self.request_id,
            "model": self.model,
            "status": self.status.value,
            "prompt_len": self.prompt_len,
            "n_generated": len(self.generated),
        }

        def dur(a, b):
            return round(b - a, 6) if a is not None and b is not None else None

        if self.reserved_blocks is not None:
            out["kv_reserved_blocks"] = self.reserved_blocks
            out["kv_peak_blocks"] = self.peak_blocks
            out["kv_shared_blocks"] = self.shared_blocks
        out["queue_wait_s"] = dur(self.arrival_time, self.admit_time)
        out["ttft_s"] = dur(self.arrival_time, self.first_token_time)
        out["e2e_s"] = dur(self.arrival_time, self.finish_time)
        decode_s = dur(self.first_token_time, self.finish_time)
        out["decode_s"] = decode_s
        if decode_s and len(self.generated) > 1:
            out["decode_tok_per_s"] = round(
                (len(self.generated) - 1) / decode_s, 1)
        else:
            out["decode_tok_per_s"] = None
        # SLO outcome: deadline_met/ttft_met are None when no budget was
        # declared, False when the request never finished (shed/cancelled)
        out["priority"] = self.slo.priority
        out["preemptions"] = self.preemptions
        if self.shed_reason is not None:
            out["shed_reason"] = self.shed_reason
        if self.slo.deadline_ms is not None:
            out["deadline_ms"] = self.slo.deadline_ms
            e2e = out["e2e_s"]
            out["deadline_met"] = (e2e is not None
                                   and e2e * 1000.0 <= self.slo.deadline_ms
                                   and self.status is Status.FINISHED)
        else:
            out["deadline_met"] = None
        if self.slo.max_ttft_ms is not None:
            out["max_ttft_ms"] = self.slo.max_ttft_ms
            ttft = out["ttft_s"]
            out["ttft_met"] = (ttft is not None
                               and ttft * 1000.0 <= self.slo.max_ttft_ms)
        else:
            out["ttft_met"] = None
        return out
