r"""Request objects + per-request latency/throughput metrics.

Lifecycle (see docs/serving.md):

    QUEUED --admit--> RUNNING --last token--> FINISHED
      |    \             |        \
      |     cancel       |         cancel (released next tick)
      |        \         |            \
      arrival   +--------+-------> CANCELLED
      arrival_time       admit_time / first_token_time ... finish_time

``cancel`` is first-class (``InferenceEngine.cancel``): a queued request
is retired at the next admission pass without ever being reserved or
prefilled; a running one keeps CANCELLED through retirement while its
lane and KV reservation release normally.  All timestamps come from the
engine's injectable clock so tests can freeze time; durations are
derived lazily in ``metrics()``.
"""

from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

_ids = itertools.count()


class Status(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    FINISHED = "finished"
    CANCELLED = "cancelled"      # withdrawn (queued or mid-decode)


@dataclass
class Request:
    """One generation request: prompt tokens + a decode budget."""
    prompt: np.ndarray                       # (plen,) int32
    max_new_tokens: int
    request_id: str = ""
    model: Optional[str] = None              # routing key (multi-model)
    eos_id: Optional[int] = None             # optional early stop
    arrival_time: Optional[float] = None     # stamped by the queue
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    status: Status = Status.QUEUED
    slot: Optional[int] = None               # pool slot / decode lane
    generated: list[int] = field(default_factory=list)
    # online serving: a TokenStream the engine feeds as tokens appear and
    # closes (with the terminal status) at retirement; None for batch use
    stream: Optional[Any] = None
    # paged engines only: blocks reserved at admission (the byte guarantee),
    # the high-water mark of blocks actually allocated while running, and
    # how many physical blocks were aliased from a prompt-prefix donor
    reserved_blocks: Optional[int] = None
    peak_blocks: Optional[int] = None
    shared_blocks: Optional[int] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if not self.request_id:
            self.request_id = f"req-{next(_ids)}"

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        if self.status is Status.CANCELLED:
            return True
        if len(self.generated) >= self.max_new_tokens:
            return True
        return bool(self.generated and self.eos_id is not None
                    and self.generated[-1] == self.eos_id)

    def remaining_tokens(self) -> int:
        return max(0, self.max_new_tokens - len(self.generated))

    def metrics(self) -> dict:
        """JSON-ready per-request latency/throughput record."""
        out = {
            "request_id": self.request_id,
            "model": self.model,
            "status": self.status.value,
            "prompt_len": self.prompt_len,
            "n_generated": len(self.generated),
        }

        def dur(a, b):
            return round(b - a, 6) if a is not None and b is not None else None

        if self.reserved_blocks is not None:
            out["kv_reserved_blocks"] = self.reserved_blocks
            out["kv_peak_blocks"] = self.peak_blocks
            out["kv_shared_blocks"] = self.shared_blocks
        out["queue_wait_s"] = dur(self.arrival_time, self.admit_time)
        out["ttft_s"] = dur(self.arrival_time, self.first_token_time)
        out["e2e_s"] = dur(self.arrival_time, self.finish_time)
        decode_s = dur(self.first_token_time, self.finish_time)
        out["decode_s"] = decode_s
        if decode_s and len(self.generated) > 1:
            out["decode_tok_per_s"] = round(
                (len(self.generated) - 1) / decode_s, 1)
        else:
            out["decode_tok_per_s"] = None
        return out
