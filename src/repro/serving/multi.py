"""Multi-model serving: several loaded engines, one device timeline.

Hydra's thesis — interleave many independent jobs to hide per-job stalls —
applied to inference: each loaded model owns an ``InferenceEngine``, and
between ticks the server asks the SHARP scheduling policy (Sharded-LRTF
from ``repro.core.scheduler``) which model's decode step runs next.  A
model's "remaining train time" maps onto its remaining decode work in
seconds (``ModelProgress.from_remaining``): LRTF therefore keeps the model
with the most outstanding tokens moving, the same longest-first rule the
paper proves out for training makespan.

``scheduler="slo"`` generalizes the LRTF router for deadline traffic:
each tick first asks every eligible engine for its tightest deadline
slack (``InferenceEngine.min_slack_seconds``); if some engine's slack is
inside the urgency margin, that engine steps (EDF across models) —
otherwise the tick falls back to plain LRTF, so workloads without
deadlines route identically to ``"lrtf"``.

Ties in remaining time resolve deterministically: eligible models are
presented to the policy sorted by (model name, earliest arrival seq), so
equal-remaining-work schedules are reproducible across runs instead of
following dict insertion order.
"""

from __future__ import annotations

import math
from collections import deque
from typing import Optional, Union

from repro.core.scheduler import ModelProgress, SchedulerFn, get_scheduler
from repro.serving.engine import InferenceEngine
from repro.serving.request import Request
from repro.serving.slo import most_urgent


class MultiModelServer:
    def __init__(self, engines: dict[str, InferenceEngine],
                 scheduler: Union[str, SchedulerFn] = "lrtf",
                 trace_cap: int = 4096, slo_margin_s: float = 0.5):
        if not engines:
            raise ValueError("need at least one engine")
        self.engines = dict(engines)
        self._names = list(self.engines)
        # "slo" = deadline-aware pre-pass + LRTF fallback (module
        # docstring); get_scheduler maps the name onto the fallback fn
        self.slo_routing = scheduler == "slo"
        self.slo_margin_s = slo_margin_s
        self.scheduler: SchedulerFn = (get_scheduler(scheduler)
                                       if isinstance(scheduler, str)
                                       else scheduler)
        # model picked at each tick — a capped ring, not an unbounded
        # list: a server alive for millions of ticks holds steady memory
        self.schedule_trace: deque[str] = deque(maxlen=trace_cap)

    def submit(self, model: str, prompt, max_new_tokens: int,
               **kw) -> Request:
        return self.engines[model].submit(prompt, max_new_tokens, **kw)

    def cancel(self, request_id: str) -> bool:
        """Withdraw a request by id from whichever engine holds it."""
        return any(eng.cancel(request_id)
                   for eng in self.engines.values())

    def has_work(self) -> bool:
        return any(e.has_work() for e in self.engines.values())

    def _earliest_seq(self, name: str) -> float:
        """Oldest live arrival seq in an engine (queued or active) — the
        second component of the deterministic tie-break."""
        eng = self.engines[name]
        seqs = [r.arrival_seq
                for r in list(eng.queue) + eng.active_requests()
                if r.arrival_seq is not None]
        return min(seqs) if seqs else math.inf

    def step(self) -> Optional[str]:
        """One server tick: pick a model via the policy, run its engine
        tick.  Returns the model name stepped, or None when idle."""
        # deterministic tie-breaking: the LRTF/SRTF fns keep the FIRST
        # best on exact remaining-time ties, so present eligible models
        # sorted by (model name, earliest arrival seq) instead of dict
        # insertion order — equal-work schedules reproduce across runs
        eligible = sorted(
            (name for name in self._names if self.engines[name].has_work()),
            key=lambda name: (name, self._earliest_seq(name)))
        if not eligible:
            return None
        pick = None
        if self.slo_routing:
            # EDF pre-pass: an engine whose tightest deadline is inside
            # the urgency margin wins outright; None -> LRTF fallback
            now = self.engines[eligible[0]].clock()
            pick = most_urgent([self.engines[n] for n in eligible], now,
                               margin_s=self.slo_margin_s)
        if pick is None:
            progress = [ModelProgress.from_remaining(
                i, self.engines[name].remaining_seconds())
                for i, name in enumerate(eligible)]
            pick = self.scheduler(progress)
        name = eligible[pick]
        self.engines[name].step()
        self.schedule_trace.append(name)
        return name

    def run(self, max_steps: Optional[int] = None) -> dict[str, list[Request]]:
        """Drive to completion; returns only the requests completed DURING
        this call (mirrors ``InferenceEngine.run`` — returning the full
        ``completed`` history double-counted on repeated invocations)."""
        before = {name: eng.retired_total
                  for name, eng in self.engines.items()}
        steps = 0
        while self.step() is not None:
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return {name: eng.completed_since(before[name])
                for name, eng in self.engines.items()}

    def drain_completed(self) -> dict[str, list[Request]]:
        """Pop every engine's retained completions (the serving loop's
        drain-on-read; see ``InferenceEngine.drain_completed``)."""
        return {name: eng.drain_completed()
                for name, eng in self.engines.items()}

    def summary(self) -> dict:
        out = {name: eng.summary() for name, eng in self.engines.items()}
        ledger = self.shared_ledger()
        if ledger is not None:
            out["device_memory"] = {
                "budget_bytes": ledger.budget,
                "kv_reserved_bytes": ledger.kv_reserved_bytes,
                "kv_peak_bytes": ledger.kv_peak_bytes,
                "resident_bytes": ledger.resident_bytes,
            }
        return out

    def shared_ledger(self):
        """The one DeviceMemory every paged engine charges, when the server
        was built that way (admission across models then splits a single
        device byte budget); None when ledgers are absent or per-engine.
        A lone engine's private ledger (device_id -1, built from its own
        kv_budget_bytes) is per-engine state, not device-level memory."""
        ledgers = [e.ledger for e in self.engines.values()
                   if getattr(e, "ledger", None) is not None]
        if ledgers and all(lg is ledgers[0] for lg in ledgers) \
                and (len(ledgers) > 1 or ledgers[0].device_id >= 0):
            return ledgers[0]
        return None
