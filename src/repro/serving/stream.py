"""Per-request token streams: the engine-to-client hand-off for online
serving.

A ``TokenStream`` is a small thread-safe pipe between the engine tick
thread (producer) and whoever is delivering tokens to a client — the SSE
writer in ``serving/server.py``, or a test iterating the stream directly.
The engine side never blocks: ``put`` appends, ``close`` marks the
terminal status; the consumer side blocks on ``get`` (with an optional
timeout, so an SSE writer can interleave keep-alive probes that detect a
dead socket even while decode is stalled).

Attach one via ``InferenceEngine.submit(..., stream=True)`` — the engine
then pushes every generated token the moment it exists (first token at
prefill, one per decode tick, speculative backends included since they
drain through the same per-tick surface) and closes the stream with the
request's terminal ``Status`` at retirement.  A cancelled request's
stream closes with ``Status.CANCELLED`` so the consumer can distinguish
"finished" from "withdrawn" without touching the request object.
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

from repro.serving.request import Status


class TokenStream:
    """Thread-safe single-producer token pipe with a terminal status."""

    _CLOSE = object()           # sentinel: no more tokens

    def __init__(self, request_id: str = ""):
        self.request_id = request_id
        self._q: queue.Queue = queue.Queue()
        self._status: Optional[Status] = None
        self._closed = threading.Event()

    # -- producer side (engine tick thread) ---------------------------------
    def put(self, token: int) -> None:
        self._q.put(int(token))

    def close(self, status: Status) -> None:
        """Mark the stream finished; idempotent (a double retirement must
        not enqueue a second sentinel and desync the consumer)."""
        if self._closed.is_set():
            return
        self._status = status
        self._closed.set()
        self._q.put(self._CLOSE)

    # -- consumer side ------------------------------------------------------
    @property
    def closed(self) -> bool:
        return self._closed.is_set()

    @property
    def status(self) -> Optional[Status]:
        """Terminal status, or None while the request is still live."""
        return self._status

    @property
    def cancelled(self) -> bool:
        return self._status is Status.CANCELLED

    def get(self, timeout: Optional[float] = None) -> Optional[int]:
        """Next token; None on timeout (stream still live) or raises
        ``StopIteration`` once the close sentinel is reached.  Termination
        is sticky: the sentinel is re-queued so every later ``get`` (or a
        second consumer) sees end-of-stream too, never a timeout."""
        try:
            item = self._q.get(timeout=timeout)
        except queue.Empty:
            return None
        if item is self._CLOSE:
            self._q.put(self._CLOSE)
            raise StopIteration
        return item

    def __iter__(self) -> Iterator[int]:
        while True:
            item = self._q.get()
            if item is self._CLOSE:
                self._q.put(self._CLOSE)
                return
            yield item
