"""SLO-aware admission scheduling: deadlines, priority tiers, preemption.

This module is the policy half of ROADMAP item 2 ("retire FIFO"): every
request may declare an SLO — ``deadline_ms`` (end-to-end budget from
arrival), ``priority`` (``high`` / ``normal`` / ``low``), ``max_ttft_ms``
(admission latency budget) — and the engine orders admission by an
**EDF-with-priority-tiers** rank instead of arrival order:

    rank(req, now) = (effective_tier, admission_deadline, arrival_seq)

* ``effective_tier`` is the declared priority tier minus one level per
  ``aging_s`` seconds spent waiting (**starvation aging**: a low-priority
  request left behind long enough eventually outranks fresh high-priority
  arrivals — the tier is unbounded below, so no stream of urgent traffic
  can starve it forever).
* ``admission_deadline`` is the earliest absolute instant among the
  request's declared budgets (EDF within a tier); no SLO means +inf, so a
  default workload degrades exactly to FIFO (ties broken by arrival).
* ``arrival_seq`` is the queue's monotonic stamp — the FIFO tie-break
  that makes schedules reproducible.

**Preemption** (``SLOPolicy.pick_victim``): when no lane is free and the
head of the queue strictly outranks a running request *by declared
priority and deadline* (aging moves queue order, never evictions — an
aged tier would let equals preempt each other in a thrash loop), the
engine deschedules the worst-ranked running victim.  Only backends that
declare ``preemptible`` (the paged backend: block tables snapshot in
O(blocks) and the blocks stay refcounted) participate; others decline
with a capability reason.

**Overload shedding** (``pressure``): the queue's estimated decode-work
seconds gate two levels, shed in declared order —

    1. ``soft_overload_s``  — degrade: speculative backends drop their
       draft-model work (plain decode, still token-identical) before any
       request is refused;
    2. ``hard_overload_s``  — reject: the lowest-priority *waiting* tier
       is shed (queued requests retire as ``REJECTED``; new submissions
       of that tier raise ``OverloadedError`` → HTTP 429 with a
       structured status) rather than livelocking the whole queue.

``FIFOPolicy`` is the strict arrival-order baseline (no preemption, no
shedding) kept for A/B benchmarking (``bench_load.py --slo-smoke``).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Sequence

PRIORITIES = {"high": 0, "normal": 1, "low": 2}


def validate_slo(deadline_ms: Optional[float], priority: Optional[str],
                 max_ttft_ms: Optional[float]) -> None:
    """Reject nonsensical SLOs with actionable messages (mirrors
    ``HydraConfig.validate()``); the HTTP layer maps these to 400."""
    if deadline_ms is not None:
        if not math.isfinite(deadline_ms) or deadline_ms <= 0:
            raise ValueError(
                f"deadline_ms={deadline_ms}: a deadline is a positive "
                "end-to-end millisecond budget measured from arrival; "
                "omit it for no deadline")
    if max_ttft_ms is not None:
        if not math.isfinite(max_ttft_ms) or max_ttft_ms <= 0:
            raise ValueError(
                f"max_ttft_ms={max_ttft_ms}: the time-to-first-token "
                "budget must be a positive number of milliseconds; "
                "omit it for no TTFT bound")
    if priority is not None and priority not in PRIORITIES:
        raise ValueError(
            f"priority={priority!r}: known priorities are "
            f"{sorted(PRIORITIES, key=PRIORITIES.get)} "
            "(high runs first, low is shed first under overload)")


@dataclass
class SLO:
    """Per-request service-level objective (all fields optional)."""
    deadline_ms: Optional[float] = None     # end-to-end budget from arrival
    priority: str = "normal"                # "high" | "normal" | "low"
    max_ttft_ms: Optional[float] = None     # admission-latency budget

    def validate(self) -> "SLO":
        validate_slo(self.deadline_ms, self.priority, self.max_ttft_ms)
        return self

    @property
    def tier(self) -> int:
        return PRIORITIES[self.priority]

    def merged(self, default: Optional["SLO"]) -> "SLO":
        """Request-level fields win; unset ones inherit the model default."""
        if default is None:
            return self
        return SLO(
            deadline_ms=(self.deadline_ms if self.deadline_ms is not None
                         else default.deadline_ms),
            priority=(self.priority if self.priority != "normal"
                      or default.priority == "normal" else default.priority),
            max_ttft_ms=(self.max_ttft_ms if self.max_ttft_ms is not None
                         else default.max_ttft_ms))

    def deadline_abs(self, arrival: float) -> float:
        """Absolute end-to-end deadline (+inf when none declared)."""
        if self.deadline_ms is None:
            return math.inf
        return arrival + self.deadline_ms / 1000.0

    def admission_deadline(self, arrival: float) -> float:
        """Earliest absolute instant any declared budget expires — the
        EDF key (admission latency bounds TTFT, so ``max_ttft_ms``
        participates alongside the end-to-end deadline)."""
        out = self.deadline_abs(arrival)
        if self.max_ttft_ms is not None:
            out = min(out, arrival + self.max_ttft_ms / 1000.0)
        return out


class OverloadedError(RuntimeError):
    """Submission refused by the shed policy (HTTP maps this to 429)."""

    def __init__(self, message: str, *, payload: Optional[dict] = None):
        super().__init__(message)
        self.payload = dict(payload or {})


# ---------------------------------------------------------------------------
# admission policies
# ---------------------------------------------------------------------------

class FIFOPolicy:
    """Strict arrival order: the PR-1 baseline, kept for A/B comparison.
    Never preempts, never sheds — exactly the old head-of-queue scan."""

    name = "fifo"
    preempt = False

    def rank(self, req, now: float):
        return (req.arrival_seq if req.arrival_seq is not None else 0,)

    def order(self, reqs: Sequence, now: float) -> list:
        return sorted(reqs, key=lambda r: self.rank(r, now))

    def pick_victim(self, head, running: Sequence, now: float):
        return None

    def pressure(self, queued_seconds: float) -> int:
        return 0


@dataclass
class SLOPolicy:
    """EDF with priority tiers + starvation aging (see module docstring).

    ``aging_s``            — seconds of waiting per tier promotion
                             (0 disables aging).
    ``preempt``            — allow descheduling running requests when the
                             backend declares ``preemptible``.
    ``preempt_min_tokens`` — a victim must have decoded this many tokens
                             since its last admit/resume (anti-thrash).
    ``demote_on_preempt``  — on tiered-KV backends, eagerly demote a
                             victim's parked pages to the host pool so
                             they stop pinning device bytes (preempt→
                             demote, resume→prefetch barrier; see
                             docs/serving.md).  Ignored when the engine
                             is not tiered.
    ``soft_overload_s``    — queued-work seconds above which speculative
                             draft models are degraded (level 1).
    ``hard_overload_s``    — queued-work seconds above which the
                             lowest-priority waiting tier is shed
                             (level 2).  Defaults are +inf: no shedding
                             unless the deployment declares thresholds.
    """

    name: str = "slo"
    aging_s: float = 30.0
    preempt: bool = True
    preempt_min_tokens: int = 2
    demote_on_preempt: bool = True
    soft_overload_s: float = math.inf
    hard_overload_s: float = math.inf

    # -- ordering ------------------------------------------------------------
    def _tier(self, req, now: float) -> int:
        tier = req.slo.tier
        if self.aging_s > 0 and req.arrival_time is not None:
            waited = max(0.0, now - req.arrival_time)
            # unbounded below: aging must eventually outrank even fresh
            # high-priority deadline traffic, or low-priority requests
            # starve forever under sustained load (tests/test_slo.py)
            tier -= int(waited / self.aging_s)
        return tier

    def rank(self, req, now: float):
        return (self._tier(req, now),
                req.slo.admission_deadline(req.arrival_time or now),
                req.arrival_seq if req.arrival_seq is not None else 0)

    def order(self, reqs: Sequence, now: float) -> list:
        return sorted(reqs, key=lambda r: self.rank(r, now))

    # -- preemption ----------------------------------------------------------
    def _victim_rank(self, req, now: float):
        """Preemption compares DECLARED priority + deadline only: aging
        promotes queue order, but letting an aged tier evict a running
        equal would thrash (each preempts the other forever)."""
        return (req.slo.tier,
                req.slo.deadline_abs(req.arrival_time or now),
                req.arrival_seq if req.arrival_seq is not None else 0)

    def pick_victim(self, head, running: Sequence, now: float):
        """The worst-ranked running request the queue head STRICTLY
        outranks by (tier, deadline), or None.  Victims must have decoded
        ``preempt_min_tokens`` since their last admit/resume."""
        if not self.preempt:
            return None
        cands = [r for r in running
                 if len(r.generated) - r.resume_generated
                 >= self.preempt_min_tokens]
        if not cands:
            return None
        victim = max(cands, key=lambda r: self._victim_rank(r, now))
        if self._victim_rank(victim, now)[:2] > self._victim_rank(head,
                                                                  now)[:2]:
            return victim
        return None

    # -- overload ------------------------------------------------------------
    def pressure(self, queued_seconds: float) -> int:
        """0 nominal · 1 soft (degrade spec drafts) · 2 hard (shed)."""
        if queued_seconds >= self.hard_overload_s:
            return 2
        if queued_seconds >= self.soft_overload_s:
            return 1
        return 0

    @staticmethod
    def shed_tier(waiting: Sequence) -> Optional[int]:
        """The tier shed first under hard overload: the lowest-priority
        (numerically highest) tier currently waiting — relative, so an
        all-``normal`` workload still sheds rather than livelocking."""
        tiers = [r.slo.tier for r in waiting]
        return max(tiers) if tiers else None


POLICIES = {"slo": SLOPolicy, "fifo": FIFOPolicy}


def make_policy(name: str, **kw):
    """Policy by name; kwargs reach the policy constructor (``fifo``
    takes none — its point is having no knobs)."""
    if name not in POLICIES:
        raise ValueError(f"unknown admission policy {name!r} "
                         f"(have {sorted(POLICIES)})")
    if name == "fifo":
        return FIFOPolicy()
    return SLOPolicy(**kw)


# ---------------------------------------------------------------------------
# SLO-aware multi-model routing (the LRTF generalization multi.py uses)
# ---------------------------------------------------------------------------

def most_urgent(engines: Sequence, now: float,
                margin_s: float = 0.5) -> Optional[int]:
    """Index of the engine whose tightest deadline is closest to being
    missed — but only when some engine's slack is inside ``margin_s``
    (deadline pressure is real); otherwise None, and the caller falls
    back to LRTF's throughput-optimal pick.  This generalizes the LRTF
    router: identical behavior with no deadlines declared, EDF across
    engines when deadlines bite."""
    best: Optional[tuple[float, int]] = None
    for i, eng in enumerate(engines):
        slack = eng.min_slack_seconds(now)
        if slack is None or slack >= margin_s:
            continue
        if best is None or slack < best[0]:
            best = (slack, i)
    return best[1] if best else None
