"""DecodeBackend: one surface for decode-state placement + admission cost.

``InferenceEngine`` owns the request lifecycle (queue, bucketing, prefill
grouping, metrics); a **backend** owns where decode state lives and what a
request's residency costs.  The engine selects a backend object once and
never branches on layout again — adding a backend (or a feature inside
one) touches no engine call sites.  The protocol:

    free_lanes                      -> lanes available for admission
    admission_check(req, rows)      -> raise iff the request can NEVER fit
    reserve(req, rows) -> bool      -> admission: lane + byte reservation
    release(req)                    -> retire: free lane, release bytes
    fresh_states(n, rows)           -> transient states for a prefill group
    write_prefill(group, states)    -> move prefilled rows into the backend
    decode(params, tokens, active)  -> one pooled decode step (all lanes)
    advance(lane)                   -> post-token bookkeeping
    summary()                       -> backend-specific metric extras

Two implementations:

* ``SlotBackend`` — every request owns a ``max_seq``-sized slot of a
  stacked decode-state pool; admission charges a constant ``slot_bytes``.
  Works for every servable family.
* ``PagedBackend`` — K/V lives in a refcounted ``BlockPool`` of fixed-size
  blocks; admission reserves only the blocks the request's actual
  prompt + decode extent can touch, charged against a ``DeviceMemory``
  ledger.  Ships **copy-on-write prefix sharing**: requests with a common
  block-aligned prompt prefix alias the same physical pages (refcounted),
  admission charges only the unshared blocks, and the first write past the
  shared extent copies the boundary block before touching it — outputs
  stay token-identical to unshared decode while common-prefix workloads
  admit strictly more concurrency under the same byte budget
  (tests/test_prefix_sharing.py, ``make backend-smoke``).

Both charge their reservations through the same budget shapes
(``KVBudget`` / ``PagedKVBudget`` over ``core.spilling.DeviceMemory``), so
a session's device byte ledger arbitrates decode state exactly like SHARP
shard promotions.
"""

from __future__ import annotations

import hashlib
from functools import lru_cache
from typing import Optional, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.registry import spec as family_spec
from repro.serving.paging import (BlockPool, blocks_for_rows,
                                  default_n_blocks)
from repro.serving.queue import KVBudget, PagedKVBudget
from repro.serving.request import Request
from repro.serving.slots import SlotPool, stack_trees, write_slots
from repro.training.train_loop import make_decode_step, make_paged_decode_step


@runtime_checkable
class DecodeBackend(Protocol):
    """Structural protocol every decode backend implements (see module
    docstring for the call contract)."""

    name: str

    @property
    def free_lanes(self) -> int: ...

    def admission_check(self, req: Request, prefill_rows: int) -> None: ...

    def reserve(self, req: Request, prefill_rows: int) -> bool: ...

    def release(self, req: Request) -> None: ...

    def fresh_states(self, n: int, prefill_rows: int): ...

    def write_prefill(self, group: Sequence[Request], states) -> None: ...

    def decode(self, params, tokens: np.ndarray,
               active: dict) -> np.ndarray: ...

    def advance(self, lane: int) -> None: ...

    def summary(self) -> dict: ...


# ---------------------------------------------------------------------------
# compiled decode programs (module-level caches: a fresh backend for an
# already-loaded model never recompiles)
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _compiled_decode(cfg, window):
    """Slot decode vmapped over the slot axis; the pre-step pool state is
    donated so XLA updates the KV cache in place instead of copying the
    whole pool every tick."""
    return jax.jit(jax.vmap(make_decode_step(cfg, window=window),
                            in_axes=(None, 0, 0)), donate_argnums=(1,))


@lru_cache(maxsize=None)
def _compiled_paged_decode(cfg, window, impl):
    """One-token decode through block tables, pages donated in place."""
    return jax.jit(make_paged_decode_step(cfg, window=window, impl=impl),
                   donate_argnums=(1,))


@lru_cache(maxsize=None)
def _compiled_page_scatter(block_size):
    """Scatter freshly prefilled contiguous KV rows into physical blocks.

    k/v_new: (n, L, 1, W, nkv, hd) stacked prefill output, W a multiple of
    ``block_size``; ids: (n * W/bs,) physical block per logical block, all
    requests concatenated (aliased blocks are redirected to the garbage
    block — their owner already holds identical rows).  Pages are donated
    — the scatter updates the pool in place instead of copying every page
    per admission."""
    def scatter(kp, vp, k_new, v_new, ids):
        n, L, _, W, nkv, hd = k_new.shape
        nb = W // block_size

        def resh(a):
            a = a[:, :, 0].transpose(1, 0, 2, 3, 4)        # (L, n, W, kv, hd)
            return a.reshape(L, n * nb, block_size, nkv, hd)

        kp = kp.at[:, ids].set(resh(k_new).astype(kp.dtype))
        vp = vp.at[:, ids].set(resh(v_new).astype(vp.dtype))
        return kp, vp

    return jax.jit(scatter, donate_argnums=(0, 1))


@lru_cache(maxsize=None)
def _compiled_page_copy():
    """Copy one physical block's rows (all layers) src -> dst: the
    copy-on-write primitive.  Pages donated — an in-place row copy, not a
    pool copy."""
    def copy(kp, vp, src, dst):
        kp = kp.at[:, dst].set(kp[:, src])
        vp = vp.at[:, dst].set(vp[:, src])
        return kp, vp

    return jax.jit(copy, donate_argnums=(0, 1))


# ---------------------------------------------------------------------------
# slot backend
# ---------------------------------------------------------------------------

class SlotBackend:
    """Fixed slot pool: constant ``slot_bytes`` admission, every family."""

    name = "slot"

    def __init__(self, cfg, capacity: int, max_seq: int, *,
                 window: Optional[int] = None,
                 kv_budget_bytes: Optional[int] = None, ledger=None):
        self.cfg = cfg
        self.capacity = capacity
        self.max_seq = max_seq
        self.slot_bytes = family_spec(cfg).decode_state_bytes(cfg, 1, max_seq)
        self.pool = SlotPool(cfg, capacity, max_seq)
        self.ledger = ledger
        if ledger is not None:
            if kv_budget_bytes is not None:
                raise ValueError(
                    "pass either a shared DeviceMemory ledger or a private "
                    "kv_budget_bytes, not both")
            # slot-granular reservations against the shared device ledger:
            # one budget arbitrates slots, pages, and SHARP promotions
            self.budget = PagedKVBudget(ledger, self.slot_bytes)
        else:
            self.budget = KVBudget(kv_budget_bytes, self.slot_bytes)
        self._decode = _compiled_decode(cfg, window)

    @property
    def free_lanes(self) -> int:
        return self.pool.n_free

    def admission_check(self, req: Request, prefill_rows: int) -> None:
        if isinstance(self.budget, PagedKVBudget) \
                and self.slot_bytes > self.ledger.budget:
            raise ValueError(
                f"one decode slot costs {self.slot_bytes} B but the ledger "
                f"budget is {self.ledger.budget} B — the engine can never "
                "admit this request")

    def _reserve_one(self) -> bool:
        if isinstance(self.budget, PagedKVBudget):
            return self.budget.reserve(1)
        return self.budget.reserve()

    def reserve(self, req: Request, prefill_rows: int) -> bool:
        if not self._reserve_one():
            return False
        req.slot = self.pool.alloc(req.request_id)
        return True

    def release(self, req: Request) -> None:
        self.pool.free(req.slot)
        if isinstance(self.budget, PagedKVBudget):
            self.budget.release(1)
        else:
            self.budget.release()

    def fresh_states(self, n: int, prefill_rows: int):
        return self.pool.fresh_states(n)

    def write_prefill(self, group: Sequence[Request], states) -> None:
        slots = [r.slot for r in group]
        self.pool.state = write_slots(self.pool.state, states, slots)

    def decode(self, params, tokens: np.ndarray, active: dict) -> np.ndarray:
        toks = jnp.asarray(tokens)
        ntoks, self.pool.state = self._decode(params, self.pool.state, toks)
        # np.array (copy): asarray of a jax array is a read-only view, and
        # admission writes freshly prefilled tokens into this buffer
        return np.array(jax.block_until_ready(ntoks), np.int32)

    def advance(self, lane: int) -> None:
        pass

    def summary(self) -> dict:
        return {}


# ---------------------------------------------------------------------------
# paged backend (block-granular admission + copy-on-write prefix sharing)
# ---------------------------------------------------------------------------

class PagedBackend:
    """Refcounted block pool; admission charges only unshared blocks."""

    name = "paged"

    def __init__(self, cfg, capacity: int, max_seq: int, *,
                 window: Optional[int] = None, block_size: int = 16,
                 n_blocks: Optional[int] = None,
                 kv_budget_bytes: Optional[int] = None, ledger=None,
                 paged_impl: Optional[str] = None,
                 prefix_share: bool = True):
        from repro.core.spilling import DeviceMemory
        from repro.kernels import ops as kops
        if ledger is not None and kv_budget_bytes is not None:
            raise ValueError(
                "pass either a shared DeviceMemory ledger or a private "
                "kv_budget_bytes, not both")
        self.cfg = cfg
        self.capacity = capacity
        self.max_seq = max_seq
        self.block_size = block_size
        self.prefix_share = bool(prefix_share)
        self.max_blocks = blocks_for_rows(max_seq, block_size)
        block_bytes = family_spec(cfg).kv_block_bytes(cfg, block_size)
        worst = default_n_blocks(capacity, max_seq, block_size, n_blocks)
        if ledger is None:
            budget = (kv_budget_bytes if kv_budget_bytes is not None
                      else (worst - 1) * block_bytes)
            if budget < block_bytes:
                raise ValueError(
                    f"KV budget {budget} B below one block "
                    f"({block_bytes} B): nothing could ever be admitted")
            ledger = DeviceMemory(-1, budget)
        self.ledger = ledger
        if n_blocks is None:
            # never materialize pages the byte budget can't admit anyway:
            # cap the physical pool at the budget's worth of blocks
            worst = max(2, min(worst,
                               int(ledger.budget) // block_bytes + 1))
        self.pool = BlockPool(cfg, worst, block_size)
        self.budget = PagedKVBudget(ledger, self.pool.block_bytes)
        self.paged_impl = paged_impl or kops.default_paged_impl()
        self._decode = _compiled_paged_decode(cfg, window, self.paged_impl)
        self._page_scatter = _compiled_page_scatter(block_size)
        self._page_copy = _compiled_page_copy()
        self._tables = np.full((capacity, self.max_blocks),
                               BlockPool.GARBAGE, np.int32)
        self._lengths = np.zeros((capacity,), np.int32)
        self._lane_free = list(range(capacity - 1, -1, -1))
        self._lane_blocks: dict[int, list[int]] = {}   # logical -> physical
        self._lane_owned: dict[int, set[int]] = {}     # charge-owned blocks
        self._committed_blocks = 0   # sum of active reservations + orphans
        self._fresh_by_width: dict[int, object] = {}
        # prefix index: full-block token chains -> physical block, plus a
        # parent-chain children map for boundary (partial-block) matches
        self._index: dict[bytes, int] = {}
        self._children: dict[bytes, list[int]] = {}
        self._block_tokens: dict[int, np.ndarray] = {}
        self._rev: dict[int, tuple] = {}               # bid -> (key, parent)
        self._orphans: set[int] = set()  # charged blocks whose owner retired
        self.shared_block_hits = 0       # blocks aliased instead of allocated
        self.cow_copies = 0              # copy-on-write block copies

    # -- sizing --------------------------------------------------------------
    def _prefill_width(self, prefill_rows: int) -> int:
        """Contiguous rows the prefill writes, rounded up to whole blocks
        (the scatter moves whole blocks; the round-up tail is masked)."""
        return blocks_for_rows(prefill_rows,
                               self.block_size) * self.block_size

    def _worst_blocks(self, req: Request, prefill_rows: int) -> int:
        """Blocks for the WORST CASE this request can touch — its prefill
        footprint or its full decode extent, whichever is larger."""
        rows = max(self._prefill_width(prefill_rows),
                   req.prompt_len + req.max_new_tokens - 1)
        return blocks_for_rows(rows, self.block_size)

    @property
    def free_lanes(self) -> int:
        return len(self._lane_free)

    # -- prefix matching -----------------------------------------------------
    def _chain_keys(self, prompt: np.ndarray, n_full: int) -> list[bytes]:
        """Cumulative-content keys for the prompt's full blocks: key[j]
        digests tokens [0, (j+1)*bs).  One incremental hash walk — O(plen)
        total with O(1)-sized keys, instead of storing every byte prefix."""
        h = hashlib.sha256()
        keys = []
        bs = self.block_size
        for j in range(n_full):
            h.update(prompt[j * bs:(j + 1) * bs].tobytes())
            keys.append(h.digest())
        return keys

    _ROOT = b"root"          # parent key of block 0's chain

    def _match_prefix(self, prompt: np.ndarray):
        """Physical blocks this prompt can alias: the longest run of fully
        covered prompt blocks whose token chains are indexed, plus (when
        every full block matched) a boundary block whose indexed tokens
        start with the prompt's partial tail."""
        if not self.prefix_share:
            return [], None
        bs = self.block_size
        plen = int(prompt.shape[0])
        n_full = plen // bs
        keys = self._chain_keys(prompt, n_full)
        aliased: list[int] = []
        for j in range(n_full):
            bid = self._index.get(keys[j])
            if bid is None:
                break
            aliased.append(bid)
        boundary = None
        tail = plen - n_full * bs
        if tail and len(aliased) == n_full:
            parent = keys[n_full - 1] if n_full else self._ROOT
            for bid in self._children.get(parent, ()):
                toks = self._block_tokens.get(bid)
                if toks is not None and toks.shape[0] >= tail \
                        and bool((toks[:tail] == prompt[n_full * bs:]).all()):
                    boundary = bid
                    break
        return aliased, boundary

    def _register_prefix(self, req: Request, n_aliased: int,
                         boundary_aliased: bool) -> None:
        """Index this request's OWNED prompt blocks so later arrivals can
        alias them (aliased blocks are already indexed by their owner).
        ``_block_tokens`` keeps each indexed block's own tokens so a chain
        match is confirmed against real content at alias time — boundary
        matches compare tokens; full-block matches ride on the digest."""
        if not self.prefix_share:
            return
        bs = self.block_size
        prompt = req.prompt
        plen = req.prompt_len
        blocks = self._lane_blocks[req.slot]
        n_full = plen // bs
        keys = self._chain_keys(prompt, n_full)
        for j in range(n_aliased, n_full):
            bid = blocks[j]
            key = keys[j]
            parent = keys[j - 1] if j else self._ROOT
            self._index[key] = bid
            self._children.setdefault(parent, []).append(bid)
            self._block_tokens[bid] = prompt[j * bs:(j + 1) * bs]
            self._rev[bid] = (key, parent)
        tail = plen - n_full * bs
        if tail and not boundary_aliased and n_full < len(blocks):
            # partial boundary block: no full chain key, but boundary-
            # matchable by later arrivals whose tail it covers
            bid = blocks[n_full]
            parent = keys[n_full - 1] if n_full else self._ROOT
            self._children.setdefault(parent, []).append(bid)
            self._block_tokens[bid] = prompt[n_full * bs:plen]
            self._rev[bid] = (None, parent)

    def _unindex(self, bid: int) -> None:
        entry = self._rev.pop(bid, None)
        if entry is None:
            return
        key, parent = entry
        if key is not None:
            self._index.pop(key, None)
        kids = self._children.get(parent)
        if kids is not None:
            kids.remove(bid)
            if not kids:
                del self._children[parent]
        self._block_tokens.pop(bid, None)

    # -- admission -----------------------------------------------------------
    def admission_check(self, req: Request, prefill_rows: int) -> None:
        """Reject requests that can NEVER fit even unshared — queued
        forever at the FIFO head they would livelock admission."""
        nb = self._worst_blocks(req, prefill_rows)
        if nb > self.pool.n_allocatable \
                or nb * self.pool.block_bytes > self.ledger.budget:
            raise ValueError(
                f"request needs {nb} KV blocks "
                f"({nb * self.pool.block_bytes} B) but the engine can "
                f"never admit more than {self.pool.n_allocatable} "
                f"blocks / {self.ledger.budget} B — raise the KV "
                "budget or lower max_new_tokens")

    def reserve(self, req: Request, prefill_rows: int) -> bool:
        nb_worst = self._worst_blocks(req, prefill_rows)
        aliased, boundary = self._match_prefix(req.prompt)
        # fully shared aligned blocks are never written by this request
        # (its first decode row lands past them), so only unshared blocks
        # are charged; an aliased boundary block still charges one block —
        # its copy-on-write copy at the first decode write
        need = nb_worst - len(aliased)
        if self._committed_blocks + need > self.pool.n_allocatable:
            return False
        if not self.budget.reserve(need):
            return False
        req.reserved_blocks = need
        self._committed_blocks += need
        lane = self._lane_free.pop()
        nb0 = self._prefill_width(prefill_rows) // self.block_size
        owned = self.pool.alloc(nb0 - len(aliased) - bool(boundary))
        blocks = [self.pool.incref(b) for b in aliased]
        if boundary is not None:
            blocks.append(self.pool.incref(boundary))
        self.shared_block_hits += len(blocks)
        req.shared_blocks = len(blocks)
        blocks.extend(owned)
        self._lane_blocks[lane] = blocks
        self._lane_owned[lane] = set(owned)
        self._tables[lane, :] = BlockPool.GARBAGE
        self._tables[lane, :nb0] = blocks
        self._lengths[lane] = 0
        req.peak_blocks = nb0
        req.slot = lane
        self._register_prefix(req, len(aliased), boundary is not None)
        return True

    # -- retirement ----------------------------------------------------------
    def _drop_alias(self, bid: int) -> None:
        """Drop a non-owned reference; if that frees the block, settle the
        orphan charge its dead owner left behind."""
        if self.pool.decref(bid) == 0:
            self._unindex(bid)
            if bid in self._orphans:
                self._orphans.discard(bid)
                self.budget.release(1)
                self._committed_blocks -= 1

    def release(self, req: Request) -> None:
        lane = req.slot
        blocks = self._lane_blocks.pop(lane)
        owned = self._lane_owned.pop(lane)
        orphaned = 0
        for bid in blocks:
            if bid in owned:
                if self.pool.decref(bid) == 0:
                    self._unindex(bid)
                else:
                    # still aliased by a live sharer: keep the block's
                    # charge alive as an engine-held orphan until the
                    # last reference drops
                    self._orphans.add(bid)
                    orphaned += 1
            else:
                self._drop_alias(bid)
        self.budget.release(req.reserved_blocks - orphaned)
        self._committed_blocks -= req.reserved_blocks - orphaned
        self._tables[lane, :] = BlockPool.GARBAGE
        self._lengths[lane] = 0
        self._lane_free.append(lane)

    # -- prefill -------------------------------------------------------------
    def fresh_states(self, n: int, prefill_rows: int):
        """Transient block-aligned-width states — just wide enough for the
        prompt group; the rows are scattered into pages and the temporary
        is dropped, so peak transient bytes stay O(prompt)."""
        width = self._prefill_width(prefill_rows)
        tmpl = self._fresh_by_width.get(width)
        if tmpl is None:
            tmpl = api.init_decode_state(self.cfg, 1, width)
            self._fresh_by_width[width] = tmpl
        return stack_trees([tmpl] * n)

    def write_prefill(self, group: Sequence[Request], states) -> None:
        """Scatter a prefilled contiguous group into the block pool pages.
        Aliased blocks are redirected to the garbage block: their owner
        already wrote identical rows (same tokens, same positions)."""
        ids = np.concatenate([
            [bid if bid in self._lane_owned[r.slot] else BlockPool.GARBAGE
             for bid in self._lane_blocks[r.slot]]
            for r in group]).astype(np.int32)
        kp, vp = self._page_scatter(
            self.pool.pages["k"], self.pool.pages["v"],
            states["kv"]["k"], states["kv"]["v"], jnp.asarray(ids))
        self.pool.pages = {"k": kp, "v": vp}
        for r in group:
            self._lengths[r.slot] = r.prompt_len

    # -- decode --------------------------------------------------------------
    def _prepare_lanes(self, active: dict) -> None:
        """Make every active lane's next write row safe: allocate the block
        it lands in (the admission reservation guarantees this can never
        fail), and copy-on-write any aliased block about to be written —
        the write would otherwise clobber rows other lanes are reading."""
        for lane, req in active.items():
            j = int(self._lengths[lane]) // self.block_size
            blocks = self._lane_blocks[lane]
            owned = self._lane_owned[lane]
            while len(blocks) <= j:
                (bid,) = self.pool.alloc(1)
                self._tables[lane, len(blocks)] = bid
                blocks.append(bid)
                owned.add(bid)
            if blocks[j] not in owned:
                (dst,) = self.pool.alloc(1)
                src = blocks[j]
                kp, vp = self._page_copy(
                    self.pool.pages["k"], self.pool.pages["v"], src, dst)
                self.pool.pages = {"k": kp, "v": vp}
                self._tables[lane, j] = dst
                blocks[j] = dst
                owned.add(dst)
                self.cow_copies += 1
                self._drop_alias(src)
            req.peak_blocks = max(req.peak_blocks or 0, len(blocks))

    def decode(self, params, tokens: np.ndarray, active: dict) -> np.ndarray:
        self._prepare_lanes(active)
        ntoks, self.pool.pages = self._decode(
            params, self.pool.pages, jnp.asarray(self._tables),
            jnp.asarray(self._lengths), jnp.asarray(tokens[:, 0, :]))
        return np.array(jax.block_until_ready(ntoks), np.int32)[:, None, :]

    def advance(self, lane: int) -> None:
        self._lengths[lane] += 1

    def summary(self) -> dict:
        return {
            "block_size": self.block_size,
            "block_bytes": self.pool.block_bytes,
            "n_blocks": self.pool.n_blocks,
            "kv_page_peak_bytes": self.pool.peak_bytes(),
            "kv_block_allocs": self.pool.total_allocs,
            "paged_impl": self.paged_impl,
            "prefix_share": self.prefix_share,
            "shared_block_hits": self.shared_block_hits,
            "cow_copies": self.cow_copies,
        }


BACKENDS = {"slot": SlotBackend, "paged": PagedBackend}


def make_backend(name: str, cfg, capacity: int, max_seq: int, **kw):
    """Construct a backend by name, dropping kwargs it does not take."""
    if name not in BACKENDS:
        raise ValueError(f"unknown decode backend {name!r} "
                         f"(have {sorted(BACKENDS)})")
    if name == "slot":
        kw = {k: v for k, v in kw.items()
              if k in ("window", "kv_budget_bytes", "ledger")}
    return BACKENDS[name](cfg, capacity, max_seq, **kw)
