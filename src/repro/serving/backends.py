"""DecodeBackend: one surface for decode-state placement + admission cost.

``InferenceEngine`` owns the request lifecycle (queue, bucketing, prefill
grouping, metrics); a **backend** owns where decode state lives and what a
request's residency costs.  The engine selects a backend object once and
never branches on layout again — adding a backend (or a feature inside
one) touches no engine call sites.  The protocol:

    free_lanes                      -> lanes available for admission
    admission_check(req, rows)      -> raise iff the request can NEVER fit
    reserve(req, rows) -> bool      -> admission: lane + byte reservation
    release(req)                    -> retire: free lane, release bytes
    fresh_states(n, rows)           -> transient states for a prefill group
    write_prefill(group, states)    -> move prefilled rows into the backend
    decode(params, tokens, active)  -> one pooled decode step (all lanes)
    advance(lane)                   -> post-token bookkeeping
    summary()                       -> backend-specific metric extras

Backends that can deschedule a RUNNING request additionally declare
``preemptible = True`` and implement the preemption trio the SLO
scheduler (``serving/slo.py``) drives:

    preempt(req)                    -> snapshot lane state, free the lane
    resume(req) -> bool             -> re-attach the snapshot to a lane
    discard_preempted(req)          -> drop the snapshot (cancel/shed)

Only the paged backend qualifies: its per-lane state is a block table
over refcounted pages, so a snapshot is O(blocks) of integers and the
KV bytes (still reserved) never move.  The slot pool's KV is a
contiguous per-lane buffer and the spec backend advances a draft model
in lockstep — both declare ``preemptible = False`` with a
``preempt_reason`` the capability machinery surfaces.

Three implementations:

* ``SlotBackend`` — every request owns a ``max_seq``-sized slot of a
  stacked decode-state pool; admission charges a constant ``slot_bytes``.
  Works for every servable family.
* ``SpecDecodeBackend`` — speculative decoding over an inner slot or
  paged backend: a draft member model proposes ``draft_k`` tokens per
  round, the target verifies all of them in ONE batched forward, and
  greedy-exact acceptance keeps outputs token-identical to plain decode
  while target forwards per token drop toward 1/k (docs/serving.md).
  ``spec_draftable`` families only (dense/vlm), target and draft both.
* ``PagedBackend`` — K/V lives in a refcounted ``BlockPool`` of fixed-size
  blocks; admission reserves only the blocks the request's actual
  prompt + decode extent can touch, charged against a ``DeviceMemory``
  ledger.  Ships **copy-on-write prefix sharing**: requests with a common
  block-aligned prompt prefix alias the same physical pages (refcounted),
  admission charges only the unshared blocks, and the first write past the
  shared extent copies the boundary block before touching it — outputs
  stay token-identical to unshared decode while common-prefix workloads
  admit strictly more concurrency under the same byte budget
  (tests/test_prefix_sharing.py, ``make backend-smoke``).

Both charge their reservations through the same budget shapes
(``KVBudget`` / ``PagedKVBudget`` over ``core.spilling.DeviceMemory``), so
a session's device byte ledger arbitrates decode state exactly like SHARP
shard promotions.
"""

from __future__ import annotations

import hashlib
import itertools
from collections import deque
from functools import lru_cache
from typing import Optional, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.registry import spec as family_spec
from repro.serving.paging import (BlockPool, HostBlockPool, blocks_for_rows,
                                  default_n_blocks)
from repro.serving.queue import KVBudget, PagedKVBudget
from repro.serving.request import Request
from repro.serving.slots import SlotPool, stack_trees, write_slots
from repro.training.train_loop import (make_decode_step,
                                       make_paged_decode_step,
                                       make_paged_verify_step,
                                       make_prefill_into_cache,
                                       make_verify_step)


@runtime_checkable
class DecodeBackend(Protocol):
    """Structural protocol every decode backend implements (see module
    docstring for the call contract)."""

    name: str

    @property
    def free_lanes(self) -> int: ...

    def admission_check(self, req: Request, prefill_rows: int) -> None: ...

    def reserve(self, req: Request, prefill_rows: int) -> bool: ...

    def release(self, req: Request) -> None: ...

    def fresh_states(self, n: int, prefill_rows: int): ...

    def write_prefill(self, group: Sequence[Request], states) -> None: ...

    def decode(self, params, tokens: np.ndarray,
               active: dict) -> np.ndarray: ...

    def advance(self, lane: int) -> None: ...

    def summary(self) -> dict: ...


# ---------------------------------------------------------------------------
# compiled decode programs (module-level caches: a fresh backend for an
# already-loaded model never recompiles)
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _compiled_decode(cfg, window):
    """Slot decode vmapped over the slot axis; the pre-step pool state is
    donated so XLA updates the KV cache in place instead of copying the
    whole pool every tick."""
    return jax.jit(jax.vmap(make_decode_step(cfg, window=window),
                            in_axes=(None, 0, 0)), donate_argnums=(1,))


@lru_cache(maxsize=None)
def _compiled_paged_decode(cfg, window, impl):
    """One-token decode through block tables, pages donated in place."""
    return jax.jit(make_paged_decode_step(cfg, window=window, impl=impl),
                   donate_argnums=(1,))


@lru_cache(maxsize=None)
def _compiled_verify(cfg, window):
    """Slot speculative verify vmapped over the slot axis: k draft
    positions scored in ONE target forward per lane, pool donated."""
    return jax.jit(jax.vmap(make_verify_step(cfg, window=window),
                            in_axes=(None, 0, 0)), donate_argnums=(1,))


@lru_cache(maxsize=None)
def _compiled_paged_verify(cfg, window, impl):
    """Paged speculative verify: k rows written + scored through block
    tables in one forward, pages donated in place."""
    return jax.jit(make_paged_verify_step(cfg, window=window, impl=impl),
                   donate_argnums=(1,))


@lru_cache(maxsize=None)
def _compiled_rollback(cfg):
    """Per-lane KV index rewind (the slot-side speculative rollback);
    the state is donated — only the index leaf changes."""
    from repro.models import api as mapi

    def roll(state, delta):
        return mapi.rollback_decode_state(cfg, state, delta)

    return jax.jit(roll, donate_argnums=(0,))


@lru_cache(maxsize=None)
def _compiled_draft_chain(cfg, window, k):
    """k sequential greedy draft steps fused into ONE jitted program
    (``lax.scan`` over the vmapped decode step): one dispatch and one
    device sync per round instead of k — the draft chain has no host
    decision between steps.  Returns ``(drafts (k, S, 1, 1), state)``."""
    vstep = jax.vmap(make_decode_step(cfg, window=window),
                     in_axes=(None, 0, 0))

    def chain(params, state, toks):
        def body(carry, _):
            toks, state = carry
            ntoks, state = vstep(params, state, toks)
            return (ntoks, state), ntoks

        (_, state), drafts = jax.lax.scan(body, (toks, state), None,
                                          length=k)
        return drafts, state

    return jax.jit(chain, donate_argnums=(1,))


@lru_cache(maxsize=None)
def _compiled_draft_prefill(cfg, window):
    """Draft-model prefill (vmapped over batch=1 groups), states donated.
    Mirrors the engine's compiled prefill, cached per draft config."""
    return jax.jit(jax.vmap(make_prefill_into_cache(cfg, window=window),
                            in_axes=(None, 0, 0)), donate_argnums=(1,))


@lru_cache(maxsize=None)
def _compiled_page_scatter(block_size, quant=False):
    """Scatter freshly prefilled contiguous KV rows into physical blocks.

    k/v_new: (n, L, 1, W, nkv, hd) stacked prefill output, W a multiple of
    ``block_size``; ids: (n * W/bs,) physical block per logical block, all
    requests concatenated (aliased blocks are redirected to the garbage
    block — their owner already holds identical rows).  The pages pytree
    is donated — the scatter updates the pool in place instead of copying
    every page per admission.  ``quant`` pools quantize the rows per-row
    on the way in and land the scales in the scale planes — prefill
    states stay fp; only the pool is int8."""
    def scatter(pages, k_new, v_new, ids):
        n, L, _, W, nkv, hd = k_new.shape
        nb = W // block_size

        def resh(a):
            a = a[:, :, 0].transpose(1, 0, 2, 3, 4)        # (L, n, W, kv, hd)
            return a.reshape(L, n * nb, block_size, nkv, hd)

        k_r, v_r = resh(k_new), resh(v_new)
        if quant:
            from repro.kernels import ref as kref
            kq, ks = kref.quantize_kv(k_r)
            vq, vs = kref.quantize_kv(v_r)
            return {"k": pages["k"].at[:, ids].set(kq),
                    "v": pages["v"].at[:, ids].set(vq),
                    "k_scale": pages["k_scale"].at[:, ids].set(ks),
                    "v_scale": pages["v_scale"].at[:, ids].set(vs)}
        return {"k": pages["k"].at[:, ids].set(
                    k_r.astype(pages["k"].dtype)),
                "v": pages["v"].at[:, ids].set(
                    v_r.astype(pages["v"].dtype))}

    return jax.jit(scatter, donate_argnums=(0,))


@lru_cache(maxsize=None)
def _compiled_page_copy():
    """Copy one physical block's rows (all layers, every pages leaf —
    scale planes included for int8 pools) src -> dst: the copy-on-write
    primitive.  Pages donated — an in-place row copy, not a pool copy."""
    def copy(pages, src, dst):
        return jax.tree.map(lambda p: p.at[:, dst].set(p[:, src]), pages)

    return jax.jit(copy, donate_argnums=(0,))


@lru_cache(maxsize=None)
def _compiled_block_write():
    """Write one block's host rows (a per-leaf dict mirroring the pages
    pytree) into a physical block across all layers: the tiered-KV
    prefetch landing step.  Pages donated, like the CoW copy — an
    in-place row write, not a pool copy."""
    def write(pages, bid, rows):
        return jax.tree.map(
            lambda p, r: p.at[:, bid].set(r.astype(p.dtype)), pages, rows)

    return jax.jit(write, donate_argnums=(0,))


# ---------------------------------------------------------------------------
# slot backend
# ---------------------------------------------------------------------------

class SlotBackend:
    """Fixed slot pool: constant ``slot_bytes`` admission, every family."""

    name = "slot"
    preemptible = False
    preempt_reason = ("slot KV is one contiguous per-lane buffer — "
                      "descheduling would copy the whole cache out or "
                      "replay the prompt; use backend='paged'")

    def __init__(self, cfg, capacity: int, max_seq: int, *,
                 window: Optional[int] = None,
                 kv_budget_bytes: Optional[int] = None, ledger=None,
                 verify_headroom: int = 0):
        self.cfg = cfg
        self.capacity = capacity
        self.max_seq = max_seq
        # verify_headroom: extra rows per slot for a wrapping speculative
        # backend's k-token verify writes (rows past the accept point are
        # rewound, but the buffer must exist); charged honestly
        self.slot_bytes = family_spec(cfg).decode_state_bytes(
            cfg, 1, max_seq + verify_headroom)
        self.pool = SlotPool(cfg, capacity, max_seq + verify_headroom)
        self.ledger = ledger
        if ledger is not None:
            if kv_budget_bytes is not None:
                raise ValueError(
                    "pass either a shared DeviceMemory ledger or a private "
                    "kv_budget_bytes, not both")
            # slot-granular reservations against the shared device ledger:
            # one budget arbitrates slots, pages, and SHARP promotions
            self.budget = PagedKVBudget(ledger, self.slot_bytes)
        else:
            self.budget = KVBudget(kv_budget_bytes, self.slot_bytes)
        self._decode = _compiled_decode(cfg, window)

    @property
    def free_lanes(self) -> int:
        return self.pool.n_free

    def admission_check(self, req: Request, prefill_rows: int) -> None:
        if isinstance(self.budget, PagedKVBudget) \
                and self.slot_bytes > self.ledger.budget:
            raise ValueError(
                f"one decode slot costs {self.slot_bytes} B but the ledger "
                f"budget is {self.ledger.budget} B — the engine can never "
                "admit this request")

    def _reserve_one(self) -> bool:
        if isinstance(self.budget, PagedKVBudget):
            return self.budget.reserve(1)
        return self.budget.reserve()

    def reserve(self, req: Request, prefill_rows: int) -> bool:
        if not self._reserve_one():
            return False
        req.slot = self.pool.alloc(req.request_id)
        return True

    def release(self, req: Request) -> None:
        self.pool.free(req.slot)
        if isinstance(self.budget, PagedKVBudget):
            self.budget.release(1)
        else:
            self.budget.release()

    def fresh_states(self, n: int, prefill_rows: int):
        return self.pool.fresh_states(n)

    def write_prefill(self, group: Sequence[Request], states) -> None:
        slots = [r.slot for r in group]
        self.pool.state = write_slots(self.pool.state, states, slots)

    def decode(self, params, tokens: np.ndarray, active: dict) -> np.ndarray:
        toks = jnp.asarray(tokens)
        ntoks, self.pool.state = self._decode(params, self.pool.state, toks)
        # np.array (copy): asarray of a jax array is a read-only view, and
        # admission writes freshly prefilled tokens into this buffer
        return np.array(jax.block_until_ready(ntoks), np.int32)

    def advance(self, lane: int) -> None:
        pass

    def summary(self) -> dict:
        return {}


# ---------------------------------------------------------------------------
# paged backend (block-granular admission + copy-on-write prefix sharing)
# ---------------------------------------------------------------------------

class PagedBackend:
    """Refcounted block pool; admission charges only unshared blocks."""

    name = "paged"
    preemptible = True
    preempt_reason = None

    def __init__(self, cfg, capacity: int, max_seq: int, *,
                 window: Optional[int] = None, block_size: int = 16,
                 n_blocks: Optional[int] = None,
                 kv_budget_bytes: Optional[int] = None, ledger=None,
                 paged_impl: Optional[str] = None,
                 prefix_share: bool = True, verify_headroom: int = 0,
                 tiered: bool = False, prefetch_ticks: int = 1,
                 kv_dtype: Optional[str] = None):
        from repro.core.spilling import DeviceMemory
        from repro.kernels import ops as kops
        if ledger is not None and kv_budget_bytes is not None:
            raise ValueError(
                "pass either a shared DeviceMemory ledger or a private "
                "kv_budget_bytes, not both")
        self.cfg = cfg
        self.capacity = capacity
        self.max_seq = max_seq
        self.block_size = block_size
        self.prefix_share = bool(prefix_share)
        # kv_dtype='int8' quantizes the paged pool (per-row scales stored
        # alongside the pages); block_bytes shrinks ~3.8x, so the same
        # byte budget admits proportionally more blocks.  Validated (and
        # priced) through the family registry's kv_quant capability.
        self.kv_dtype = "fp" if kv_dtype in (None, "fp") else kv_dtype
        # extra rows per lane a wrapping speculative backend's k-token
        # verify may transiently write past the decode extent; folded into
        # every worst-case reservation so verify allocation can never fail
        self.verify_headroom = verify_headroom
        self.max_blocks = blocks_for_rows(max_seq + verify_headroom,
                                          block_size)
        block_bytes = family_spec(cfg).kv_block_bytes(cfg, block_size,
                                                      self.kv_dtype)
        worst = default_n_blocks(capacity, max_seq + verify_headroom,
                                 block_size, n_blocks)
        if ledger is None:
            budget = (kv_budget_bytes if kv_budget_bytes is not None
                      else (worst - 1) * block_bytes)
            if budget < block_bytes:
                raise ValueError(
                    f"KV budget {budget} B below one block "
                    f"({block_bytes} B): nothing could ever be admitted")
            ledger = DeviceMemory(-1, budget)
        self.ledger = ledger
        if n_blocks is None:
            # never materialize pages the byte budget can't admit anyway:
            # cap the physical pool at the budget's worth of blocks
            worst = max(2, min(worst,
                               int(ledger.budget) // block_bytes + 1))
        self.pool = BlockPool(cfg, worst, block_size, self.kv_dtype)
        self.budget = PagedKVBudget(ledger, self.pool.block_bytes)
        self.paged_impl = paged_impl or kops.default_paged_impl()
        self._decode = _compiled_paged_decode(cfg, window, self.paged_impl)
        self._page_scatter = _compiled_page_scatter(
            block_size, self.kv_dtype == "int8")
        self._page_copy = _compiled_page_copy()
        self._tables = np.full((capacity, self.max_blocks),
                               BlockPool.GARBAGE, np.int32)
        self._lengths = np.zeros((capacity,), np.int32)
        self._lane_free = list(range(capacity - 1, -1, -1))
        self._lane_blocks: dict[int, list[int]] = {}   # logical -> physical
        self._lane_owned: dict[int, set[int]] = {}     # charge-owned blocks
        self._committed_blocks = 0   # sum of active reservations + orphans
        self._fresh_by_width: dict[int, object] = {}
        # prefix index: full-block token chains -> physical block, plus a
        # parent-chain children map for boundary (partial-block) matches
        self._index: dict[bytes, int] = {}
        self._children: dict[bytes, list[int]] = {}
        self._block_tokens: dict[int, np.ndarray] = {}
        self._rev: dict[int, tuple] = {}               # bid -> (key, parent)
        self._orphans: set[int] = set()  # charged blocks whose owner retired
        # preemption parking lot: request_id -> (blocks, owned, length).
        # A preempted request's blocks stay refcounted and its byte
        # reservation stays charged — descheduling frees the LANE only, so
        # resume is a table re-attach with prefill skipped.
        self._preempted: dict[str, tuple[list[int], set[int], int]] = {}
        self.shared_block_hits = 0       # blocks aliased instead of allocated
        self.cow_copies = 0              # copy-on-write block copies
        # tiered KV (host-DRAM page demotion, docs/serving.md): parked
        # snapshots' private pages can leave the device for a host pool —
        # eagerly on preempt, or LRU-by-park-time under ledger pressure —
        # and prefetch back asynchronously before their lane resumes.
        self.tiered = bool(tiered)
        if prefetch_ticks < 1:
            raise ValueError("prefetch_ticks must be >= 1")
        self.prefetch_ticks = prefetch_ticks
        self.host_pool = (HostBlockPool(self.pool.block_bytes)
                          if self.tiered else None)
        self._block_write = _compiled_block_write()
        self._demoted: dict[str, dict[int, int]] = {}   # rid -> {j: hostkey}
        self._prefetching: dict[str, dict] = {}         # rid -> staging
        self._park_seq = itertools.count()
        self._park_order: dict[str, int] = {}           # rid -> park stamp
        self._prefetch_done_late: dict[str, bool] = {}
        self.kv_demote_block_moves = 0      # device -> host block copies
        self.kv_prefetch_block_moves = 0    # host -> device block copies
        self.prefetch_hits = 0      # prefetch done before the lane needed it
        self.prefetch_misses = 0    # lane had to wait on an in-flight fetch
        if self.tiered:
            # failing reservations demote parked pages before giving up —
            # the mechanism that lets admission proceed past parked bytes
            self.ledger.on_pressure(self.relieve_pressure)

    # -- sizing --------------------------------------------------------------
    def _prefill_width(self, prefill_rows: int) -> int:
        """Contiguous rows the prefill writes, rounded up to whole blocks
        (the scatter moves whole blocks; the round-up tail is masked)."""
        return blocks_for_rows(prefill_rows,
                               self.block_size) * self.block_size

    def _worst_blocks(self, req: Request, prefill_rows: int) -> int:
        """Blocks for the WORST CASE this request can touch — its prefill
        footprint or its full decode extent (plus any speculative verify
        headroom), whichever is larger."""
        rows = max(self._prefill_width(prefill_rows),
                   req.prompt_len + req.max_new_tokens - 1
                   + self.verify_headroom)
        return blocks_for_rows(rows, self.block_size)

    @property
    def free_lanes(self) -> int:
        return len(self._lane_free)

    # -- prefix matching -----------------------------------------------------
    def _chain_keys(self, prompt: np.ndarray, n_full: int) -> list[bytes]:
        """Cumulative-content keys for the prompt's full blocks: key[j]
        digests tokens [0, (j+1)*bs).  One incremental hash walk — O(plen)
        total with O(1)-sized keys, instead of storing every byte prefix."""
        h = hashlib.sha256()
        keys = []
        bs = self.block_size
        for j in range(n_full):
            h.update(prompt[j * bs:(j + 1) * bs].tobytes())
            keys.append(h.digest())
        return keys

    _ROOT = b"root"          # parent key of block 0's chain

    def _match_prefix(self, prompt: np.ndarray):
        """Physical blocks this prompt can alias: the longest run of fully
        covered prompt blocks whose token chains are indexed, plus (when
        every full block matched) a boundary block whose indexed tokens
        start with the prompt's partial tail."""
        if not self.prefix_share:
            return [], None
        bs = self.block_size
        plen = int(prompt.shape[0])
        n_full = plen // bs
        keys = self._chain_keys(prompt, n_full)
        aliased: list[int] = []
        for j in range(n_full):
            bid = self._index.get(keys[j])
            if bid is None:
                break
            aliased.append(bid)
        boundary = None
        tail = plen - n_full * bs
        if tail and len(aliased) == n_full:
            parent = keys[n_full - 1] if n_full else self._ROOT
            for bid in self._children.get(parent, ()):
                toks = self._block_tokens.get(bid)
                if toks is not None and toks.shape[0] >= tail \
                        and bool((toks[:tail] == prompt[n_full * bs:]).all()):
                    boundary = bid
                    break
        return aliased, boundary

    def _register_prefix(self, req: Request, n_aliased: int,
                         boundary_aliased: bool) -> None:
        """Index this request's OWNED prompt blocks so later arrivals can
        alias them (aliased blocks are already indexed by their owner).
        ``_block_tokens`` keeps each indexed block's own tokens so a chain
        match is confirmed against real content at alias time — boundary
        matches compare tokens; full-block matches ride on the digest."""
        if not self.prefix_share:
            return
        bs = self.block_size
        prompt = req.prompt
        plen = req.prompt_len
        blocks = self._lane_blocks[req.slot]
        n_full = plen // bs
        keys = self._chain_keys(prompt, n_full)
        for j in range(n_aliased, n_full):
            bid = blocks[j]
            key = keys[j]
            parent = keys[j - 1] if j else self._ROOT
            self._index[key] = bid
            self._children.setdefault(parent, []).append(bid)
            self._block_tokens[bid] = prompt[j * bs:(j + 1) * bs]
            self._rev[bid] = (key, parent)
        tail = plen - n_full * bs
        if tail and not boundary_aliased and n_full < len(blocks):
            # partial boundary block: no full chain key, but boundary-
            # matchable by later arrivals whose tail it covers
            bid = blocks[n_full]
            parent = keys[n_full - 1] if n_full else self._ROOT
            self._children.setdefault(parent, []).append(bid)
            self._block_tokens[bid] = prompt[n_full * bs:plen]
            self._rev[bid] = (None, parent)

    def _unindex(self, bid: int) -> None:
        entry = self._rev.pop(bid, None)
        if entry is None:
            return
        key, parent = entry
        if key is not None:
            self._index.pop(key, None)
        kids = self._children.get(parent)
        if kids is not None:
            kids.remove(bid)
            if not kids:
                del self._children[parent]
        self._block_tokens.pop(bid, None)

    # -- admission -----------------------------------------------------------
    def admission_check(self, req: Request, prefill_rows: int) -> None:
        """Reject requests that can NEVER fit even unshared — queued
        forever at the FIFO head they would livelock admission."""
        nb = self._worst_blocks(req, prefill_rows)
        if nb > self.pool.n_allocatable \
                or nb * self.pool.block_bytes > self.ledger.budget:
            raise ValueError(
                f"request needs {nb} KV blocks "
                f"({nb * self.pool.block_bytes} B) but the engine can "
                f"never admit more than {self.pool.n_allocatable} "
                f"blocks / {self.ledger.budget} B — raise the KV "
                "budget or lower max_new_tokens")

    def reserve(self, req: Request, prefill_rows: int) -> bool:
        nb_worst = self._worst_blocks(req, prefill_rows)
        aliased, boundary = self._match_prefix(req.prompt)
        # fully shared aligned blocks are never written by this request
        # (its first decode row lands past them), so only unshared blocks
        # are charged; an aliased boundary block still charges one block —
        # its copy-on-write copy at the first decode write
        need = nb_worst - len(aliased)
        if self._committed_blocks + need > self.pool.n_allocatable:
            return False
        if not self.budget.reserve(need):
            return False
        req.reserved_blocks = need
        self._committed_blocks += need
        lane = self._lane_free.pop()
        nb0 = self._prefill_width(prefill_rows) // self.block_size
        owned = self.pool.alloc(nb0 - len(aliased) - bool(boundary))
        blocks = [self.pool.incref(b) for b in aliased]
        if boundary is not None:
            blocks.append(self.pool.incref(boundary))
        self.shared_block_hits += len(blocks)
        req.shared_blocks = len(blocks)
        blocks.extend(owned)
        self._lane_blocks[lane] = blocks
        self._lane_owned[lane] = set(owned)
        self._tables[lane, :] = BlockPool.GARBAGE
        self._tables[lane, :nb0] = blocks
        self._lengths[lane] = 0
        req.peak_blocks = nb0
        req.slot = lane
        self._register_prefix(req, len(aliased), boundary is not None)
        return True

    # -- retirement ----------------------------------------------------------
    def _drop_alias(self, bid: int) -> None:
        """Drop a non-owned reference; if that frees the block, settle the
        orphan charge its dead owner left behind."""
        if self.pool.decref(bid) == 0:
            self._unindex(bid)
            if bid in self._orphans:
                self._orphans.discard(bid)
                self.budget.release(1)
                self._committed_blocks -= 1

    def _release_blocks(self, blocks: list[int], owned: set[int],
                        reserved_blocks: int) -> None:
        """Settle a retiring block set's refcounts + byte charge (shared
        by lane release and preempted-snapshot discard)."""
        orphaned = 0
        for bid in blocks:
            if bid in owned:
                if self.pool.decref(bid) == 0:
                    self._unindex(bid)
                else:
                    # still aliased by a live sharer: keep the block's
                    # charge alive as an engine-held orphan until the
                    # last reference drops
                    self._orphans.add(bid)
                    orphaned += 1
            else:
                self._drop_alias(bid)
        self.budget.release(reserved_blocks - orphaned)
        self._committed_blocks -= reserved_blocks - orphaned

    def release(self, req: Request) -> None:
        lane = req.slot
        self._release_blocks(self._lane_blocks.pop(lane),
                             self._lane_owned.pop(lane),
                             req.reserved_blocks)
        self._tables[lane, :] = BlockPool.GARBAGE
        self._lengths[lane] = 0
        self._lane_free.append(lane)

    # -- preemption ----------------------------------------------------------
    def preempt(self, req: Request) -> None:
        """Deschedule a RUNNING request: park (block table, committed
        length) under its request_id and free the lane.  Refcounts and
        the byte reservation are untouched — the request still *holds*
        its KV, it just isn't decoding — so resume needs only a lane.
        (Tiered engines follow up with ``demote_parked`` so the parked
        bytes stop pinning device memory.)"""
        lane = req.slot
        self._preempted[req.request_id] = (
            self._lane_blocks.pop(lane), self._lane_owned.pop(lane),
            int(self._lengths[lane]))
        self._park_order[req.request_id] = next(self._park_seq)
        self._tables[lane, :] = BlockPool.GARBAGE
        self._lengths[lane] = 0
        self._lane_free.append(lane)

    def resume(self, req: Request) -> bool:
        """Re-attach a preempted request's snapshot to a free lane.  The
        KV rows never moved (or have been prefetched back), so the caller
        skips prefill and resumes decode from the last generated token.
        Demoted / still-prefetching snapshots refuse: the engine must
        drive ``start_prefetch`` + ``poll_prefetches`` first."""
        rid = req.request_id
        if not self._lane_free or self._demoted.get(rid) \
                or rid in self._prefetching:
            return False
        blocks, owned, length = self._preempted.pop(rid)
        self._park_order.pop(rid, None)
        late = self._prefetch_done_late.pop(rid, None)
        if late is not None:
            self.prefetch_misses += int(late)
            self.prefetch_hits += int(not late)
        lane = self._lane_free.pop()
        self._lane_blocks[lane] = blocks
        self._lane_owned[lane] = owned
        self._tables[lane, :] = BlockPool.GARBAGE
        self._tables[lane, :len(blocks)] = blocks
        self._lengths[lane] = length
        req.slot = lane
        return True

    def discard_preempted(self, req: Request) -> None:
        """Drop a parked snapshot without resuming (cancel / shed while
        preempted): refcounts and bytes settle exactly like a release —
        including any pages demoted to the host pool or caught mid-
        prefetch.  No-op for requests that never held a snapshot — the
        terminal sweep calls this for every dead queue entry."""
        rid = req.request_id
        parked = self._preempted.pop(rid, None)
        if parked is None:
            return
        self._park_order.pop(rid, None)
        self._prefetch_done_late.pop(rid, None)
        blocks, owned, _ = parked
        # mid-prefetch: the new physical blocks exist and their device
        # bytes are re-reserved, but the rows were never attached — free
        # them like ordinary owned blocks by completing the bookkeeping
        st = self._prefetching.pop(rid, None)
        if st is not None:
            for j, (bid, _rows) in st["rows"].items():
                blocks[j] = bid
                owned.add(bid)
        hostmap = self._demoted.pop(rid, {})
        live = [b for b in blocks if b >= 0]
        # the demoted blocks' device reservation and physical commitment
        # were already settled at demotion time — release only the rest
        self._release_blocks(live, owned,
                             req.reserved_blocks - len(hostmap))
        for key in hostmap.values():
            self.host_pool.drop(key)
        if hostmap:
            self.budget.drop_host(len(hostmap))

    # -- tiered KV: demotion / prefetch (docs/serving.md) --------------------
    def _demotable(self, bid: int, owned: set) -> bool:
        """Only private pages move tiers: sole-owner, unindexed blocks —
        the same guard as speculative rollback.  Shared/indexed pages stay
        device-resident for their other readers."""
        return bid in owned and self.pool.ref(bid) == 1 \
            and bid not in self._rev

    def demoted_blocks(self, req: Request) -> int:
        """Blocks of this request currently host-resident or in flight
        (the SLO router's resume-cost input)."""
        rid = req.request_id
        st = self._prefetching.get(rid)
        if st is not None:
            return len(st["rows"])
        return len(self._demoted.get(rid, ()))

    def parked_state(self, req: Request) -> str:
        """'resident' | 'demoted' | 'inflight' for a parked snapshot."""
        rid = req.request_id
        if rid in self._prefetching:
            return "inflight"
        if self._demoted.get(rid):
            return "demoted"
        return "resident"

    def _demote_snapshot(self, rid: str, need_blocks=None) -> int:
        """Move a parked snapshot's private pages device -> host pool.
        Each moved block's rows are copied out, the physical block is
        freed, and its device byte reservation is re-parked as host-pool
        bytes.  Returns blocks moved."""
        parked = self._preempted.get(rid)
        if parked is None or rid in self._prefetching:
            return 0
        blocks, owned, _length = parked
        hostmap = self._demoted.setdefault(rid, {})
        moved = 0
        for j, bid in enumerate(blocks):
            if need_blocks is not None and moved >= need_blocks:
                break
            if bid < 0 or not self._demotable(bid, owned):
                continue
            hostmap[j] = self.host_pool.put(
                {name: np.array(leaf[:, bid])
                 for name, leaf in self.pool.pages.items()})
            owned.discard(bid)
            self.pool.decref(bid)
            blocks[j] = -1
            moved += 1
        if not hostmap:
            self._demoted.pop(rid, None)
        if moved:
            self._committed_blocks -= moved
            self.budget.demote(moved)
            self.kv_demote_block_moves += moved
        return moved

    def demote_parked(self, req: Request) -> int:
        """Eagerly demote a just-preempted request's private pages (the
        engine calls this right after ``preempt`` when tiering is on), so
        parked requests stop pinning device bytes.  Returns blocks moved."""
        if not self.tiered:
            return 0
        return self._demote_snapshot(req.request_id)

    def relieve_pressure(self, need_bytes: int) -> int:
        """``DeviceMemory`` pressure handler: demote parked snapshots'
        pages, least-recently-parked first, until ``need_bytes`` are freed
        or nothing demotable is left.  Returns bytes freed."""
        if not self.tiered:
            return 0
        bb = self.pool.block_bytes
        need = blocks_for_rows(need_bytes, bb)   # ceil-div bytes -> blocks
        freed = 0
        for rid in sorted(self._preempted, key=self._park_order.get):
            if freed >= need:
                break
            freed += self._demote_snapshot(rid, need - freed)
        return freed * bb

    def start_prefetch(self, req: Request) -> bool:
        """Begin the async host -> device fetch of a demoted snapshot:
        re-reserve its device bytes, allocate physical blocks, and stage
        the row copies — they land at a later ``poll_prefetches`` (the
        modeled transfer latency), after which ``resume`` proceeds.
        False when the device bytes or blocks do not fit yet: the caller
        keeps the request queued and retries as bytes drain — it always
        fits eventually because the bytes being waited on were part of
        this request's original admission reservation."""
        rid = req.request_id
        if rid in self._prefetching:
            return True
        hostmap = self._demoted.get(rid)
        if not hostmap:
            return True
        n = len(hostmap)
        if n > self.pool.n_free:
            return False
        if not self.budget.prefetch(n):
            return False
        ids = self.pool.alloc(n)
        self._committed_blocks += n
        rows = {}
        for (j, key), bid in zip(sorted(hostmap.items()), ids):
            rows[j] = (bid, self.host_pool.pop(key))
        del self._demoted[rid]
        self._prefetching[rid] = {"rows": rows,
                                  "ticks": self.prefetch_ticks,
                                  "late": False}
        return True

    def poll_prefetches(self) -> None:
        """Advance in-flight prefetches one tick; completed ones write
        their staged rows into the pages and the snapshot becomes
        resumable.  The engine calls this at the top of every step — the
        async-transfer barrier."""
        for rid in list(self._prefetching):
            st = self._prefetching[rid]
            st["ticks"] -= 1
            if st["ticks"] > 0:
                continue
            blocks, owned, _length = self._preempted[rid]
            for j, (bid, host_rows) in sorted(st["rows"].items()):
                self.pool.pages = self._block_write(
                    self.pool.pages, bid,
                    {name: jnp.asarray(r) for name, r in host_rows.items()})
                blocks[j] = bid
                owned.add(bid)
            self.kv_prefetch_block_moves += len(st["rows"])
            self._prefetch_done_late[rid] = st["late"]
            del self._prefetching[rid]

    def note_prefetch_wait(self, req: Request) -> None:
        """The scheduler wanted this lane but its pages are still in
        flight — a prefetch that completed 'late' (miss, not hit)."""
        st = self._prefetching.get(req.request_id)
        if st is not None:
            st["late"] = True

    def can_admit_bytes(self, req: Request, prefill_rows: int) -> bool:
        """Byte-side admissibility if a lane WERE free — the preemption
        guard: evicting a victim only helps when the lane is the scarce
        resource, not blocks (read-only; conservative on aliasing)."""
        if req.request_id in self._preempted:
            return True      # bytes still charged from first admission
        aliased, _ = self._match_prefix(req.prompt)
        need = self._worst_blocks(req, prefill_rows) - len(aliased)
        return (self._committed_blocks + need <= self.pool.n_allocatable
                and self.budget.can_reserve(need))

    # -- prefill -------------------------------------------------------------
    def fresh_states(self, n: int, prefill_rows: int):
        """Transient block-aligned-width states — just wide enough for the
        prompt group; the rows are scattered into pages and the temporary
        is dropped, so peak transient bytes stay O(prompt)."""
        width = self._prefill_width(prefill_rows)
        tmpl = self._fresh_by_width.get(width)
        if tmpl is None:
            tmpl = api.init_decode_state(self.cfg, 1, width)
            self._fresh_by_width[width] = tmpl
        return stack_trees([tmpl] * n)

    def write_prefill(self, group: Sequence[Request], states) -> None:
        """Scatter a prefilled contiguous group into the block pool pages.
        Aliased blocks are redirected to the garbage block: their owner
        already wrote identical rows (same tokens, same positions)."""
        ids = np.concatenate([
            [bid if bid in self._lane_owned[r.slot] else BlockPool.GARBAGE
             for bid in self._lane_blocks[r.slot]]
            for r in group]).astype(np.int32)
        self.pool.pages = self._page_scatter(
            self.pool.pages, states["kv"]["k"], states["kv"]["v"],
            jnp.asarray(ids))
        for r in group:
            self._lengths[r.slot] = r.prompt_len

    # -- decode --------------------------------------------------------------
    def _prepare_lanes(self, active: dict, n_rows: int = 1) -> None:
        """Make every active lane's next ``n_rows`` write rows safe:
        allocate the blocks they land in (the admission reservation —
        which includes ``verify_headroom`` — guarantees this can never
        fail), and copy-on-write any aliased block about to be written —
        the write would otherwise clobber rows other lanes are reading."""
        for lane, req in active.items():
            lo = int(self._lengths[lane]) // self.block_size
            hi = (int(self._lengths[lane]) + n_rows - 1) // self.block_size
            blocks = self._lane_blocks[lane]
            owned = self._lane_owned[lane]
            for j in range(lo, hi + 1):
                while len(blocks) <= j:
                    (bid,) = self.pool.alloc(1)
                    self._tables[lane, len(blocks)] = bid
                    blocks.append(bid)
                    owned.add(bid)
                if blocks[j] not in owned:
                    (dst,) = self.pool.alloc(1)
                    src = blocks[j]
                    self.pool.pages = self._page_copy(
                        self.pool.pages, src, dst)
                    self._tables[lane, j] = dst
                    blocks[j] = dst
                    owned.add(dst)
                    self.cow_copies += 1
                    self._drop_alias(src)
            req.peak_blocks = max(req.peak_blocks or 0, len(blocks))

    def _rewind_lane(self, lane: int) -> int:
        """Free owned tail blocks past the lane's committed rows — the
        speculative-decode rollback: verify wrote up to k rows past the
        accept point, and any whole blocks holding only rejected rows go
        back to the pool (rejected rows inside a kept block are masked and
        overwritten as decode resumes).  Returns blocks freed."""
        needed = max(1, blocks_for_rows(int(self._lengths[lane]),
                                        self.block_size))
        blocks = self._lane_blocks[lane]
        owned = self._lane_owned[lane]
        freed = 0
        while len(blocks) > needed:
            bid = blocks[-1]
            if bid not in owned or self.pool.ref(bid) != 1 \
                    or bid in self._rev:
                break       # shared or indexed blocks are never speculative
            blocks.pop()
            self._tables[lane, len(blocks)] = BlockPool.GARBAGE
            owned.discard(bid)
            self.pool.decref(bid)
            freed += 1
        return freed

    def decode(self, params, tokens: np.ndarray, active: dict) -> np.ndarray:
        self._prepare_lanes(active)
        ntoks, self.pool.pages = self._decode(
            params, self.pool.pages, jnp.asarray(self._tables),
            jnp.asarray(self._lengths), jnp.asarray(tokens[:, 0, :]))
        return np.array(jax.block_until_ready(ntoks), np.int32)[:, None, :]

    def advance(self, lane: int) -> None:
        self._lengths[lane] += 1

    def summary(self) -> dict:
        out = {
            "block_size": self.block_size,
            "kv_dtype": self.kv_dtype,
            "block_bytes": self.pool.block_bytes,
            "n_blocks": self.pool.n_blocks,
            "kv_page_peak_bytes": self.pool.peak_bytes(),
            "kv_block_allocs": self.pool.total_allocs,
            "paged_impl": self.paged_impl,
            "prefix_share": self.prefix_share,
            "shared_block_hits": self.shared_block_hits,
            "cow_copies": self.cow_copies,
            "preempted_held": len(self._preempted),
        }
        if self.tiered:
            bb = self.pool.block_bytes
            fetches = self.prefetch_hits + self.prefetch_misses
            out.update({
                "tiered": True,
                "host_pool_blocks": self.host_pool.n_blocks,
                "host_pool_bytes": self.host_pool.used_bytes(),
                "host_pool_peak_blocks": self.host_pool.peak_blocks,
                "kv_demoted_bytes": self.kv_demote_block_moves * bb,
                "kv_prefetched_bytes": self.kv_prefetch_block_moves * bb,
                "prefetch_hits": self.prefetch_hits,
                "prefetch_misses": self.prefetch_misses,
                "prefetch_hit_rate": (round(self.prefetch_hits / fetches, 3)
                                      if fetches else None),
            })
        return out


# ---------------------------------------------------------------------------
# speculative-decode backend (draft member model + batched target verify)
# ---------------------------------------------------------------------------

class SpecDecodeBackend:
    """Speculative decode over an inner slot or paged backend.

    Per round, a small *draft* member model proposes ``draft_k`` greedy
    tokens ahead of the target, then the target scores all k positions in
    ONE batched verify forward (``models/api.verify_step``; the paged
    variant reads K/V through block tables).  Acceptance is greedy-exact:
    the longest prefix where the draft matches the target's own argmax is
    kept, plus the target's correction token — so emitted tokens are
    **token-identical** to target-only greedy decode, and each verify
    forward yields between 1 and k tokens (``accepted_tokens_per_
    target_step`` in ``summary()``).

    Rollback past the accept point: the slot inner rewinds per-lane cache
    indices (rejected rows are masked and overwritten); the paged inner
    advances lane lengths by only the accepted rows and frees whole tail
    blocks holding nothing but rejected rows back to the refcounted
    ``BlockPool``.

    Memory: the inner backend is built with ``verify_headroom=draft_k``
    (k transient verify rows per lane beyond the decode extent), and when
    a shared ``DeviceMemory`` ledger is given, each admission additionally
    reserves the draft model's decode-state bytes — so one session budget
    arbitrates target KV, verify headroom, AND draft state exactly like
    SHARP shard promotions.

    The engine contract is unchanged (one token per active lane per
    ``decode()`` call): rounds run only for lanes whose emitted-token
    buffer ran dry, and every call pops one buffered token per lane.
    Lanes not in the round still ride through the batched draft/verify
    programs (fixed shapes — no retracing) with their writes parked in
    the garbage block / rewound, outputs discarded.

    **Degraded mode** (``set_degraded(True)`` — the SLO scheduler's soft
    overload shed, docs/serving.md): the draft model stops running.
    Rounds substitute trivial proposals (the last token repeated), so
    the draft chain, draft prefill, and draft rollback are all skipped —
    the shed is pure compute, no memory moves.  Correctness is
    untouched: acceptance only ever emits the target's own argmax
    tokens, so a degraded round yields >= 1 exact token per verify (the
    accept rate just collapses toward plain decode).  Un-degrading
    re-enables proposals immediately; lanes admitted while degraded have
    stale draft state, which costs acceptance, never correctness.
    """

    name = "spec"
    preemptible = False
    preempt_reason = ("the draft model's decode state advances in "
                      "lockstep with the target — snapshotting both "
                      "mid-round is not supported; use backend='paged'")

    def __init__(self, cfg, capacity: int, max_seq: int, *,
                 draft_cfg=None, draft_params=None, draft_k: int = 4,
                 inner: str = "slot", window: Optional[int] = None,
                 kv_budget_bytes: Optional[int] = None, ledger=None,
                 block_size: int = 16, n_blocks: Optional[int] = None,
                 paged_impl: Optional[str] = None,
                 prefix_share: bool = True,
                 kv_dtype: Optional[str] = None,
                 verify_impl: Optional[str] = None):
        if draft_cfg is None or draft_params is None:
            raise ValueError(
                "the spec backend needs a draft member model: pass "
                "draft_cfg and draft_params (ServeJob: draft_model=...)")
        tspec, dspec = family_spec(cfg), family_spec(draft_cfg)
        if not tspec.spec_draftable:
            raise ValueError(
                f"{cfg.name} ({cfg.family}): "
                f"{tspec.why_not('spec_draftable')}")
        if not dspec.spec_draftable:
            raise ValueError(
                f"draft {draft_cfg.name} ({draft_cfg.family}): "
                f"{dspec.why_not('spec_draftable')} — the draft must run "
                "the same rollback-able batched decode surface")
        if draft_cfg.vocab_size != cfg.vocab_size:
            raise ValueError(
                f"draft vocab {draft_cfg.vocab_size} != target vocab "
                f"{cfg.vocab_size}: greedy-exact acceptance compares "
                "token ids, so the models must share a tokenizer")
        if draft_k < 1:
            raise ValueError("draft_k must be >= 1")
        if inner not in ("slot", "paged"):
            raise ValueError(f"spec inner backend {inner!r}: "
                             "expected 'slot' or 'paged'")
        self.cfg = cfg
        self.capacity = capacity
        self.max_seq = max_seq
        self.draft_cfg = draft_cfg
        self.draft_params = draft_params
        self.draft_k = draft_k
        inner_kw: dict = dict(window=window, verify_headroom=draft_k,
                              kv_budget_bytes=kv_budget_bytes,
                              ledger=ledger)
        if inner == "paged":
            inner_kw.update(block_size=block_size, n_blocks=n_blocks,
                            paged_impl=paged_impl,
                            prefix_share=prefix_share, kv_dtype=kv_dtype)
        elif kv_dtype not in (None, "fp"):
            raise ValueError(
                f"kv_dtype={kv_dtype!r} needs the paged block pool: serve "
                "with inner='paged' (the slot inner keeps contiguous fp "
                "decode state)")
        self.inner = BACKENDS[inner](cfg, capacity, max_seq, **inner_kw)
        # draft decode state: one stacked pool over the same lane ids the
        # inner backend assigns; k extra rows absorb the round's writes.
        # Its bytes reserve against whatever byte ledger backs the job —
        # the shared session ledger, or the paged inner's private one; a
        # slot inner with a private kv_budget_bytes has no byte ledger,
        # so that budget bounds target slots only.
        self._charge_ledger = (ledger if ledger is not None
                               else getattr(self.inner, "ledger", None))
        self.draft_slot_bytes = dspec.decode_state_bytes(
            draft_cfg, 1, max_seq + draft_k)
        self._draft_fresh = api.init_decode_state(draft_cfg, 1,
                                                  max_seq + draft_k)
        self._draft_state = stack_trees([self._draft_fresh] * capacity)
        self._draft_chain = _compiled_draft_chain(draft_cfg, None, draft_k)
        self._draft_prefill = _compiled_draft_prefill(draft_cfg, None)
        self._draft_rollback = _compiled_rollback(draft_cfg)
        self._rollback = _compiled_rollback(cfg)
        if inner == "slot":
            if verify_impl is not None:
                raise ValueError(
                    f"verify_impl={verify_impl!r} selects a paged verify "
                    "kernel: serve with inner='paged' (the slot inner "
                    "verifies against contiguous decode state)")
            self.verify_impl = None
            self._verify = _compiled_verify(cfg, window)
        else:
            # default: verify through whatever impl decode uses; the
            # fused multi-query kernel activates with verify_impl=
            # 'pallas' (or 'pallas_interpret' off-TPU) — one launch
            # scores all k draft rows through the block tables.
            self.verify_impl = verify_impl or self.inner.paged_impl
            self._verify = _compiled_paged_verify(cfg, window,
                                                  self.verify_impl)
        self._pending: dict[int, deque] = {}    # lane -> emitted tokens
        self.degraded = False       # soft-overload shed: draft model off
        # round stats (summary / bench --spec)
        self.spec_rounds = 0        # batched verify forwards
        self.target_steps = 0       # per-lane verify participations
        self.draft_steps = 0        # per-lane draft tokens proposed
        self.spec_tokens = 0        # tokens emitted by spec rounds
        self.drafts_accepted = 0    # proposed drafts that matched target
        self.degraded_rounds = 0    # rounds run with the draft shed

    # -- introspection delegates (engine compat properties read these) -------
    @property
    def pool(self):
        return self.inner.pool

    @property
    def budget(self):
        return self.inner.budget

    @property
    def ledger(self):
        return getattr(self.inner, "ledger", None)

    @property
    def block_size(self):
        return getattr(self.inner, "block_size", None)

    @property
    def paged_impl(self):
        return getattr(self.inner, "paged_impl", None)

    @property
    def free_lanes(self) -> int:
        return self.inner.free_lanes

    # -- admission ------------------------------------------------------------
    def _worst_target_bytes(self, req: Request, prefill_rows: int) -> int:
        if isinstance(self.inner, PagedBackend):
            return self.inner._worst_blocks(req, prefill_rows) \
                * self.inner.pool.block_bytes
        return self.inner.slot_bytes

    def admission_check(self, req: Request, prefill_rows: int) -> None:
        self.inner.admission_check(req, prefill_rows)
        if self._charge_ledger is not None:
            need = self.draft_slot_bytes \
                + self._worst_target_bytes(req, prefill_rows)
            if need > self._charge_ledger.budget:
                raise ValueError(
                    f"speculative decode needs {need} B (draft state "
                    f"{self.draft_slot_bytes} B + target KV incl. "
                    f"{self.draft_k}-token verify headroom) but the ledger "
                    f"budget is {self._charge_ledger.budget} B — the "
                    "engine can never admit this request")

    def reserve(self, req: Request, prefill_rows: int) -> bool:
        if self._charge_ledger is not None \
                and not self._charge_ledger.reserve_kv(self.draft_slot_bytes):
            return False
        if not self.inner.reserve(req, prefill_rows):
            if self._charge_ledger is not None:
                self._charge_ledger.release_kv(self.draft_slot_bytes)
            return False
        self._pending[req.slot] = deque()
        return True

    def release(self, req: Request) -> None:
        # unconsumed pending tokens (overshoot past max_new_tokens / eos)
        # are discarded with the lane
        self._pending.pop(req.slot, None)
        self.inner.release(req)
        if self._charge_ledger is not None:
            self._charge_ledger.release_kv(self.draft_slot_bytes)

    # -- prefill --------------------------------------------------------------
    def fresh_states(self, n: int, prefill_rows: int):
        return self.inner.fresh_states(n, prefill_rows)

    def set_degraded(self, flag: bool) -> None:
        """Shed (or restore) the draft model — the SLO policy's soft-
        overload lever.  Takes effect at the next round."""
        self.degraded = bool(flag)

    def write_prefill(self, group: Sequence[Request], states) -> None:
        self.inner.write_prefill(group, states)
        if self.degraded:
            return      # draft shed: skip its prefill entirely (compute
            # only — lanes admitted now draft garbage if un-degraded
            # later, costing acceptance, never correctness)
        # the draft model prefills the same prompts into its own pool at
        # exact lengths (one vmapped call per same-length subgroup); its
        # prefill logits are unused — the first token is the target's
        by_len: dict[int, list[Request]] = {}
        for r in group:
            by_len.setdefault(r.prompt_len, []).append(r)
        for plen, reqs in sorted(by_len.items()):
            toks = jnp.asarray(
                np.stack([r.prompt for r in reqs])[:, None, :])
            fresh = stack_trees([self._draft_fresh] * len(reqs))
            _, dstates = self._draft_prefill(self.draft_params, fresh, toks)
            self._draft_state = write_slots(self._draft_state, dstates,
                                            [r.slot for r in reqs])

    # -- decode ---------------------------------------------------------------
    def decode(self, params, tokens: np.ndarray, active: dict) -> np.ndarray:
        todo = {lane: req for lane, req in active.items()
                if not self._pending[lane]}
        if todo:
            self._spec_round(params, tokens, todo)
        out = np.zeros_like(tokens)
        for lane in active:
            out[lane, 0, 0] = self._pending[lane].popleft()
        return out

    def _spec_round(self, params, tokens: np.ndarray, todo: dict) -> None:
        """One draft+verify round for the lanes whose buffers ran dry."""
        k = self.draft_k
        cap = self.capacity
        t_last = tokens[:, 0, 0].astype(np.int32)           # (cap,)
        # 1. draft k greedy tokens per lane — ONE fused scan dispatch and
        #    one device sync (full lane width, fixed shapes;
        #    non-participants are rolled back below).  Degraded (soft
        #    overload): the draft model is shed — propose the last token
        #    repeated instead; the verify path below still emits >= 1
        #    exact target token per round, so outputs stay identical.
        if self.degraded:
            dr = np.repeat(t_last[:, None], k, axis=1)      # (cap, k)
        else:
            drafts, self._draft_state = self._draft_chain(
                self.draft_params, self._draft_state,
                jnp.asarray(t_last[:, None, None]))
            dr = np.asarray(drafts)[:, :, 0, 0].T.copy()    # (cap, k)
        # 2. verify all k positions in ONE batched target forward: feed
        #    [t_last, d_1 .. d_{k-1}]; position i's argmax is the target's
        #    own next token after t_last, d_1 .. d_i
        V = np.concatenate([t_last[:, None], dr[:, :k - 1]], axis=1)
        if isinstance(self.inner, PagedBackend):
            # make the k write rows safe for participants (alloc + CoW —
            # the admission reservation includes the verify headroom) and
            # park non-participants' writes in the garbage block
            self.inner._prepare_lanes(todo, n_rows=k)
            tables = np.array(self.inner._tables)
            outside = np.ones(cap, bool)
            outside[list(todo)] = False
            tables[outside, :] = BlockPool.GARBAGE
            g, self.inner.pool.pages = self._verify(
                params, self.inner.pool.pages, jnp.asarray(tables),
                jnp.asarray(self.inner._lengths), jnp.asarray(V))
            g = np.asarray(g)                               # (cap, k)
        else:
            g, self.inner.pool.state = self._verify(
                params, self.inner.pool.state, jnp.asarray(V[:, None, :]))
            g = np.asarray(g)[:, 0, :]                      # (cap, k)
        # 3. greedy-exact acceptance: longest matching prefix + the
        #    target's correction (or the free k-th draft on a clean sweep)
        m = np.cumprod(dr == g, axis=1).sum(axis=1)         # leading matches
        accept = np.zeros(cap, np.int64)
        for lane in todo:
            accept[lane] = m[lane] + 1 if m[lane] < k else k
        for lane in todo:
            self._pending[lane].extend(
                int(t) for t in g[lane, :accept[lane]])
        # 4. roll both models back past the accept point (degraded: the
        #    draft never stepped, so only the target rewinds)
        delta = jnp.asarray((k - accept).astype(np.int32))
        if not self.degraded:
            self._draft_state = self._draft_rollback(self._draft_state,
                                                     delta)
        if isinstance(self.inner, PagedBackend):
            for lane in todo:
                self.inner._lengths[lane] += int(accept[lane])
                self.inner._rewind_lane(lane)
        else:
            self.inner.pool.state = self._rollback(self.inner.pool.state,
                                                   delta)
        # 5. stats (degraded rounds propose nothing, so they count no
        #    draft steps and no acceptances)
        self.spec_rounds += 1
        self.target_steps += len(todo)
        if self.degraded:
            self.degraded_rounds += 1
        else:
            self.draft_steps += len(todo) * k
            self.drafts_accepted += int(m[list(todo)].sum())
        self.spec_tokens += int(accept.sum())

    def advance(self, lane: int) -> None:
        pass        # rounds advance lengths/indices at the accept point

    def summary(self) -> dict:
        out = {
            "inner_backend": self.inner.name,
            "draft_model": self.draft_cfg.name,
            "draft_k": self.draft_k,
            "draft_slot_bytes": self.draft_slot_bytes,
            "spec_rounds": self.spec_rounds,
            "target_steps": self.target_steps,
            "draft_steps": self.draft_steps,
            "spec_tokens": self.spec_tokens,
            "accepted_tokens_per_target_step":
                round(self.spec_tokens / self.target_steps, 3)
                if self.target_steps else None,
            "draft_accept_rate":
                round(self.drafts_accepted / self.draft_steps, 3)
                if self.draft_steps else None,
            "degraded": self.degraded,
            "degraded_rounds": self.degraded_rounds,
        }
        out.update(self.inner.summary())
        return out


BACKENDS = {"slot": SlotBackend, "paged": PagedBackend,
            "spec": SpecDecodeBackend}

# kwargs each backend constructor understands (make_backend drops the rest
# so one engine call site can carry the union)
_BACKEND_KWARGS = {
    "slot": ("window", "kv_budget_bytes", "ledger", "verify_headroom"),
    "paged": ("window", "kv_budget_bytes", "ledger", "block_size",
              "n_blocks", "paged_impl", "prefix_share", "verify_headroom",
              "tiered", "prefetch_ticks", "kv_dtype"),
    "spec": ("window", "kv_budget_bytes", "ledger", "block_size",
             "n_blocks", "paged_impl", "prefix_share", "draft_cfg",
             "draft_params", "draft_k", "inner", "kv_dtype",
             "verify_impl"),
}


def make_backend(name: str, cfg, capacity: int, max_seq: int, **kw):
    """Construct a backend by name, dropping kwargs it does not take."""
    if name not in BACKENDS:
        raise ValueError(f"unknown decode backend {name!r} "
                         f"(have {sorted(BACKENDS)})")
    kw = {k: v for k, v in kw.items() if k in _BACKEND_KWARGS[name]}
    return BACKENDS[name](cfg, capacity, max_seq, **kw)
