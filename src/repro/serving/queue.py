"""FIFO request queue (arrival-stamped) + KV-budget admission control.

Two admission granularities share this module (each ``DecodeBackend`` in
``serving/backends.py`` owns one):

* ``KVBudget`` — slot-granular: every running request owns one slot of the
  fixed-capacity pool at a constant ``slot_bytes`` residency (computed via
  the family spec's ``decode_state_bytes`` cost fn — no allocation).
* ``PagedKVBudget`` — ledger-unit-granular: a request reserves only the
  units (KV blocks, or whole slots when ``SlotBackend`` is handed a
  ledger) its actual extent can touch, charged against a shared
  ``core.spilling.DeviceMemory`` ledger — the SAME ledger SHARP shard
  promotions charge, so train double-buffers and serve reservations split
  one device byte budget.  With prefix sharing, a request's reservation
  covers only its UNSHARED blocks; blocks whose owner retired while still
  aliased stay charged by the backend as orphans until the last reference
  drops.  Under speculative decoding the same reservation grows to cover
  draft + target + the k-token verify headroom: the inner backend's
  worst-case sizing folds in ``verify_headroom`` rows, and the spec
  backend reserves the draft model's decode-state bytes on whatever byte
  ledger backs the job (the session's shared one, or the paged inner's
  private ledger; a slot inner with a private ``kv_budget_bytes`` has no
  byte ledger, so that budget bounds target slots only).

Both enforce ``reserved <= budget`` as an invariant: a request is admitted
only if its reservation fits, so concurrency degrades gracefully when the
budget is tighter than the pool (tests/test_serving.py asserts the peak
never exceeds it).
"""

from __future__ import annotations

import itertools
import time
from collections import deque
from typing import Optional

from repro.serving.request import Request


class RequestQueue:
    """Arrival-ordered queue; stamps ``arrival_time`` + ``arrival_seq``
    on push.  The seq is a per-queue monotonic counter: the deterministic
    tie-break every admission policy (and the LRTF router) falls back to,
    so schedules are reproducible across runs regardless of clock
    resolution.  Admission policies reorder by iterating (``__iter__`` /
    ``remove``) — the deque itself stays arrival-ordered."""

    def __init__(self, clock=time.perf_counter):
        self.clock = clock
        self._q: deque[Request] = deque()
        self._seq = itertools.count()

    def push(self, req: Request) -> Request:
        if req.arrival_time is None:
            req.arrival_time = self.clock()
        if req.arrival_seq is None:
            req.arrival_seq = next(self._seq)
        self._q.append(req)
        return req

    def pop(self) -> Request:
        return self._q.popleft()

    def peek(self) -> Request:
        """Head of the queue without removing it (page-granular admission
        must size the head's reservation before deciding to admit)."""
        return self._q[0]

    def remove(self, req: Request) -> None:
        """Remove a specific entry (policy-ordered admission pulls
        requests out of arrival order; shed/cancel sweeps retire them)."""
        self._q.remove(req)

    def find(self, request_id: str) -> Optional[Request]:
        """Queued request by id (cancellation targets it in place — the
        entry stays in FIFO order and admission retires it when reached)."""
        for req in self._q:
            if req.request_id == request_id:
                return req
        return None

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)

    def __iter__(self):
        return iter(self._q)


class KVBudget:
    """Byte accounting for decode-state residency (admission control).

    ``budget_bytes=None`` disables the cap but keeps the accounting so
    metrics can report residency either way.
    """

    def __init__(self, budget_bytes: Optional[int], slot_bytes: int):
        if slot_bytes <= 0:
            raise ValueError("slot_bytes must be positive")
        if budget_bytes is not None and budget_bytes < slot_bytes:
            raise ValueError(
                f"KV budget {budget_bytes} B below one slot "
                f"({slot_bytes} B): nothing could ever be admitted")
        self.budget_bytes = budget_bytes
        self.slot_bytes = slot_bytes
        self.reserved_bytes = 0
        self.peak_bytes = 0

    def can_reserve(self) -> bool:
        return (self.budget_bytes is None
                or self.reserved_bytes + self.slot_bytes <= self.budget_bytes)

    def reserve(self) -> bool:
        if not self.can_reserve():
            return False
        self.reserved_bytes += self.slot_bytes
        self.peak_bytes = max(self.peak_bytes, self.reserved_bytes)
        return True

    def release(self) -> None:
        # a real error, not an assert: a double release corrupts admission
        # accounting and must be caught under `python -O` too
        if self.reserved_bytes < self.slot_bytes:
            raise RuntimeError(
                f"KVBudget.release: only {self.reserved_bytes} B reserved, "
                f"below one slot ({self.slot_bytes} B) — release without a "
                "matching reserve")
        self.reserved_bytes -= self.slot_bytes

    def max_concurrent(self) -> Optional[int]:
        if self.budget_bytes is None:
            return None
        return self.budget_bytes // self.slot_bytes


class PagedKVBudget:
    """Page-granular admission charging a shared ``DeviceMemory`` ledger.

    Reservations are variable-sized (blocks for the request's actual
    prompt + decode budget, not ``max_seq``); the ledger arbitrates the
    device byte budget between these reservations and whatever else lives
    on the device (promoted shards, double buffers).  Local
    ``reserved_bytes``/``peak_bytes`` counters track THIS engine's share
    so multi-engine metrics stay attributable.
    """

    def __init__(self, ledger, block_bytes: int):
        if block_bytes <= 0:
            raise ValueError("block_bytes must be positive")
        self.ledger = ledger
        self.block_bytes = block_bytes
        self.reserved_bytes = 0
        self.peak_bytes = 0

    @property
    def budget_bytes(self) -> int:
        return self.ledger.budget

    def can_reserve(self, n_blocks: int) -> bool:
        return self.ledger.can_reserve_kv(n_blocks * self.block_bytes)

    def reserve(self, n_blocks: int) -> bool:
        nbytes = n_blocks * self.block_bytes
        if not self.ledger.reserve_kv(nbytes):
            return False
        self.reserved_bytes += nbytes
        self.peak_bytes = max(self.peak_bytes, self.reserved_bytes)
        return True

    def release(self, n_blocks: int) -> None:
        nbytes = n_blocks * self.block_bytes
        if nbytes > self.reserved_bytes:
            raise RuntimeError(
                f"PagedKVBudget.release({n_blocks} blocks = {nbytes} B): "
                f"only {self.reserved_bytes} B reserved — release without "
                "a matching reserve")
        self.reserved_bytes -= nbytes
        self.ledger.release_kv(nbytes)

    # -- tiered KV: device <-> host-pool moves (serving/backends.py) --------
    def demote(self, n_blocks: int) -> None:
        """Park reserved blocks in the host pool: device bytes release,
        ``DeviceMemory.host_kv_bytes`` picks them up."""
        nbytes = n_blocks * self.block_bytes
        if nbytes > self.reserved_bytes:
            raise RuntimeError(
                f"PagedKVBudget.demote({n_blocks} blocks = {nbytes} B): "
                f"only {self.reserved_bytes} B reserved")
        self.reserved_bytes -= nbytes
        self.ledger.demote_kv(nbytes)

    def prefetch(self, n_blocks: int) -> bool:
        """Re-reserve device bytes for demoted blocks; False when the
        device side does not fit yet."""
        nbytes = n_blocks * self.block_bytes
        if not self.ledger.prefetch_kv(nbytes):
            return False
        self.reserved_bytes += nbytes
        self.peak_bytes = max(self.peak_bytes, self.reserved_bytes)
        return True

    def drop_host(self, n_blocks: int) -> None:
        """Discard demoted blocks outright (owner cancelled while parked)."""
        self.ledger.drop_host_kv(n_blocks * self.block_bytes)
