"""FIFO request queue (arrival-stamped) + KV-budget admission control.

Admission is slot-granular: every running request owns one slot of the
fixed-capacity pool, and a slot's decode-state residency is a constant
``slot_bytes`` (computed via ``api.decode_state_bytes`` — no allocation).
``KVBudget`` enforces ``reserved <= budget_bytes`` as an invariant: a
request is admitted only if reserving one more slot stays under budget,
so concurrency degrades gracefully when the budget is tighter than the
pool (tests/test_serving.py asserts the peak never exceeds it).
"""

from __future__ import annotations

import time
from collections import deque
from typing import Optional

from repro.serving.request import Request


class RequestQueue:
    """Arrival-ordered queue; stamps ``arrival_time`` on push."""

    def __init__(self, clock=time.perf_counter):
        self.clock = clock
        self._q: deque[Request] = deque()

    def push(self, req: Request) -> Request:
        if req.arrival_time is None:
            req.arrival_time = self.clock()
        self._q.append(req)
        return req

    def pop(self) -> Request:
        return self._q.popleft()

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)

    def __iter__(self):
        return iter(self._q)


class KVBudget:
    """Byte accounting for decode-state residency (admission control).

    ``budget_bytes=None`` disables the cap but keeps the accounting so
    metrics can report residency either way.
    """

    def __init__(self, budget_bytes: Optional[int], slot_bytes: int):
        if slot_bytes <= 0:
            raise ValueError("slot_bytes must be positive")
        if budget_bytes is not None and budget_bytes < slot_bytes:
            raise ValueError(
                f"KV budget {budget_bytes} B below one slot "
                f"({slot_bytes} B): nothing could ever be admitted")
        self.budget_bytes = budget_bytes
        self.slot_bytes = slot_bytes
        self.reserved_bytes = 0
        self.peak_bytes = 0

    def can_reserve(self) -> bool:
        return (self.budget_bytes is None
                or self.reserved_bytes + self.slot_bytes <= self.budget_bytes)

    def reserve(self) -> bool:
        if not self.can_reserve():
            return False
        self.reserved_bytes += self.slot_bytes
        self.peak_bytes = max(self.peak_bytes, self.reserved_bytes)
        return True

    def release(self) -> None:
        assert self.reserved_bytes >= self.slot_bytes, "release without reserve"
        self.reserved_bytes -= self.slot_bytes

    def max_concurrent(self) -> Optional[int]:
        if self.budget_bytes is None:
            return None
        return self.budget_bytes // self.slot_bytes
