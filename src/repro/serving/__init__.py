"""Continuous-batching multi-model inference (see docs/serving.md)."""

from repro.models.registry import CapabilityFallbackWarning
from repro.serving.backends import (BACKENDS, DecodeBackend, PagedBackend,
                                    SlotBackend, SpecDecodeBackend,
                                    make_backend)
from repro.serving.engine import InferenceEngine, pow2_buckets
from repro.serving.multi import MultiModelServer
from repro.serving.paging import BlockPool, blocks_for_rows, default_n_blocks
from repro.serving.queue import KVBudget, PagedKVBudget, RequestQueue
from repro.serving.request import Request, Status
from repro.serving.server import (HydraHTTPServer, ServingFrontend,
                                  encode_prompt)
from repro.serving.slo import (PRIORITIES, SLO, FIFOPolicy, OverloadedError,
                               SLOPolicy, make_policy)
from repro.serving.slots import SlotPool, stack_trees, write_slots
from repro.serving.stream import TokenStream

__all__ = ["InferenceEngine", "MultiModelServer", "KVBudget", "PagedKVBudget",
           "RequestQueue", "Request", "Status", "SlotPool", "BlockPool",
           "blocks_for_rows", "default_n_blocks", "stack_trees",
           "write_slots", "pow2_buckets", "DecodeBackend", "SlotBackend",
           "PagedBackend", "SpecDecodeBackend", "BACKENDS", "make_backend",
           "CapabilityFallbackWarning", "TokenStream", "ServingFrontend",
           "HydraHTTPServer", "encode_prompt", "SLO", "SLOPolicy",
           "FIFOPolicy", "OverloadedError", "PRIORITIES", "make_policy"]
