"""Block-granular KV paging: a free-list of fixed-size physical KV blocks.

The slot pool reserves a ``max_seq``-sized cache per request, so admission
is bounded by the worst case.  ``BlockPool`` instead owns ONE pages pytree
— ``{"k","v"}`` of ``(L, n_blocks, block_size, n_kv_heads, head_dim)`` —
and hands out physical blocks request-by-request; a request's residency is
the blocks it has actually grown into, so short prompts admit at their real
footprint and concurrency rises under the same byte budget (paper §4.2's
byte-accounted memory management, applied to decode state).

Physical block 0 is reserved as the *garbage block*: inactive decode lanes
and unused block-table entries all point at it, so every table entry is a
valid physical index (the Pallas kernel's scalar-prefetch index map needs
no clamping) and the lane-batched KV write scatter has a harmless target.
Attention masks rows past each lane's length, so garbage contents are
mathematically invisible.

Blocks are **refcounted**: ``alloc`` hands a block out at refcount 1, and
``incref``/``decref`` let several decode lanes alias one physical block —
the mechanism copy-on-write prefix sharing builds on (requests with a
common block-aligned prompt prefix read the same pages).  A block returns
to the free list only when its last reference drops.
"""

from __future__ import annotations

from typing import Optional

from repro.models import api


class BlockPool:
    """Free-list of refcounted physical KV blocks + the pages pytree."""

    GARBAGE = 0          # reserved physical block; never allocated

    def __init__(self, cfg, n_blocks: int, block_size: int, kv_dtype=None):
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        if n_blocks < 2:
            raise ValueError(
                f"n_blocks={n_blocks}: need at least one allocatable block "
                "on top of the reserved garbage block 0")
        self.block_size = block_size
        self.n_blocks = n_blocks
        # kv_dtype='int8' allocates int8 pages + per-row f32 scale planes
        # (~3.8x smaller blocks at head_dim 64, so the same byte budget
        # admits proportionally more blocks); block_bytes prices the whole
        # pytree either way, so ledger charges stay exact.
        self.kv_dtype = "fp" if kv_dtype is None else kv_dtype
        self.block_bytes = api.kv_block_bytes(cfg, block_size, kv_dtype)
        self.pages = api.init_kv_pages(cfg, n_blocks, block_size, kv_dtype)
        # low ids handed out first (stable layouts in tests); 0 is reserved
        self._free = list(range(n_blocks - 1, 0, -1))
        self._ref: dict[int, int] = {}          # allocated block -> refcount
        self.total_allocs = 0        # lifetime blocks handed out (reuse stat)
        self.peak_used = 0

    @property
    def n_allocatable(self) -> int:
        return self.n_blocks - 1

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return len(self._ref)

    def used_bytes(self) -> int:
        return self.n_used * self.block_bytes

    def peak_bytes(self) -> int:
        return self.peak_used * self.block_bytes

    def alloc(self, n: int = 1) -> list[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"BlockPool exhausted: need {n} block(s), "
                f"{len(self._free)} free of {self.n_allocatable} "
                f"allocatable ({self.block_size} rows * "
                f"{self.block_bytes} B each) — raise n_blocks or lower "
                "concurrency")
        ids = [self._free.pop() for _ in range(n)]
        for b in ids:
            self._ref[b] = 1
        self.total_allocs += n
        self.peak_used = max(self.peak_used, self.n_used)
        return ids

    def ref(self, bid: int) -> int:
        """Current refcount (0 when not allocated)."""
        return self._ref.get(bid, 0)

    def refcounts(self) -> dict[int, int]:
        """Snapshot of every live block's refcount — the leak-audit
        surface: preempt/resume must leave this identical, and a full
        retire must leave it empty (tests/test_slo.py)."""
        return dict(self._ref)

    def incref(self, bid: int) -> int:
        """Alias an allocated block (prefix sharing); returns the block id
        so table-building code can write ``incref(bid)`` in place."""
        if bid not in self._ref:
            raise RuntimeError(
                f"BlockPool.incref({bid}): block is not allocated "
                "(cannot alias a free or garbage block)")
        self._ref[bid] += 1
        return bid

    def decref(self, bid: int) -> int:
        """Drop one reference; frees the block when the last one goes.
        Returns the remaining refcount."""
        if bid not in self._ref:
            raise RuntimeError(
                f"BlockPool.decref({bid}): block is not allocated "
                "(double free, or the reserved garbage block)")
        self._ref[bid] -= 1
        if self._ref[bid] == 0:
            del self._ref[bid]
            self._free.append(bid)
            return 0
        return self._ref[bid]

    def free(self, ids) -> None:
        """Drop one reference per id (the sole-owner fast path)."""
        for b in ids:
            self.decref(b)


class HostBlockPool:
    """Host-DRAM side of the tiered KV cache (ROADMAP item 3b).

    Holds the *contents* of demoted KV blocks — per block, a dict of the
    pages pytree's per-block rows as numpy arrays ({"k","v"} of
    ``(L, block_size, n_kv_heads, head_dim)``, plus the per-row scale
    planes for int8 pools) — keyed by an opaque handle.  Byte accounting
    mirrors the device pool's ``block_bytes`` so
    ``DeviceMemory.host_kv_bytes`` reconciles exactly with
    ``used_bytes()`` here (an int8 pool demotes int8 rows: the snapshot
    is as small as the device block).  Unlike the device pool there is no
    free list or budget: host DRAM is the backing tier, bounded only by
    what was demoted out of the device budget.
    """

    def __init__(self, block_bytes: int):
        self.block_bytes = block_bytes
        self._data: dict[int, dict] = {}        # key -> per-leaf rows
        self._next = 0
        self.total_demotions = 0     # lifetime blocks parked here
        self.total_prefetches = 0    # lifetime blocks pulled back out
        self.peak_blocks = 0

    @property
    def n_blocks(self) -> int:
        return len(self._data)

    def used_bytes(self) -> int:
        return self.n_blocks * self.block_bytes

    def put(self, rows: dict) -> int:
        """Park one demoted block's rows (per-leaf dict); returns its
        handle."""
        key = self._next
        self._next += 1
        self._data[key] = rows
        self.total_demotions += 1
        self.peak_blocks = max(self.peak_blocks, self.n_blocks)
        return key

    def pop(self, key: int) -> dict:
        """Pull a block back out for prefetch (host -> device)."""
        if key not in self._data:
            raise RuntimeError(f"HostBlockPool.pop({key}): no such block")
        self.total_prefetches += 1
        return self._data.pop(key)

    def drop(self, key: int) -> None:
        """Discard a parked block (owner cancelled/shed while demoted)."""
        if key not in self._data:
            raise RuntimeError(f"HostBlockPool.drop({key}): no such block")
        del self._data[key]


def blocks_for_rows(rows: int, block_size: int) -> int:
    """Blocks needed to hold ``rows`` KV rows (ceil division)."""
    return -(-rows // block_size)


def default_n_blocks(capacity: int, max_seq: int, block_size: int,
                     n_blocks: Optional[int] = None) -> int:
    """Physical pool size: worst case of every lane at ``max_seq`` rows,
    plus the garbage block — sized so lazy growth can never exhaust the
    pool while admission holds the per-request reservation invariant."""
    if n_blocks is not None:
        return n_blocks
    return capacity * blocks_for_rows(max_seq, block_size) + 1
