"""Serving CLI: a thin shell over ``repro.api.Session`` + ``ServeJob``.

Synthetic requests are prefilled in one jitted call (batched prefill) and
decoded with continuous batching over a fixed slot pool; greedy sampling
(argmax) keeps outputs deterministic for tests.  ``--stagger`` drips
requests in between decode steps so late arrivals join mid-flight, a
comma-separated ``--arch`` list serves several models at once with the
session's scheduling policy picking which model steps next, ``--buckets``
pads prompt groups to power-of-two length buckets, ``--cold`` starts
models spilled in the host store (promoted on the first request), and
``--backend slot|paged|spec`` picks the decode backend once (``--paged``
is the legacy spelling of ``--backend paged``; ``--no-prefix-share``
disables copy-on-write prompt-prefix page sharing; ``--backend spec``
takes ``--draft-model ARCH --draft-k N [--spec-inner slot|paged]`` for
speculative decoding with a draft member model).  Prints per-request
latency/throughput metrics plus engine summaries as JSON.

With ``--http`` the CLI instead brings the models up behind the online
HTTP front-end (``repro.serving.server``): OpenAI-compatible
``/v1/completions`` + ``/v1/chat/completions`` with SSE token streaming,
``/v1/cancel`` for first-class request cancellation, and ``/v1/metrics``.
It prints ``{"url": ...}`` once the socket is bound and serves until
interrupted.

  python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --batch 4 --prompt-len 32 --gen 16
  python -m repro.launch.serve --arch qwen3-0.6b,xlstm-350m --smoke \
      --batch 3 --stagger 2
  python -m repro.launch.serve --arch qwen3-0.6b --smoke --http --port 8000
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.api import ServeJob, Session
from repro.configs import get_config
from repro.core.sharp import HydraConfig


def build_serve_job(arch: str, args) -> ServeJob:
    cfg = get_config(arch, smoke=args.smoke)
    max_seq = args.max_seq or (args.prompt_len + args.gen + 8)
    budget = int(args.kv_budget_mb * 2**20) if args.kv_budget_mb else None
    draft = getattr(args, "draft_model", None)
    # pass both spellings through: ServeJob.requested_backend() resolves
    # the legacy --paged flag and rejects a conflicting --backend slot
    return ServeJob(cfg, seed=args.seed, name=arch, capacity=args.capacity,
                    max_seq=max_seq, kv_budget_bytes=budget,
                    bucket_sizes="pow2" if getattr(args, "buckets", False)
                    else None,
                    cold=getattr(args, "cold", False),
                    backend=getattr(args, "backend", None),
                    paged=getattr(args, "paged", False),
                    block_size=getattr(args, "block_size", 16),
                    prefix_share=not getattr(args, "no_prefix_share", False),
                    draft_model=get_config(draft, smoke=args.smoke)
                    if draft else None,
                    draft_seed=args.seed,
                    draft_k=getattr(args, "draft_k", 4),
                    spec_inner=getattr(args, "spec_inner", None),
                    stream=not getattr(args, "no_stream", False),
                    endpoint=getattr(args, "endpoint", None),
                    policy=getattr(args, "policy", "slo"),
                    deadline_ms=getattr(args, "deadline_ms", None),
                    priority=getattr(args, "priority", None) or "normal",
                    max_ttft_ms=getattr(args, "max_ttft_ms", None))


def synth_prompts(cfg, n: int, prompt_len: int, seed: int):
    key = jax.random.PRNGKey(seed + 1)
    return jax.random.randint(key, (n, prompt_len), 0, cfg.vocab_size,
                              jnp.int32)


def serve(args) -> dict:
    archs = [a.strip() for a in args.arch.split(",") if a.strip()]
    session = Session(HydraConfig(scheduler=args.scheduler, seed=args.seed))
    jids = {a: session.submit(build_serve_job(a, args)) for a in archs}

    pending = []            # (model, prompt row) not yet submitted
    for arch in archs:
        cfg = session.jobs()[jids[arch]].cfg
        prompts = synth_prompts(cfg, args.batch, args.prompt_len, args.seed)
        pending.extend((arch, prompts[i]) for i in range(args.batch))

    # submit everything up front, or drip --stagger at a time between ticks
    drip = args.stagger if args.stagger > 0 else len(pending)
    while session.serve_has_work() or pending:
        for model, prompt in pending[:drip]:
            session.submit_request(model, prompt, args.gen)
        pending = pending[drip:]
        session.serve_tick()

    report = session.run()     # no train/eval jobs: collects serve summaries
    out = {"engines": {a: {k: v for k, v in report.serve[jids[a]].items()
                           if k != "requests"} for a in archs},
           "schedule": report.serve_trace if len(archs) > 1 else None,
           "requests": [r for a in archs
                        for r in report.serve[jids[a]].get("requests", [])]}
    if len(archs) == 1:
        eng = session.engine(archs[0])
        out["sample"] = eng.completed[0].generated[:8] if eng.completed else []
    return out


def serve_http(args):
    """Bring the models up behind the HTTP/SSE front-end and block."""
    import time

    from repro.serving import HydraHTTPServer, MultiModelServer

    archs = [a.strip() for a in args.arch.split(",") if a.strip()]
    session = Session(HydraConfig(scheduler=args.scheduler, seed=args.seed))
    jids = {a: session.submit(build_serve_job(a, args)) for a in archs}
    engines = {a: session.engine(a) for a in archs}   # build + promote now
    options = {a: session.jobs()[jids[a]].http_options() for a in archs}
    server = MultiModelServer(engines, scheduler=args.scheduler)
    http = HydraHTTPServer(server, host=args.host, port=args.port,
                           model_options=options)
    http.start()
    # machine-readable first line: benches/scripts parse the bound address
    # (--port 0 binds an ephemeral port)
    print(json.dumps({"url": http.url, "models": archs}), flush=True)
    try:
        while True:
            time.sleep(3600)
    except KeyboardInterrupt:
        pass
    finally:
        http.stop()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="model id, or comma-separated list for multi-model")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="requests per model")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--capacity", type=int, default=4,
                    help="decode slot-pool size per model")
    ap.add_argument("--max-seq", type=int, default=0,
                    help="per-slot cache length (default prompt+gen+8)")
    ap.add_argument("--kv-budget-mb", type=float, default=0,
                    help="KV admission budget per model (0 = uncapped)")
    ap.add_argument("--stagger", type=int, default=0,
                    help="submit N requests per tick instead of all upfront")
    ap.add_argument("--buckets", action="store_true",
                    help="pad prompt groups to power-of-two length buckets")
    ap.add_argument("--cold", action="store_true",
                    help="start models spilled; promote on first request")
    ap.add_argument("--backend", default=None,
                    choices=["slot", "paged", "spec"],
                    help="decode backend (default: slot; families whose "
                    "FamilySpec lacks a capability fall back with a "
                    "warning)")
    ap.add_argument("--draft-model", default=None,
                    help="draft member model for --backend spec (arch id; "
                    "must share the target's vocab)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="draft tokens per speculative round")
    ap.add_argument("--spec-inner", default=None,
                    choices=["slot", "paged"],
                    help="inner backend the spec backend wraps "
                    "(default slot)")
    ap.add_argument("--paged", action="store_true",
                    help="legacy spelling of --backend paged")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV rows per physical block (paged backend)")
    ap.add_argument("--no-prefix-share", action="store_true",
                    help="disable copy-on-write prompt-prefix page sharing "
                    "(paged backend)")
    ap.add_argument("--scheduler", default="lrtf",
                    choices=["lrtf", "srtf", "fifo", "random", "slo"],
                    help="multi-model routing policy; 'slo' adds a "
                    "deadline-urgency pre-pass over LRTF")
    ap.add_argument("--policy", default="slo", choices=["slo", "fifo"],
                    help="per-engine admission policy (ServeJob.policy): "
                    "'slo' = EDF with priority tiers + aging + paged "
                    "preemption; 'fifo' = legacy arrival order")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="default end-to-end deadline budget for every "
                    "request to this model (requests may override)")
    ap.add_argument("--priority", default=None,
                    choices=["high", "normal", "low"],
                    help="default priority tier for requests to this model")
    ap.add_argument("--max-ttft-ms", type=float, default=None,
                    help="default time-to-first-token budget (ms)")
    ap.add_argument("--http", action="store_true",
                    help="serve over HTTP (OpenAI-compatible /v1 endpoints "
                    "with SSE streaming) instead of a synthetic batch")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000,
                    help="HTTP port (0 binds an ephemeral port)")
    ap.add_argument("--no-stream", action="store_true",
                    help="disable SSE streaming on the served models "
                    "(ServeJob.stream=False)")
    ap.add_argument("--endpoint", default=None,
                    help="extra route alias clients may pass as 'model' "
                    "(ServeJob.endpoint; single-model serving)")
    args = ap.parse_args()
    if args.http:
        serve_http(args)
    else:
        print(json.dumps(serve(args)))


if __name__ == "__main__":
    main()
