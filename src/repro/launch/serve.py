"""Batched serving driver: prefill + decode loop with KV cache.

Demonstrates the inference side the decode shapes lower: a batch of
requests is prefllled once, then decoded token-by-token with the cached
state.  Greedy sampling (argmax) keeps it deterministic for tests.

  python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import api
from repro.models import layers as nn
from repro.training import make_decode_step


def prefill_into_cache(cfg, params, tokens, state):
    """Feed prompt tokens through decode_step one at a time (correct for all
    families incl. recurrent); batched prefill-into-cache is a later perf
    optimization recorded in EXPERIMENTS.md §Perf."""
    step = jax.jit(lambda p, s, t: api.decode_step(cfg, p, s, t))
    logits = None
    for i in range(tokens.shape[1]):
        logits, state = step(params, state, tokens[:, i:i + 1])
    return logits, state


def serve(args) -> dict:
    cfg = get_config(args.arch, smoke=args.smoke)
    params = api.init_params(cfg, jax.random.PRNGKey(args.seed))
    max_seq = args.prompt_len + args.gen + 8
    state = api.init_decode_state(cfg, args.batch, max_seq)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size, jnp.int32)

    t0 = time.perf_counter()
    logits, state = prefill_into_cache(cfg, params, prompt, state)
    prefill_s = time.perf_counter() - t0

    decode = jax.jit(make_decode_step(cfg))
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        tok, state = decode(params, state, tok)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    decode_s = time.perf_counter() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    return {
        "generated_shape": list(gen.shape),
        "prefill_s": round(prefill_s, 3),
        "decode_tok_per_s": round(args.batch * (args.gen - 1)
                                  / max(decode_s, 1e-9), 1),
        "sample": gen[0, :8].tolist(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print(json.dumps(serve(args)))


if __name__ == "__main__":
    main()
