"""Production mesh construction.

A FUNCTION (not module-level constant) so importing never touches jax device
state.  The dry-run sets ``--xla_force_host_platform_device_count=512``
before any jax import; smoke tests and benchmarks see the real single CPU
device.
"""

from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` with explicit Auto axis types where the installed
    jax supports them (``jax.sharding.AxisType`` landed after 0.4.x; on
    older versions every axis is already Auto, so plain make_mesh is
    equivalent)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16×16 = 256 chips per pod; 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small host-device mesh for tests (requires >= n_data*n_model devices)."""
    return make_mesh((n_data, n_model), ("data", "model"))


# v5e hardware constants (roofline) — the single source of truth is the
# MachineFacts schema (repro/profiler/facts.py): a measured profile may
# override them, and these module names re-export the analytic defaults
# so unprofiled consumers see byte-identical values.  facts.py is pure
# data + stdlib, so this import still never touches jax device state.
from repro.profiler.facts import HBM_BW  # noqa: E402,F401  bytes/s per chip
from repro.profiler.facts import ICI_BW  # noqa: E402,F401  bytes/s per link
from repro.profiler.facts import \
    PEAK_FLOPS_BF16  # noqa: E402,F401  per chip
