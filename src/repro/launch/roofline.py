"""Roofline analysis (§Roofline): per (arch × shape), derive the three terms

    compute    = FLOPs / (chips × 197 TFLOP/s bf16)
    memory     = HBM bytes / (chips × 819 GB/s)
    collective = collective bytes / (chips × 50 GB/s per ICI link)

Methodology (CPU container, TPU target — see EXPERIMENTS.md §Roofline):

* collective bytes come from the *compiled artifact*: the dry-run parses the
  partitioned HLO and sums collective-op output bytes, scaling while-body
  collectives by the scan trip count (XLA's text shows loop bodies once).
* FLOPs/HBM bytes use an explicit analytic model (formulas below): XLA's
  ``cost_analysis`` also counts loop bodies once, which under-reports a
  64-layer scan ~64×; the analytic model is exact for matmul-dominated
  programs and is cross-checked against the raw HLO numbers (reported as
  ``hlo_flops_body_once``).
* MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); the ratio
  MODEL_FLOPS / total-FLOPs exposes remat/attention/router overhead.

Usage:
    python -m repro.launch.roofline --dryrun results/dryrun.jsonl \
        --out results/roofline.json --markdown results/roofline.md
"""

from __future__ import annotations

import argparse
import json
from typing import Optional

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.profiler.facts import hardware_constants, load_facts
from repro.training.train_loop import decode_window_for

CHIPS = {"16x16": 256, "2x16x16": 512}


# ---------------------------------------------------------------------------
# analytic FLOPs / bytes
# ---------------------------------------------------------------------------

def _body_params(cfg) -> tuple[int, int]:
    """(dense-equivalent body params, active body params) excluding embed."""
    total = cfg.n_layers * cfg.layer_params
    if cfg.is_encoder_decoder:
        total += cfg.n_encoder_layers * cfg.layer_params
    if cfg.family == "moe":
        active_layer = cfg.attn_params + cfg.top_k * cfg.mlp_params \
            + cfg.d_model * cfg.n_experts
        active = cfg.n_layers * active_layer
        # capacity padding: experts compute ceil to capacity_factor
        compute = cfg.n_layers * (cfg.attn_params
                                  + cfg.capacity_factor * cfg.top_k
                                  * cfg.mlp_params)
        return int(compute), int(active)
    return total, total


def attn_flops(cfg, tokens: int, kv_len: int, window: Optional[int]) -> float:
    """QK^T + AV matmul flops (fwd) across all layers."""
    if cfg.family == "ssm":
        return 0.0
    eff = min(kv_len, window) if window else kv_len
    causal_frac = 0.5 if (cfg.causal and kv_len == tokens and not window) \
        else 1.0
    n_attn_layers = cfg.n_layers
    if cfg.family == "hybrid":
        n_attn_layers = max(1, cfg.n_layers // max(cfg.attn_every, 1))
    per_tok = 4 * eff * cfg.d_model * causal_frac
    fl = tokens * per_tok * n_attn_layers
    if cfg.is_encoder_decoder:
        fl += cfg.encoder_len * 4 * cfg.encoder_len * cfg.d_model \
            * cfg.n_encoder_layers                       # encoder self-attn
        fl += tokens * 4 * cfg.encoder_len * cfg.d_model * cfg.n_layers  # cross
    return fl


def ssm_flops(cfg, tokens: int) -> float:
    """SSD / recurrent extra flops (state updates) per fwd."""
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    from repro.models.ssm import mamba2_dims
    if cfg.family == "hybrid":
        d_in, h, p, n = mamba2_dims(cfg)
        per_tok = 2 * h * (cfg.ssm_chunk * (n + p) + 2 * p * n)
        return tokens * per_tok * cfg.n_layers
    # xlstm: mLSTM matrix memory (n = p) + sLSTM vector ops
    d_in = cfg.ssm_expand * cfg.d_model
    p = d_in // cfg.n_heads
    per_tok = 2 * cfg.n_heads * (cfg.ssm_chunk * 2 * p + 2 * p * p)
    return tokens * per_tok * (cfg.n_layers // 2)


def analytic_step(cfg, shape) -> dict:
    """Global FLOPs and HBM bytes for one step of the shape's program."""
    b, s = shape.global_batch, shape.seq_len
    V, d = cfg.vocab_size, cfg.d_model
    window = decode_window_for(cfg, shape) or cfg.window
    body, active = _body_params(cfg)
    emb_unembed = 2 * d * V            # unembed matmul params-equivalent

    if shape.kind == "train":
        tokens = b * s
        fwd = 2 * tokens * (body + emb_unembed) \
            + attn_flops(cfg, tokens, s, window) + ssm_flops(cfg, tokens)
        flops = 4 * fwd                 # bwd 2x + full remat recompute 1x
        model_flops = 6 * tokens * (active + emb_unembed // 2)
        # HBM: param/grad/opt traffic (f32 master + bf16 cast) + activations
        state_bytes = (body + V * d) * (4 * 7)   # p,g,mu,nu r/w per step
        act_bytes = tokens * d * 20 * (cfg.n_layers + getattr(
            cfg, "n_encoder_layers", 0))
        hbm = state_bytes + act_bytes
    elif shape.kind == "prefill":
        tokens = b * s
        flops = 2 * tokens * body + 2 * b * (emb_unembed // 2) \
            + attn_flops(cfg, tokens, s, window) + ssm_flops(cfg, tokens)
        model_flops = 2 * tokens * active
        hbm = (body + V * d) * 2 + tokens * d * 12 * cfg.n_layers
    else:  # decode: one token against a seq_len cache/state
        tokens = b
        kv_len = s
        flops = 2 * tokens * (active + emb_unembed) \
            + attn_flops(cfg, tokens, kv_len, window) + ssm_flops(cfg, tokens)
        model_flops = 2 * tokens * active
        # HBM: weights once + KV cache read (the decode wall)
        eff = min(kv_len, window) if window else kv_len
        if cfg.family in ("ssm", "hybrid"):
            from repro.models.ssm import mamba2_dims
            state = b * cfg.n_layers * 2 * d * 64 * 4    # rough state bytes
            kv_bytes = state
        else:
            kv_bytes = (b * cfg.n_layers * 2 * eff
                        * cfg.n_kv_heads * cfg.head_dim * 2)
        if cfg.family == "hybrid":
            n_attn = max(1, cfg.n_layers // max(cfg.attn_every, 1))
            kv_bytes += b * n_attn * 2 * eff * cfg.n_kv_heads \
                * cfg.head_dim * 2
        hbm = (active + V * d) * 2 + kv_bytes * 2        # read + write
    return {"flops": flops, "model_flops": model_flops, "hbm_bytes": hbm}


# ---------------------------------------------------------------------------
# assembling the table
# ---------------------------------------------------------------------------

def lever_for(dominant: str, cfg, shape) -> str:
    if dominant == "compute":
        return ("MFU work: fuse attention (Pallas flash kernel) and cut remat "
                "recompute with a coarser checkpoint policy")
    if dominant == "memory":
        if shape.kind == "decode":
            return ("KV/state residency dominates: quantize cache to int8 or "
                    "shrink window; batch more requests per step")
        return ("HBM-bound: raise arithmetic intensity — larger micro-batch "
                "per device or fuse norm/residual round-trips")
    return ("collective-bound: reshard to cut all-gathers (wider FSDP axis), "
            "overlap collectives with compute, or move to bf16 gathers")


def analyze(records: list[dict], facts=None) -> list[dict]:
    """``facts`` (a ``repro.profiler.MachineFacts``) overrides the analytic
    hardware constants with this machine's measured ones; None preserves
    the historical analytic table byte-identically."""
    hw = hardware_constants(facts)
    out = []
    for rec in records:
        if rec.get("status") != "ok":
            out.append(dict(rec, roofline=None))
            continue
        cfg = get_config(rec["arch"])
        shape = INPUT_SHAPES[rec["shape"]]
        chips = CHIPS[rec["mesh"]]
        a = analytic_step(cfg, shape)
        t_compute = a["flops"] / (chips * hw["peak_flops_bf16"])
        t_memory = a["hbm_bytes"] / (chips * hw["hbm_bw"])
        coll_bytes = rec["collectives"].get("total", 0)   # per device
        t_coll = coll_bytes / hw["ici_bw"]
        terms = {"compute": t_compute, "memory": t_memory,
                 "collective": t_coll}
        dominant = max(terms, key=terms.get)
        out.append(dict(
            rec,
            roofline={
                "t_compute_s": t_compute,
                "t_memory_s": t_memory,
                "t_collective_s": t_coll,
                "dominant": dominant,
                "hw_source": hw["source"],
                "model_flops": a["model_flops"],
                "total_flops": a["flops"],
                "useful_ratio": a["model_flops"] / max(a["flops"], 1),
                "hlo_flops_body_once": rec.get("hlo_flops_per_device", 0),
                "lever": lever_for(dominant, cfg, shape),
            }))
    return out


def to_markdown(rows: list[dict]) -> str:
    lines = ["| arch | shape | mesh | compute s | memory s | collective s "
             "| dominant | useful FLOP ratio | peak GB/dev |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        rf = r.get("roofline")
        if rf is None:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — "
                         f"| — | FAILED | — | — |")
            continue
        peak = r["bytes_per_device"]["peak"] / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rf['t_compute_s']:.3e} | {rf['t_memory_s']:.3e} "
            f"| {rf['t_collective_s']:.3e} | **{rf['dominant']}** "
            f"| {rf['useful_ratio']:.2f} | {peak:.2f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun.jsonl")
    ap.add_argument("--out", default="results/roofline.json")
    ap.add_argument("--markdown", default="results/roofline.md")
    ap.add_argument("--profile", default=None,
                    help="MachineFacts JSON whose measured hardware "
                    "constants replace the analytic v5e table")
    args = ap.parse_args()
    records = [json.loads(l) for l in open(args.dryrun)]
    facts = load_facts(args.profile, require_fresh=False) \
        if args.profile else None
    rows = analyze(records, facts=facts)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    md = to_markdown(rows)
    with open(args.markdown, "w") as f:
        f.write(md + "\n")
    print(md)


if __name__ == "__main__":
    main()
