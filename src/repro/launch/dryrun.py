import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture × input shape × mesh)
combination lowers, partitions, and fits — with zero real allocation.

For each combination this driver:
  1. builds ShapeDtypeStruct stand-ins for params / optimizer state / inputs,
  2. jits the right step (train / prefill / decode) with the sharding rules
     from ``repro.sharding.specs``,
  3. ``.lower().compile()`` on the production mesh,
  4. records ``memory_analysis()`` (fits?), ``cost_analysis()`` (FLOPs/bytes)
     and the collective mix parsed from the partitioned HLO,
  5. appends a JSON record consumed by §Dry-run / §Roofline of EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --all --out results/dryrun.jsonl
  python -m repro.launch.dryrun --all --multi-pod
"""

import argparse
import json
import re
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.optim import OptimizerConfig, init_state
from repro.sharding import specs as sh
from repro.training import (decode_window_for, make_decode_step,
                            make_prefill_step, make_train_step)

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\b")


def _sds_tree(f, *args, **kw):
    return jax.eval_shape(f, *args, **kw)


def collective_bytes(hlo_text: str, trip_scale: dict[str, int]) -> dict:
    """Sum operand bytes of collective ops in partitioned HLO.

    Collectives inside while-loop body computations are scaled by the scan
    trip count (layer count), since XLA's cost/text shows the body once.
    """
    shape_re = re.compile(r"(bf16|f32|f16|s32|u32|s8|u8|f64|pred)\[([\d,]*)\]")
    dtype_bytes = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4,
                   "u32": 4, "s8": 1, "u8": 1, "pred": 1}

    def op_bytes(line: str) -> int:
        # output shape(s) of the op — for collectives output size ~ operand
        total = 0
        head = line.split("=", 1)[0] + "=" + \
            line.split("=", 1)[1].split("(", 1)[0] if "=" in line else line
        for m in shape_re.finditer(head):
            dt, dims = m.groups()
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * dtype_bytes[dt]
        return total

    # split into computations
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = re.match(r"^\s*(%?[\w\.\-_]+)\s*\(.*\)\s*->.*{", line)
        if m:
            cur = m.group(1).lstrip("%")
            comps[cur] = []
        elif cur is not None:
            comps[cur].append(line)

    per_kind: dict[str, float] = {}
    count = 0
    for name, lines in comps.items():
        scale = 1
        for pat, s in trip_scale.items():
            if pat in name:
                scale = s
                break
        for line in lines:
            m = COLLECTIVE_RE.search(line)
            if m and "=" in line and not line.strip().startswith("ROOT tuple"):
                kind = m.group(1)
                if "-done" in line.split("=")[1].split("(")[0]:
                    continue   # count start, not done
                b = op_bytes(line)
                per_kind[kind] = per_kind.get(kind, 0) + b * scale
                count += scale
    per_kind["total"] = sum(v for k, v in per_kind.items())
    per_kind["n_ops"] = count
    return per_kind


def build_step(cfg, shape, mesh):
    """Returns (jitted_fn, example_args_as_SDS) for the shape's step kind."""
    ocfg = OptimizerConfig(kind="adamw", lr=1e-4, grad_clip=1.0)
    params_s = _sds_tree(lambda: api.init_params(cfg, jax.random.PRNGKey(0)))
    pspec = sh.param_specs(cfg, params_s, mesh)
    pshard = sh.to_shardings(mesh, pspec)

    if shape.kind == "train":
        batch_s = api.input_specs(cfg, shape, kind="train")
        bshard = sh.to_shardings(mesh, sh.batch_specs(cfg, batch_s, mesh))
        opt_s = _sds_tree(lambda: init_state(
            ocfg, jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                               params_s)))
        oshard = sh.to_shardings(mesh, sh.opt_state_specs(cfg, opt_s, mesh))
        # micro-batch = one sequence per data shard; the rest accumulates
        data_size = 1
        for ax in ("pod", "data"):
            if ax in mesh.axis_names:
                data_size *= mesh.shape[ax]
        accum = max(1, shape.global_batch // data_size)
        step = make_train_step(cfg, ocfg, accum_steps=accum, mesh=mesh)
        fn = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))
        return fn, (params_s, opt_s, batch_s), {"layers": cfg.n_layers,
                                                "accum": accum}

    if shape.kind == "prefill":
        batch_s = api.input_specs(cfg, shape, kind="prefill")
        batch_s.pop("labels", None)
        bshard = sh.to_shardings(mesh, sh.batch_specs(cfg, batch_s, mesh))
        step = make_prefill_step(cfg)
        fn = jax.jit(step, in_shardings=(pshard, bshard), out_shardings=None)
        return fn, (params_s, batch_s), {"layers": cfg.n_layers}

    # decode
    window = decode_window_for(cfg, shape)
    state_s = _sds_tree(lambda: api.init_decode_state(
        cfg, shape.global_batch, shape.seq_len))
    sshard = sh.to_shardings(mesh, sh.decode_state_specs(cfg, state_s, mesh))
    tok_s = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    step = make_decode_step(cfg, window=window)
    fn = jax.jit(step, in_shardings=(pshard, sshard, None),
                 out_shardings=(None, sshard), donate_argnums=(1,))
    return fn, (params_s, state_s, tok_s), {"layers": cfg.n_layers}


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            skip_notes: bool = True) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    rec: dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "family": cfg.family, "kind": shape.kind,
    }
    t0 = time.time()
    try:
        from repro.sharding.context import activation_axes
        mesh = make_production_mesh(multi_pod=multi_pod)
        fn, args, meta = build_step(cfg, shape, mesh)
        # shard_map MoE wins on serving paths; GSPMD is leaner under vjp
        with activation_axes(mesh, moe_shardmap=(shape.kind != "train")):
            lowered = fn.lower(*args)
            compiled = lowered.compile()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        trips = {"while": meta["layers"], "body": meta["layers"],
                 "cond": meta["layers"]}
        coll = collective_bytes(hlo, trips)
        rec.update(
            status="ok",
            compile_s=round(time.time() - t0, 1),
            bytes_per_device={
                "arguments": ma.argument_size_in_bytes,
                "output": ma.output_size_in_bytes,
                "temp": ma.temp_size_in_bytes,
                "alias": ma.alias_size_in_bytes,
                "peak": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                         + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
            },
            hlo_flops_per_device=ca.get("flops", 0.0),
            hlo_bytes_per_device=ca.get("bytes accessed", 0.0),
            collectives=coll,
            scan_trip=meta["layers"],
        )
        print(f"OK   {arch:24s} {shape_name:12s} {rec['mesh']:8s} "
              f"peak={rec['bytes_per_device']['peak']/1e9:6.2f}GB "
              f"flops={rec['hlo_flops_per_device']:.3e} "
              f"coll={coll.get('total', 0)/1e9:.2f}GB  "
              f"({rec['compile_s']}s)")
    except Exception as e:
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:],
                   compile_s=round(time.time() - t0, 1))
        print(f"FAIL {arch:24s} {shape_name:12s} {rec['mesh']:8s} {e}")
    return rec


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


# ---------------------------------------------------------------------------
# Session plan dry-run: the partition/spill/schedule view of a workload,
# without executing a single unit.  The Plan written here is the SAME object
# repro.api.Session.run consumes — plan once, inspect, then execute.
# ---------------------------------------------------------------------------

def _plan_loader(cfg, batch, seq, seed):
    from repro.models import api as mapi

    class L:
        def __iter__(self):
            def gen():
                i = 0
                while True:
                    k = jax.random.fold_in(jax.random.PRNGKey(seed), i)
                    yield mapi.make_dummy_batch(cfg, batch, seq, key=k)
                    i += 1
            return gen()

    return L()


def plan_dryrun(args) -> dict:
    """Build a Session over --arch TrainJobs, emit its Plan as JSON, and
    verify the JSON round-trips byte-identically."""
    from repro.api import Plan, Session, TrainJob
    from repro.core.sharp import HydraConfig

    archs = [a.strip() for a in (args.arch or "qwen3-0.6b").split(",")
             if a.strip()]
    # what-if pricing: --profile plans against another machine's measured
    # facts (loaded without the freshness gate — a foreign fingerprint is
    # the point); the default (None, not "auto") pins analytic pricing so
    # the smoke plan is byte-stable regardless of any cached local profile
    profile = None
    if getattr(args, "profile", None):
        from repro.profiler import load_facts
        profile = load_facts(args.profile, require_fresh=False)
    session = Session(HydraConfig(
        n_devices=args.n_devices,
        device_budget_bytes=int(args.budget_mb * 10**6)),
        profile=profile)
    for i, arch in enumerate(archs):
        cfg = get_config(arch, smoke=args.smoke)
        session.submit(TrainJob(cfg, _plan_loader(cfg, 2, 64, seed=i),
                                epochs=1, steps_per_epoch=2, seed=i,
                                batch=2, seq=64))
    plan = session.plan()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    plan.save(args.out)
    reloaded = Plan.load(args.out)
    if reloaded.to_json() != plan.to_json():
        raise AssertionError(f"plan JSON does not round-trip ({args.out})")

    summary = plan.summary()
    print(json.dumps(summary))
    est = summary["est_makespan_s"]
    print(f"plan -> {args.out}  ({len(plan.jobs)} jobs, "
          f"est makespan {est:.3e}s, round-trip OK)" if est is not None
          else f"plan -> {args.out}  ({len(plan.jobs)} jobs, round-trip OK)")
    return summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    # session-plan mode (repro.api): partition/spill/schedule, no execution
    ap.add_argument("--plan", action="store_true",
                    help="emit a Session Plan JSON instead of lowering HLO")
    ap.add_argument("--smoke", action="store_true",
                    help="(--plan) reduced configs")
    ap.add_argument("--n-devices", type=int, default=2,
                    help="(--plan) virtual device count")
    ap.add_argument("--budget-mb", type=float, default=18,
                    help="(--plan) per-device budget, MB")
    ap.add_argument("--profile", default=None,
                    help="(--plan) MachineFacts JSON to price the plan "
                    "with — the what-if tool; default analytic")
    args = ap.parse_args()

    if args.plan:
        if args.out == "results/dryrun.jsonl":
            args.out = "results/plan.json"
        plan_dryrun(args)
        return

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    combos = []
    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                combos.append((a, s, mp))

    with open(args.out, "a") as f:
        for a, s, mp in combos:
            rec = run_one(a, s, multi_pod=mp)
            f.write(json.dumps(rec) + "\n")
            f.flush()


if __name__ == "__main__":
    main()
