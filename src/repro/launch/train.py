"""SPMD training launcher.

Single-model pjit training over a mesh — the substrate Hydra's multi-model
layer schedules over sub-meshes of.  On the dev container it runs real steps
on the CPU device (reduced configs); on a pod the same driver drives the
production mesh.

Usage:
  python -m repro.launch.train --arch qwen3-0.6b --smoke --steps 20
  python -m repro.launch.train --arch bert-large-1b --smoke --steps 200 \
      --batch 8 --seq 128 --log-every 10 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import get_config
from repro.data import DataConfig, Prefetcher, make_dataset
from repro.models import api
from repro.optim import OptimizerConfig, init_state
from repro.sharding import specs as sh
from repro.training import make_train_step


def make_mesh_for_args(args):
    from repro.launch.mesh import make_mesh, make_production_mesh
    n = len(jax.devices())
    if args.mesh == "production":
        return make_production_mesh(multi_pod=args.multi_pod)
    if n == 1:
        return make_mesh((1, 1), ("data", "model"))
    nd = max(1, n // 2)
    return make_mesh((nd, n // nd), ("data", "model"))


def train(args) -> dict:
    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_mesh_for_args(args)
    ocfg = OptimizerConfig(kind=args.optimizer, lr=args.lr,
                           schedule="linear_warmup_cosine",
                           warmup_steps=max(args.steps // 20, 1),
                           total_steps=args.steps)

    params = api.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = init_state(ocfg, params)

    pshard = sh.to_shardings(mesh, sh.param_specs(cfg, params, mesh))
    oshard = sh.to_shardings(mesh, sh.opt_state_specs(cfg, opt_state, mesh))
    params = jax.device_put(params, pshard)
    opt_state = jax.device_put(opt_state, oshard)

    data_cfg = DataConfig(batch_size=args.batch, seq_len=args.seq,
                          vocab_size=cfg.vocab_size, seed=args.seed,
                          path=args.data)
    if cfg.family in ("audio", "vlm"):
        def synth():
            i = 0
            while True:
                yield api.make_dummy_batch(cfg, args.batch, args.seq,
                                           key=jax.random.PRNGKey(i))
                i += 1
        it = synth()
    else:
        it = iter(Prefetcher(iter(make_dataset(data_cfg)), depth=2))

    step_fn = jax.jit(
        make_train_step(cfg, ocfg, accum_steps=args.accum),
        in_shardings=(pshard, oshard, None),
        out_shardings=(pshard, oshard, None),
        donate_argnums=(0, 1))

    history = []
    t0 = time.perf_counter()
    for step in range(args.steps):
        batch = next(it)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            tok_s = args.batch * args.seq * (step + 1) / dt
            print(f"step {step:5d}  loss {loss:8.4f}  "
                  f"gnorm {float(metrics['grad_norm']):7.3f}  "
                  f"{tok_s:9.0f} tok/s")
            history.append({"step": step, "loss": loss})
        if args.ckpt_dir and step and step % args.ckpt_every == 0:
            ckpt.save(f"{args.ckpt_dir}/step_{step}", params, step=step)
    if args.ckpt_dir:
        ckpt.save(f"{args.ckpt_dir}/step_{args.steps}", params,
                  step=args.steps)
    return {"history": history,
            "final_loss": history[-1]["loss"] if history else None,
            "params": api.param_count(params)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data", default=None, help="token .bin (else synthetic)")
    ap.add_argument("--mesh", default="auto", choices=["auto", "production"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()
    out = train(args)
    print(json.dumps({k: v for k, v in out.items() if k != "history"}))


if __name__ == "__main__":
    main()
