"""SPMD training launcher: a thin shell over ``repro.api.Session`` +
``SpmdTrainJob``.

Single-model pjit training over a mesh — the substrate Hydra's multi-model
layer schedules over sub-meshes of.  On the dev container it runs real steps
on the CPU device (reduced configs); on a pod the same driver drives the
production mesh.  The loop itself lives in ``repro.api.session._run_spmd``.

Usage:
  python -m repro.launch.train --arch qwen3-0.6b --smoke --steps 20
  python -m repro.launch.train --arch bert-large-1b --smoke --steps 200 \
      --batch 8 --seq 128 --log-every 10 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import json

from repro.api import Session, SpmdTrainJob
from repro.configs import get_config


def job_from_args(args) -> SpmdTrainJob:
    cfg = get_config(args.arch, smoke=args.smoke)
    return SpmdTrainJob(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        accum=args.accum, lr=args.lr, optimizer=args.optimizer,
        seed=args.seed, data=args.data, mesh=args.mesh,
        multi_pod=args.multi_pod, log_every=args.log_every,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)


def train(args) -> dict:
    session = Session()
    jid = session.submit(job_from_args(args))
    report = session.run()
    return report.spmd[jid]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data", default=None, help="token .bin (else synthetic)")
    ap.add_argument("--mesh", default="auto", choices=["auto", "production"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()
    out = train(args)
    print(json.dumps({k: v for k, v in out.items() if k != "history"}))


if __name__ == "__main__":
    main()
