"""Activation-sharding context.

GSPMD propagates shardings from weights into activations; with FSDP-style
weight shardings (contraction dim on the data axis) it can decide to shard
activation *feature* dims over 'data' and replicate the batch — measured at
+35 GB/device on yi-34b train_4k.  The industry fix (MaxText et al.) is to
pin activations batch-sharded with explicit constraints at layer boundaries.

Model code calls ``constrain_batch(x)``; launchers opt in via
``activation_axes(mesh)`` around trace/lower.  Default is a no-op so smoke
tests and the Hydra executor (single real device) are untouched.
"""

from __future__ import annotations

import contextlib
from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_STATE: dict[str, Any] = {"mesh": None, "axes": None, "seq_parallel": True,
                          "moe_shardmap": True}


@contextlib.contextmanager
def activation_axes(mesh, *, seq_parallel: bool = True,
                    moe_shardmap: bool = True):
    """Enable batch-dim activation constraints for traces inside the ctx.

    ``moe_shardmap``: use the explicit all_to_all expert-parallel MoE path
    (measured better for prefill/decode: dbrx prefill 11.3 -> 8.7 GB; the
    GSPMD path is slightly leaner for training where the vjp keeps the
    member-local expert hiddens resident).
    """
    from repro.sharding.specs import batch_axes
    prev = dict(_STATE)
    _STATE["mesh"] = mesh
    _STATE["axes"] = batch_axes(mesh)
    _STATE["seq_parallel"] = seq_parallel
    _STATE["moe_shardmap"] = moe_shardmap
    try:
        yield
    finally:
        _STATE.update(prev)


def constrain_expert(x):
    """Pin MoE dispatch buffers (b, E, C, ...) to (data, model, ...): groups
    on the data axes, the expert axis on 'model' (expert parallelism) —
    without this the dispatch/hidden buffers stay global on every device
    (measured: 60 GB/device on dbrx-132b prefill_32k)."""
    mesh, axes = _STATE["mesh"], _STATE["axes"]
    if mesh is None:
        return x

    def one(leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim < 3:
            return leaf
        from repro.sharding.specs import spec_fits
        for spec in (P(axes, "model", *([None] * (leaf.ndim - 2))),
                     P(axes, *([None] * (leaf.ndim - 1)))):
            if spec_fits(mesh, spec, leaf.shape):
                return jax.lax.with_sharding_constraint(
                    leaf, NamedSharding(mesh, spec))
        return leaf

    return jax.tree.map(one, x)


def constrain_q_seq(q):
    """Context parallelism for attention: shard the *query* sequence dim over
    'model' (K/V stay whole).  GQA blocks head sharding whenever
    n_kv_heads < model-axis size, and unsharded (sq, skv) score matrices are
    the next-largest temp (measured 6.4 GB/device f32 on command-r-104b) —
    q-seq sharding divides scores/probs by the model-axis size instead."""
    mesh = _STATE["mesh"]
    if mesh is None or not hasattr(q, "ndim") or q.ndim != 4:
        return q
    from repro.sharding.specs import spec_fits
    axes = _STATE["axes"]
    spec = P(axes, "model", None, None)
    if q.shape[1] > 1 and spec_fits(mesh, spec, q.shape):
        return jax.lax.with_sharding_constraint(q, NamedSharding(mesh, spec))
    return q


def constrain_batch(x, *, seq_parallel: Optional[bool] = None):
    """Pin dim-0 of every leaf to the data axes (no-op outside the ctx, or
    when the batch dim doesn't divide the data axes).

    3D+ activations additionally shard dim-1 (sequence) over 'model' when it
    divides — sequence parallelism for the inter-layer residual stream.  The
    saved per-layer boundaries of a 64-layer scan are L× this tensor, so
    leaving it model-replicated costs e.g. 19 GB/device on command-r-104b
    train_4k.  Attention/matmuls re-gather internally (GSPMD inserts the
    collectives); norms run seq-sharded for free.
    """
    mesh, axes = _STATE["mesh"], _STATE["axes"]
    if mesh is None:
        return x
    sp = _STATE.get("seq_parallel", True) if seq_parallel is None \
        else seq_parallel

    def one(leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim == 0:
            return leaf
        from repro.sharding.specs import spec_fits
        cands = []
        if sp and leaf.ndim >= 3:
            cands.append(P(axes, "model", *([None] * (leaf.ndim - 2))))
        cands.append(P(axes, *([None] * (leaf.ndim - 1))))
        for spec in cands:
            if spec_fits(mesh, spec, leaf.shape):
                return jax.lax.with_sharding_constraint(
                    leaf, NamedSharding(mesh, spec))
        return leaf

    return jax.tree.map(one, x)
