from repro.sharding import specs

__all__ = ["specs"]
