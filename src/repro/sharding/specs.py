"""Per-family pjit sharding rules (FSDP + tensor parallel).

Mesh axes: ``('data', 'model')`` single-pod (16×16), ``('pod', 'data',
'model')`` multi-pod (2×16×16).  Batch shards over (pod, data); weights use
a ZeRO-3/FSDP-style layout — large matrices shard their *input* dim over
('pod','data') and their *output* dim over 'model' — so per-chip bytes scale
with total chip count, which is what lets mixtral-8x22b / command-r-104b /
dbrx-132b fit.  MoE expert banks shard the expert axis over 'model' when the
expert count divides it, else fall back to (d, f) sharding (Mixtral's 8
experts on a 16-wide model axis).

Every rule is a *candidate list*; ``param_specs`` picks the first candidate
whose sharded dims divide evenly on the actual mesh (whisper's 51865 vocab,
xLSTM's 4 heads, long_500k's batch=1 all need fallbacks).
"""

from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def fsdp_axes(mesh) -> Any:
    """The axis (or axis tuple) used for FSDP weight sharding."""
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def batch_axes(mesh) -> Any:
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def spec_fits(mesh, spec: P, shape: Sequence[int]) -> bool:
    for dim, axis in zip(shape, tuple(spec) + (None,) * len(shape)):
        n = _axis_size(mesh, axis)
        if n > 1 and dim % n != 0:
            return False
    return True


def pick_spec(mesh, candidates: Sequence[P], shape: Sequence[int]) -> P:
    for c in candidates:
        if spec_fits(mesh, c, shape):
            return c
    return P(*([None] * len(shape)))


def _path_key(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

def _param_candidates(key: str, ndim: int, mesh) -> list[P]:
    F = fsdp_axes(mesh)
    stacked = any(s in key for s in ("layers/", "encoder/", "decoder/"))

    def S(*spec):
        """Prepend the scanned layer axis (always replicated)."""
        return P(None, *spec) if stacked else P(*spec)

    # embeddings: vocab over model, features over fsdp
    if key.endswith("embed/table"):
        return [P("model", F), P(None, F), P("model", None), P(None, None)]
    if key.endswith("dec_pos"):
        return [P(None, F), P(None, None)]

    # MoE expert banks (L, E, d, f): expert-parallel first, FSDP fallback
    if key.endswith(("/w_gate", "/w_up")) and ndim == (4 if stacked else 3):
        return [S("model", F, None), S(None, F, "model"), S(None, F, None)]
    if key.endswith("/w_down") and ndim == (4 if stacked else 3):
        return [S("model", None, F), S(None, "model", F), S(None, None, F)]
    if key.endswith("/router"):
        return [S(F, None), S(None, None)]

    # projections: in-dim over fsdp, out-dim over model (ZeRO-3 + TP)
    if key.endswith(("/wq", "/wk", "/wv", "/w_gate", "/w_up", "/w_in",
                     "/in_proj", "/up_proj")):
        return [S(F, "model"), S(F, None), S(None, "model"), S(None, None)]
    if key.endswith(("/wo", "/w_down", "/w_out", "/out_proj", "/down_proj")):
        return [S("model", F), S(None, F), S("model", None), S(None, None)]
    if key.endswith(("/bq", "/bk", "/bv", "/b_in")):
        return [S("model"), S(None)]

    # xLSTM internals
    if key.endswith("/w_gates"):
        return [S(F, None), S(None, None)]
    if key.endswith("/r"):          # (h, p, 4p) block-recurrent
        return [S("model", None, None), S(None, "model", None),
                S(None, None, None)]

    # conv / gates / norms / scalars: replicate (tiny)
    return [P(*([None] * ndim))]


def param_specs(cfg, params_tree, mesh) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_tree)
    out = []
    for path, leaf in flat:
        key = _path_key(path)
        cands = _param_candidates(key, len(leaf.shape), mesh)
        out.append(pick_spec(mesh, cands, leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# batch / optimizer / decode-state rules
# ---------------------------------------------------------------------------

def batch_specs(cfg, batch_tree, mesh) -> Any:
    B = batch_axes(mesh)

    def one(leaf):
        nd = len(leaf.shape)
        cands = [P(B, *([None] * (nd - 1)))]
        return pick_spec(mesh, cands, leaf.shape)

    return jax.tree.map(one, batch_tree)


def opt_state_specs(cfg, opt_state_tree, mesh) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(opt_state_tree)
    out = []
    for path, leaf in flat:
        key = _path_key(path)
        if key.endswith("step") or len(leaf.shape) == 0:
            out.append(P())
            continue
        stripped = key.split("/", 1)[1] if "/" in key else key
        cands = _param_candidates(stripped, len(leaf.shape), mesh)
        out.append(pick_spec(mesh, cands, leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, out)


def decode_state_specs(cfg, state_tree, mesh) -> Any:
    """KV caches: batch→data, kv-heads→model; when batch is unshardable
    (long_500k's batch=1) shard the *sequence* dim over data instead."""
    B = batch_axes(mesh)

    def one(path, leaf):
        key = _path_key(path)
        nd = len(leaf.shape)
        if key.endswith("/index") or key.endswith("pos") or nd == 0:
            return P()
        if nd == 5:      # stacked kv cache (L, b, s, h, hd)
            # kv-heads over 'model' when they divide; else shard the cache
            # *sequence* over 'model' (flash-decode context parallelism: the
            # score/AV contractions reduce over seq with tiny all-reduces,
            # where an hd-sharded cache forced a full f32 cache all-gather
            # per layer per token — 30 GB/token on qwen3 decode_32k).
            # long_500k (batch=1): seq takes every axis — /512 on multi-pod.
            Bt = B if isinstance(B, tuple) else (B,)
            seq_all = Bt + ("model",)
            cands = [P(None, B, None, "model", None),
                     P(None, B, "model", None, None),
                     P(None, None, seq_all, None, None),
                     P(None, None, B, "model", None),
                     P(None, None, B, None, None),
                     P(None, B, None, None, None)]
            return pick_spec(mesh, cands, leaf.shape)
        if nd >= 3:      # per-layer recurrent states (L, b, h, ...)
            cands = [P(None, B, "model", *([None] * (nd - 3))),
                     P(None, B, *([None] * (nd - 2))),
                     P(None, None, "model", *([None] * (nd - 3))),
                     P(*([None] * nd))]
            return pick_spec(mesh, cands, leaf.shape)
        return P(*([None] * nd))

    flat, treedef = jax.tree_util.tree_flatten_with_path(state_tree)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])


def to_shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
