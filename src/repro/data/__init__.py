from repro.data.pipeline import (DataConfig, FileTokens, Prefetcher,
                                 SyntheticTokens, make_dataset)

__all__ = ["DataConfig", "SyntheticTokens", "FileTokens", "make_dataset",
           "Prefetcher"]
