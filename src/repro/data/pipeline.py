"""Data pipeline: tokenized-LM batches with host-side prefetch and
deterministic sharding across the mesh's data axis.

Two sources:
  * ``SyntheticTokens`` — seeded random token streams (benchmarks / smoke).
  * ``FileTokens`` — memory-mapped ``.bin`` uint16/uint32 token files
    (WikiText-2-style corpora after external tokenization).

Both yield ``{"tokens": (b, s), "labels": (b, s)}`` with next-token labels.
``Prefetcher`` overlaps host batch assembly with device compute (the data-
side analogue of the paper's double buffering).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    batch_size: int = 8
    seq_len: int = 512
    vocab_size: int = 32000
    seed: int = 0
    path: Optional[str] = None      # None -> synthetic
    dtype: str = "int32"


class SyntheticTokens:
    """Deterministic synthetic LM stream (a different stream per seed)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._rng = np.random.default_rng(cfg.seed)

    def __iter__(self) -> Iterator[dict]:
        cfg = self.cfg
        while True:
            toks = self._rng.integers(
                0, cfg.vocab_size, (cfg.batch_size, cfg.seq_len + 1),
                dtype=np.int64).astype(np.int32)
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class FileTokens:
    """Memory-mapped contiguous token file -> random-crop LM batches."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        dt = np.uint16 if cfg.dtype == "uint16" else np.uint32
        self.data = np.memmap(cfg.path, dtype=dt, mode="r")
        if len(self.data) < cfg.seq_len + 1:
            raise ValueError("token file shorter than one sequence")
        self._rng = np.random.default_rng(cfg.seed)

    def __iter__(self) -> Iterator[dict]:
        cfg = self.cfg
        hi = len(self.data) - cfg.seq_len - 1
        while True:
            starts = self._rng.integers(0, hi, cfg.batch_size)
            rows = np.stack([self.data[s:s + cfg.seq_len + 1] for s in starts])
            rows = rows.astype(np.int32)
            yield {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


def make_dataset(cfg: DataConfig):
    return FileTokens(cfg) if cfg.path else SyntheticTokens(cfg)


class Prefetcher:
    """Background-thread prefetch of ``depth`` batches onto device."""

    def __init__(self, it: Iterator[dict], depth: int = 2, sharding=None):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._sharding = sharding
        self._src = iter(it)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        try:
            for batch in self._src:
                if self._stop.is_set():
                    return
                if self._sharding is not None:
                    batch = jax.tree.map(
                        lambda x: jax.device_put(x, self._sharding), batch)
                else:
                    batch = jax.tree.map(jnp.asarray, batch)
                self._q.put(batch)
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
