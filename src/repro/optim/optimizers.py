"""Optimizers (AdamW / SGD-momentum / Lion) + LR schedules.

Written optax-free so Hydra can step *per shard*: optimizer state is a pytree
mirroring the params, and ``update`` is a pure function that works on any
sub-tree — a shard's params + its optimizer-state slice step independently on
device while the rest of the model is spilled to host.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "adamw"            # adamw | sgd | lion
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    momentum: float = 0.9          # sgd
    grad_clip: float = 1.0         # global-norm clip; 0 disables
    schedule: str = "constant"     # constant | cosine | linear_warmup_cosine
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


def schedule_lr(cfg: OptimizerConfig, step) -> jnp.ndarray:
    step = jnp.asarray(step, jnp.float32)
    lr = jnp.asarray(cfg.lr, jnp.float32)
    if cfg.schedule == "constant":
        return lr
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "linear_warmup_cosine" or cfg.schedule == "cosine":
        t = jnp.clip((step - cfg.warmup_steps)
                     / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                     0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        floor = cfg.min_lr_ratio
        return lr * warm * (floor + (1 - floor) * cos)
    raise ValueError(cfg.schedule)


# ---------------------------------------------------------------------------


def init_state(cfg: OptimizerConfig, params) -> dict:
    zeros = lambda: jax.tree.map(jnp.zeros_like, params)
    if cfg.kind == "adamw":
        return {"mu": zeros(), "nu": zeros(), "step": jnp.zeros((), jnp.int32)}
    if cfg.kind == "sgd":
        return {"mom": zeros(), "step": jnp.zeros((), jnp.int32)}
    if cfg.kind == "lion":
        return {"mu": zeros(), "step": jnp.zeros((), jnp.int32)}
    raise ValueError(cfg.kind)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm, precomputed_norm=None):
    norm = precomputed_norm if precomputed_norm is not None \
        else global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def update(cfg: OptimizerConfig, params, grads, state, *,
           grad_norm: Optional[jnp.ndarray] = None):
    """One optimizer step. Works on any (sub-)tree — Hydra steps per shard.

    ``grad_norm``: pass the *global* norm when stepping a shard so clipping
    matches full-model training exactly.
    """
    if cfg.grad_clip > 0:
        grads, _ = clip_by_global_norm(grads, cfg.grad_clip, grad_norm)
    step = state["step"] + 1
    lr = schedule_lr(cfg, step)

    if cfg.kind == "adamw":
        b1, b2 = cfg.b1, cfg.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                          state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                          state["nu"], grads)
        t = step.astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            return (p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                              + cfg.weight_decay * p)).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, {"mu": mu, "nu": nu, "step": step}

    if cfg.kind == "sgd":
        mom = jax.tree.map(lambda m, g: cfg.momentum * m + g,
                           state["mom"], grads)
        new_params = jax.tree.map(
            lambda p, m: (p - lr * (m + cfg.weight_decay * p)).astype(p.dtype),
            params, mom)
        return new_params, {"mom": mom, "step": step}

    if cfg.kind == "lion":
        b1, b2 = cfg.b1, cfg.b2

        def upd(p, m, g):
            direction = jnp.sign(b1 * m + (1 - b1) * g)
            return (p - lr * (direction + cfg.weight_decay * p)).astype(p.dtype)

        new_params = jax.tree.map(upd, params, state["mu"], grads)
        mu = jax.tree.map(lambda m, g: b2 * m + (1 - b2) * g,
                          state["mu"], grads)
        return new_params, {"mu": mu, "step": step}

    raise ValueError(cfg.kind)
