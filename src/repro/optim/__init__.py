from repro.optim.optimizers import (OptimizerConfig, clip_by_global_norm,
                                    global_norm, init_state, schedule_lr,
                                    update)

__all__ = ["OptimizerConfig", "init_state", "update", "schedule_lr",
           "global_norm", "clip_by_global_norm"]
