"""Core neural-net layers shared by every architecture in the zoo.

Pure-functional style: every layer is an ``init_*`` returning a param pytree
(plain dicts of jnp arrays) plus an ``apply``-style function taking
``(params, inputs, cfg)``.  No framework (flax/haiku) — keeps the param tree
transparent for Hydra's shard-granular spilling and for pjit sharding rules.

Conventions
-----------
* ``cfg`` is a ``repro.configs.base.ArchConfig``.
* Stacked-layer params: callers stack per-layer trees along axis 0 and drive
  them with ``jax.lax.scan`` so the lowered HLO is O(1) in depth.
* Compute dtype is ``cfg.dtype`` (bf16 on TPU); params kept in
  ``cfg.param_dtype`` (f32 master copies).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = dict  # nested dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis_size=None, dtype=jnp.float32):
    """Scaled-normal init (truncated-normal-free; fine for repro purposes)."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(params: Params, x: jnp.ndarray, eps: float = 1e-6,
             use_kernel: bool = False) -> jnp.ndarray:
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.rms_norm(x, params["scale"], eps=eps)
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def init_layernorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                     # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                        # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional qkv-bias / qk-norm / sliding window / cross-attn)
# ---------------------------------------------------------------------------

def init_attention(key, cfg) -> Params:
    d, nh, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], (d, nh * hd), d, cfg.param_dtype),
        "wk": dense_init(ks[1], (d, nkv * hd), d, cfg.param_dtype),
        "wv": dense_init(ks[2], (d, nkv * hd), d, cfg.param_dtype),
        "wo": dense_init(ks[3], (nh * hd, d), nh * hd, cfg.param_dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nh * hd,), cfg.param_dtype)
        p["bk"] = jnp.zeros((nkv * hd,), cfg.param_dtype)
        p["bv"] = jnp.zeros((nkv * hd,), cfg.param_dtype)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, cfg.param_dtype)
        p["k_norm"] = init_rmsnorm(hd, cfg.param_dtype)
    return p


def _project_qkv(params: Params, x: jnp.ndarray, xkv: jnp.ndarray, cfg):
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    q = x @ params["wq"].astype(dt)
    k = xkv @ params["wk"].astype(dt)
    v = xkv @ params["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = q.reshape(*x.shape[:-1], nh, hd)
    k = k.reshape(*xkv.shape[:-1], nkv, hd)
    v = v.reshape(*xkv.shape[:-1], nkv, hd)
    if cfg.qk_norm:
        q = rms_norm(params["q_norm"], q)
        k = rms_norm(params["k_norm"], k)
    return q, k, v


# q-chunking threshold: above this many score elements per (b·h) row-block,
# the XLA path scans over query chunks so the (sq, skv) score matrix is never
# materialized whole (flash-style; the Pallas kernel is the TPU fast path).
_SDPA_CHUNK_ELEMS = 4096 * 4096
_SDPA_Q_CHUNK = 1024


def _sdpa_dense(q, k, v, scale, qpos, kpos, causal, window):
    """q: (b, sq, nkv, g, hd) grouped; k/v: (b, skv, nkv, hd)."""
    logits = jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v.astype(jnp.float32))
    return out


def sdpa(q, k, v, *, causal: bool, window: Optional[int] = None,
         q_positions: Optional[jnp.ndarray] = None,
         kv_positions: Optional[jnp.ndarray] = None,
         impl: str = "xla") -> jnp.ndarray:
    """Scaled dot-product attention with GQA broadcast.

    q: (b, sq, nh, hd); k/v: (b, skv, nkv, hd).  nh % nkv == 0.
    ``window``: sliding-window size (None = full).  Positions default to
    arange; decode passes explicit positions.
    """
    if impl in ("pallas", "pallas_interpret") and causal and q.shape[1] > 1:
        from repro.kernels import ops as kops
        return kops.flash_attention(
            q, k, v, causal=True, window=window,
            interpret=(impl == "pallas_interpret"))
    b, sq, nh, hd = q.shape
    skv, nkv = k.shape[1], k.shape[2]
    groups = nh // nkv
    qg = q.reshape(b, sq, nkv, groups, hd)
    scale = 1.0 / math.sqrt(hd)
    qpos = (q_positions if q_positions is not None
            else jnp.arange(sq))
    kpos = (kv_positions if kv_positions is not None
            else jnp.arange(skv))

    from repro.sharding.context import constrain_q_seq
    qg = constrain_q_seq(qg.reshape(b, sq, nh, hd)).reshape(
        b, sq, nkv, groups, hd)

    if sq * skv <= _SDPA_CHUNK_ELEMS or sq % _SDPA_Q_CHUNK != 0:
        out = _sdpa_dense(qg, k, v, scale, qpos, kpos, causal, window)
        return out.reshape(b, sq, nh, hd).astype(q.dtype)

    # chunked path: scan over query blocks; score rows live one block at a
    # time (the XLA analogue of the Pallas flash kernel, fully differentiable)
    nq = sq // _SDPA_Q_CHUNK
    qc = qg.reshape(b, nq, _SDPA_Q_CHUNK, nkv, groups, hd).transpose(
        1, 0, 2, 3, 4, 5)
    qpc = qpos.reshape(nq, _SDPA_Q_CHUNK)

    def body(_, inp):
        qb, qp = inp
        ob = _sdpa_dense(qb, k, v, scale, qp, kpos, causal, window)
        return None, ob

    _, out = jax.lax.scan(body, None, (qc, qpc))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, nkv, groups, hd)
    return out.reshape(b, sq, nh, hd).astype(q.dtype)


def attention(params: Params, x: jnp.ndarray, cfg, *,
              positions: Optional[jnp.ndarray] = None,
              causal: bool = True,
              window: Optional[int] = None,
              xkv: Optional[jnp.ndarray] = None,
              rope: bool = True,
              kv_cache: Optional[dict] = None,
              impl: str = "xla"):
    """Full attention layer.  Returns (out, new_kv_cache).

    kv_cache: {"k": (b, max_s, nkv, hd), "v": ..., "index": scalar} — decode
    appends at ``index`` and attends to the filled prefix.
    """
    b, sq, _ = x.shape
    cross = xkv is not None
    src = xkv if cross else x
    q, k, v = _project_qkv(params, x, src, cfg)
    if positions is None:
        positions = jnp.arange(sq)[None, :].astype(jnp.int32)
        positions = jnp.broadcast_to(positions, (b, sq))
    if rope and not cross:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif rope and cross:
        q = apply_rope(q, positions, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None and not cross:
        idx = kv_cache["index"]
        ck = jax.lax.dynamic_update_slice_in_dim(
            kv_cache["k"], k.astype(kv_cache["k"].dtype), idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            kv_cache["v"], v.astype(kv_cache["v"].dtype), idx, axis=1)
        new_cache = {"k": ck, "v": cv, "index": idx + sq}
        skv = ck.shape[1]
        kvpos = jnp.arange(skv)
        qpos = idx + jnp.arange(sq)
        # mask out unwritten slots via the causal predicate (kvpos <= qpos)
        out = sdpa(q, ck, cv, causal=True, window=window,
                   q_positions=qpos, kv_positions=kvpos, impl="xla")
    elif kv_cache is not None and cross:
        # cross-attn cache holds precomputed encoder k/v
        out = sdpa(q, kv_cache["k"], kv_cache["v"], causal=False, impl="xla")
        new_cache = kv_cache
    else:
        out = sdpa(q, k, v, causal=causal, window=window, impl=impl)

    dt = x.dtype
    out = out.reshape(b, sq, cfg.n_heads * cfg.head_dim)
    return out @ params["wo"].astype(dt), new_cache


def _scatter_kv_rows(pages: dict, blk, off, k, v) -> dict:
    """Write K/V rows through the block table into a pages pytree.

    pages: {"k","v"} of (P, bs, nkv, hd) — plus {"k_scale","v_scale"} of
    (P, bs, nkv) when the pool is int8-quantized, in which case the rows
    are quantized per-row on write (`ref.quantize_kv`) and the scales land
    at the same table-addressed slots.  blk/off index rows; k/v are the
    new rows broadcast-compatible with pages[blk, off]."""
    from repro.kernels import ref as kref
    if "k_scale" in pages:
        kq, ksc = kref.quantize_kv(k)
        vq, vsc = kref.quantize_kv(v)
        return {"k": pages["k"].at[blk, off].set(kq),
                "v": pages["v"].at[blk, off].set(vq),
                "k_scale": pages["k_scale"].at[blk, off].set(ksc),
                "v_scale": pages["v_scale"].at[blk, off].set(vsc)}
    return {"k": pages["k"].at[blk, off].set(k.astype(pages["k"].dtype)),
            "v": pages["v"].at[blk, off].set(v.astype(pages["v"].dtype))}


def paged_attention_decode(params: Params, x: jnp.ndarray, cfg, *,
                           pages: dict,
                           tables: jnp.ndarray, lengths: jnp.ndarray,
                           window: Optional[int] = None,
                           impl: str = "jnp"):
    """One-token attention block over a paged KV cache (one layer's pages).

    x: (n, 1, d) *normed* hidden states, one decode lane per row.
    pages: {"k","v"} of (P, bs, nkv, hd) physical blocks (+ per-row
    {"k_scale","v_scale"} when int8-quantized); tables: (n, B) block ids
    (unused entries must name a valid block — the pool's garbage block);
    lengths: (n,) rows already written, i.e. this token's row index.

    Writes this step's K/V row through the block table (one scatter across
    lanes — inactive lanes all land in the shared garbage block) and
    attends to the ``[0, lengths]`` logical prefix via
    ``kernels.ops.paged_attention`` (the dequantizing
    ``paged_attention_quant`` for int8 pools).  Returns (out, pages).
    """
    n = x.shape[0]
    q, k, v = _project_qkv(params, x, x, cfg)
    positions = lengths[:, None]                       # (n, 1)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    bs = pages["k"].shape[1]
    blk = tables[jnp.arange(n), lengths // bs]
    off = lengths % bs
    pages = _scatter_kv_rows(pages, blk, off, k[:, 0], v[:, 0])
    from repro.kernels import ops as kops
    # the fused-layer impl falls back to plain paged attention here (the
    # quantized / non-SwiGLU configs the fused kernel doesn't cover)
    attn_impl = {"fused": "pallas",
                 "fused_interpret": "pallas_interpret"}.get(impl, impl)
    if "k_scale" in pages:
        out = kops.paged_attention_quant(
            q[:, 0], pages["k"], pages["v"], pages["k_scale"],
            pages["v_scale"], tables, lengths + 1, window=window,
            impl=attn_impl)
    else:
        out = kops.paged_attention(q[:, 0], pages["k"], pages["v"], tables,
                                   lengths + 1, window=window,
                                   impl=attn_impl)
    out = out.reshape(n, 1, cfg.n_heads * cfg.head_dim)
    return out @ params["wo"].astype(x.dtype), pages


def paged_decode_layer_fused(lp: Params, h: jnp.ndarray, cfg, *,
                             pages: dict,
                             tables: jnp.ndarray, lengths: jnp.ndarray,
                             window: Optional[int] = None,
                             interpret: bool = False):
    """One FULL pre-norm decode block through the fused Pallas kernel:
    attn-norm + QKV projection + rope + KV scatter run here (they write
    the pages); attention through the block table, wo projection,
    residual, MLP RMSNorm, SwiGLU, and the second residual all run inside
    one `kernels.fused_decode` launch.  Requires ``cfg.norm == 'rms'`` and
    ``cfg.mlp == 'swiglu'`` and an fp (non-quantized) pool — callers gate
    on that and fall back to the unfused path otherwise.

    h: (n, 1, d) raw residual stream.  Returns (new_h, pages).
    """
    n = h.shape[0]
    x = rms_norm(lp["attn_norm"], h)
    q, k, v = _project_qkv(lp["attn"], x, x, cfg)
    positions = lengths[:, None]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    bs = pages["k"].shape[1]
    blk = tables[jnp.arange(n), lengths // bs]
    off = lengths % bs
    pages = _scatter_kv_rows(pages, blk, off, k[:, 0], v[:, 0])
    from repro.kernels import ops as kops
    dt = h.dtype
    out = kops.fused_decode_layer(
        h[:, 0], q[:, 0], pages["k"], pages["v"], tables, lengths + 1,
        lp["attn"]["wo"].astype(dt), lp["mlp_norm"]["scale"].astype(dt),
        lp["mlp"]["w_gate"].astype(dt), lp["mlp"]["w_up"].astype(dt),
        lp["mlp"]["w_down"].astype(dt), window=window,
        impl="pallas_interpret" if interpret else "pallas")
    return out[:, None, :], pages


def paged_attention_verify(params: Params, x: jnp.ndarray, cfg, *,
                           pages: dict,
                           tables: jnp.ndarray, lengths: jnp.ndarray,
                           window: Optional[int] = None,
                           impl: str = "jnp"):
    """k-token attention block over a paged KV cache (speculative verify).

    The multi-token twin of ``paged_attention_decode``: x is ``(n, k, d)``
    *normed* hidden states — the last committed token followed by k-1 draft
    tokens per lane.  Writes all k K/V rows through the block table in one
    scatter (rows ``lengths + [0, k)``; lanes whose table names only the
    garbage block park their rows there harmlessly), then attends each of
    the k query positions to its own causal prefix ``[0, lengths + i]``
    via ``kernels.ops.paged_verify`` — the Mosaic multi-query kernel for
    ``impl='pallas'``, the historical gathered path for ``'jnp'``.  int8
    pools take the gathered dequant path regardless of ``impl`` (draft
    depths are too small to earn a dedicated quant kernel).  Returns
    ``(out, pages)``.
    """
    n, kk, _ = x.shape
    q, k, v = _project_qkv(params, x, x, cfg)
    positions = lengths[:, None] + jnp.arange(kk)[None, :]        # (n, k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    bs = pages["k"].shape[1]
    blk = jnp.take_along_axis(tables, positions // bs, axis=1)    # (n, k)
    off = positions % bs
    pages = _scatter_kv_rows(pages, blk, off, k, v)
    from repro.kernels import ops as kops
    from repro.kernels import ref as kref
    attn_impl = {"fused": "pallas",
                 "fused_interpret": "pallas_interpret"}.get(impl, impl)
    if "k_scale" in pages:
        out = kref.paged_verify_quant_ref(
            q, pages["k"], pages["v"], pages["k_scale"], pages["v_scale"],
            tables, lengths, window=window)
    else:
        out = kops.paged_verify(q, pages["k"], pages["v"], tables, lengths,
                                window=window, impl=attn_impl)
    out = out.reshape(n, kk, cfg.n_heads * cfg.head_dim).astype(x.dtype)
    return out @ params["wo"].astype(x.dtype), pages


def init_kv_cache(cfg, batch: int, max_seq: int, n_layers: Optional[int] = None,
                  dtype=None) -> dict:
    """Stacked (layers-first) KV cache for decode.

    ``cfg.kv_cache_dtype='float8_e4m3fn'`` halves cache residency (the
    dominant HBM term for decode_32k on the 30B+ models) at serving-standard
    precision cost; values are cast on write and upcast on read."""
    L = n_layers if n_layers is not None else cfg.n_layers
    dtype = dtype if dtype is not None else jnp.dtype(cfg.kv_cache_dtype)
    shape = (L, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "index": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_swiglu(key, cfg, d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, f), d, cfg.param_dtype),
        "w_up": dense_init(ks[1], (d, f), d, cfg.param_dtype),
        "w_down": dense_init(ks[2], (f, d), f, cfg.param_dtype),
    }


def swiglu(params: Params, x: jnp.ndarray, use_kernel: bool = False) -> jnp.ndarray:
    dt = x.dtype
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.swiglu(x, params["w_gate"].astype(dt),
                           params["w_up"].astype(dt),
                           params["w_down"].astype(dt))
    g = x @ params["w_gate"].astype(dt)
    u = x @ params["w_up"].astype(dt)
    return (jax.nn.silu(g) * u) @ params["w_down"].astype(dt)


def init_gelu_mlp(key, cfg, d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 2)
    return {
        "w_in": dense_init(ks[0], (d, f), d, cfg.param_dtype),
        "b_in": jnp.zeros((f,), cfg.param_dtype),
        "w_out": dense_init(ks[1], (f, d), f, cfg.param_dtype),
        "b_out": jnp.zeros((d,), cfg.param_dtype),
    }


def gelu_mlp(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    dt = x.dtype
    h = jax.nn.gelu(x @ params["w_in"].astype(dt) + params["b_in"].astype(dt))
    return h @ params["w_out"].astype(dt) + params["b_out"].astype(dt)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"table": embed_init(key, (vocab, d), dtype)}


def embed(params: Params, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    return params["table"].astype(dtype)[tokens]


def unembed(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Tied LM head: logits in f32 for a stable softmax-xent."""
    return jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                      params["table"].astype(jnp.float32))
