"""Capability-driven family registry: one ``FamilySpec`` per model family.

The execution layers (serving backends, prefill factories, admission
sizing, the session planner) must never hard-code which families support
which optimization — that couples every new serving feature to a hunt
through call sites (the dispatch-dict / predicate-zoo problem this module
replaces).  Instead each family module registers a spec declaring:

* ``module`` — the implementation exposing the family surface
  (``init_params`` / ``forward`` / ``decode_step`` / ...);
* capability flags — ``batched_prefill``, ``padded_prefill``, ``paging``,
  ``pure_kv_state``, ``servable``, ``token_stream_data`` — each with a
  recorded *reason* when absent (``notes``), so fallback warnings and
  plan metadata can explain themselves;
* decode-state cost fns — ``decode_state_bytes`` / ``kv_block_bytes`` —
  the byte quantities admission control charges against the session's
  ``DeviceMemory`` ledger (defaults derive from ``jax.eval_shape`` over
  the module's constructors: weak-type correct, zero allocation).

Consumers ask ``spec(cfg)`` (or ``spec("dense")``) and read capabilities;
adding a family means registering one spec, and adding a capability means
one new field with a default — no call-site hunting either way.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from importlib import import_module
from types import ModuleType
from typing import Any, Callable, Optional

import jax


class CapabilityFallbackWarning(UserWarning):
    """A requested serving feature is not in the family's declared
    capabilities; execution fell back to the closest supported mode."""


def _tree_bytes(tree) -> int:
    return sum(math.prod(x.shape) * x.dtype.itemsize
               for x in jax.tree.leaves(tree))


def _default_decode_state_bytes(mod: ModuleType, cfg, batch: int,
                                max_seq: int) -> int:
    spec = jax.eval_shape(lambda: mod.init_decode_state(cfg, batch, max_seq))
    return _tree_bytes(spec)


def _default_kv_block_bytes(cfg, block_size: int) -> int:
    from repro.models import layers as nn
    pages = jax.eval_shape(lambda: nn.init_kv_cache(cfg, 1, block_size))
    return _tree_bytes({"k": pages["k"], "v": pages["v"]})


@dataclass(frozen=True)
class FamilySpec:
    """One model family's declared surface + capabilities + cost model."""

    family: str                     # cfg.family value ("dense", "moe", ...)
    module: ModuleType              # implementation module
    # -- capabilities --------------------------------------------------------
    batched_prefill: bool = False   # whole prompt chunk in ONE decode_step
    padded_prefill: bool = False    # right-padded prefill token-identical
    paging: bool = False            # decode state can live in paged KV blocks
    pure_kv_state: bool = False     # decode state is a pure KV cache
    servable: bool = True           # InferenceEngine can serve this family
    token_stream_data: bool = True  # train/eval batches are {tokens, labels}
    spec_draftable: bool = False    # multi-token verify + KV rollback work:
    #   the family can be the target (or draft) of speculative decoding
    kv_quant: bool = False          # paged KV pool can be int8-quantized
    #   (per-row scales stored alongside pages; requires paging)
    # capability -> one-line reason it is absent (warnings / plan meta)
    notes: dict = field(default_factory=dict)
    # -- cost fns (admission control charges these against the ledger) ------
    decode_state_cost: Optional[Callable[[Any, int, int], int]] = None
    kv_block_cost: Optional[Callable[..., int]] = None

    def decode_state_bytes(self, cfg, batch: int, max_seq: int) -> int:
        """Residency bytes of one decode state (slot-granular admission)."""
        if self.decode_state_cost is not None:
            return self.decode_state_cost(cfg, batch, max_seq)
        return _default_decode_state_bytes(self.module, cfg, batch, max_seq)

    def kv_block_bytes(self, cfg, block_size: int, kv_dtype=None) -> int:
        """Residency bytes of ONE physical KV block across all layers
        (page-granular admission).  Only meaningful when ``paging``.
        ``kv_dtype='int8'`` prices the quantized pool (pages + per-row
        scale planes) and requires the ``kv_quant`` capability."""
        if kv_dtype in (None, "fp"):
            if self.kv_block_cost is not None:
                return self.kv_block_cost(cfg, block_size)
            return _default_kv_block_bytes(cfg, block_size)
        if not self.kv_quant:
            raise ValueError(
                f"{self.family}: kv_dtype={kv_dtype!r} unsupported — "
                f"{self.why_not('kv_quant')}")
        return self.kv_block_cost(cfg, block_size, kv_dtype)

    @property
    def preemptible(self) -> bool:
        """A RUNNING request can be descheduled and later resumed with
        prefill skipped.  Derived, not declared: preemption rides on the
        paged backend's refcounted block tables (snapshot the table,
        keep the blocks), so exactly the ``paging`` families qualify —
        a family cannot promise preemption without paged KV."""
        return self.paging

    def capabilities(self) -> dict:
        """JSON-ready capability record (plan meta / poll / summaries)."""
        return {"batched_prefill": self.batched_prefill,
                "padded_prefill": self.padded_prefill,
                "paging": self.paging,
                "pure_kv_state": self.pure_kv_state,
                "servable": self.servable,
                "spec_draftable": self.spec_draftable,
                "kv_quant": self.kv_quant,
                "preemptible": self.preemptible}

    def why_not(self, capability: str) -> str:
        if capability == "kv_quant" and "kv_quant" not in self.notes:
            return ("int8 KV quantizes paged blocks on write; " +
                    ("the family has not declared a quantized page "
                     "layout + cost model" if self.paging
                     else self.why_not("paging")))
        if capability == "preemptible" and "preemptible" not in self.notes:
            # derived from paging: explain through the underlying flag
            return ("preemption snapshots paged block tables; " +
                    ("the slot/spec backends keep contiguous or lockstep "
                     "decode state — serve with backend='paged'"
                     if self.paging else self.why_not("paging")))
        return self.notes.get(capability, "not declared by the family spec")


_REGISTRY: dict[str, FamilySpec] = {}

# family -> module that registers it (lazy: spec() works regardless of
# which repro.models submodule the caller happened to import first)
_FAMILY_MODULES = {
    "dense": "repro.models.transformer",
    "vlm": "repro.models.transformer",
    "moe": "repro.models.moe",
    "ssm": "repro.models.ssm",
    "hybrid": "repro.models.hybrid",
    "audio": "repro.models.encdec",
}


def register(spec: FamilySpec) -> FamilySpec:
    """Register (or re-register) one family spec; returns it."""
    if not spec.family:
        raise ValueError("FamilySpec.family must be a non-empty name")
    _REGISTRY[spec.family] = spec
    return spec


def spec(family_or_cfg) -> FamilySpec:
    """Look up the FamilySpec for a family name or an ArchConfig."""
    family = getattr(family_or_cfg, "family", family_or_cfg)
    if family not in _REGISTRY:
        mod = _FAMILY_MODULES.get(family)
        if mod is not None:
            import_module(mod)          # registration side effect
    if family not in _REGISTRY:
        raise KeyError(
            f"no registered model family {family!r} "
            f"(have {sorted(set(_REGISTRY) | set(_FAMILY_MODULES))})")
    return _REGISTRY[family]


def registered_families() -> tuple[str, ...]:
    """Every registerable family name, importing lazily as needed."""
    for fam in _FAMILY_MODULES:
        spec(fam)
    return tuple(sorted(_REGISTRY))


def families_with(capability: str) -> tuple[str, ...]:
    """Family names declaring ``capability`` True (registry-wide query)."""
    return tuple(f for f in registered_families()
                 if getattr(spec(f), capability))
