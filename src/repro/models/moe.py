"""Mixture-of-Experts decoder (Mixtral / DBRX class).

Expert layer uses switch-style top-k routing with capacity-bounded token
dropping and scatter dispatch into a dense ``(E, C, d)`` buffer so the expert
matmuls stay MXU-shaped and the expert axis can be sharded over the mesh's
``model`` axis (expert parallelism — dispatch/undispatch become all-to-all
class collectives under GSPMD).

Aux losses (load-balance + router z-loss) are returned alongside the output
and surfaced by the train step.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as nn
from repro.sharding.context import constrain_batch, constrain_expert
from repro.models import transformer as tfm


# ---------------------------------------------------------------------------
# expert MLP bank + router
# ---------------------------------------------------------------------------

def init_moe_mlp(key, cfg):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": nn.dense_init(ks[0], (d, E), d, cfg.param_dtype),
        "w_gate": nn.dense_init(ks[1], (E, d, f), d, cfg.param_dtype),
        "w_up": nn.dense_init(ks[2], (E, d, f), d, cfg.param_dtype),
        "w_down": nn.dense_init(ks[3], (E, f, d), f, cfg.param_dtype),
    }


def expert_capacity(cfg, n_tokens: int) -> int:
    cap = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return max(8, ((cap + 7) // 8) * 8)   # pad to MXU-friendly multiple


MOE_SEQ_CHUNK = 1024


def _shardmap_applicable(cfg, batch_size: int):
    """Expert-parallel all_to_all path: usable when a mesh context is
    active, the expert count divides the model axis, and the batch divides
    the data axes (shard_map in_specs are hard constraints)."""
    from repro.sharding.context import _STATE
    from repro.sharding.specs import batch_axes
    mesh = _STATE.get("mesh")
    if mesh is None or "model" not in mesh.axis_names:
        return None
    if not _STATE.get("moe_shardmap", True):
        return None
    if cfg.n_experts % mesh.shape["model"] != 0:
        return None
    B = batch_axes(mesh)
    data_size = 1
    for a in (B if isinstance(B, tuple) else (B,)):
        data_size *= mesh.shape[a]
    if batch_size % data_size != 0:
        return None
    return mesh


def moe_mlp(params, x, cfg):
    """Dispatch entry point.

    * With an active mesh whose model axis divides the expert count:
      shard_map expert parallelism with explicit ``all_to_all`` — every
      buffer is member-local, sidestepping GSPMD's inability to shard
      scatter/gather batching dims (DESIGN.md §6b.4).
    * Otherwise: the GSPMD path, seq-chunked so the (device-replicated)
      dispatch buffers stay bounded.
    """
    b, s, d = x.shape
    # keep the dispatch buffers ~constant regardless of path: chunk so that
    # b x chunk stays near 16k tokens (buffers are device-replicated on the
    # GSPMD path and member-local but capacity-proportional on shard_map)
    chunk = min(MOE_SEQ_CHUNK, max(256, 16384 // max(b, 1)))
    if s <= chunk or s % chunk != 0:
        return _moe_dispatch(params, x, cfg)
    nch = s // chunk
    xs = x.reshape(b, nch, chunk, d).transpose(1, 0, 2, 3)

    def body(_, xc):
        y, aux = _moe_dispatch(params, xc, cfg)
        return None, (y, aux)

    _, (ys, auxs) = jax.lax.scan(body, None, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d)
    aux = jax.tree.map(lambda a: jnp.mean(a, axis=0), auxs)
    return y, aux


def _moe_dispatch(params, x, cfg):
    mesh = _shardmap_applicable(cfg, x.shape[0])
    if mesh is not None:
        return _moe_mlp_shardmap(params, x, cfg, mesh)
    return _moe_mlp_inner(params, x, cfg)


def _routing(x, router, cfg):
    """Top-k routing + positions-within-expert (group-local, slot-major)."""
    b, s, _ = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = expert_capacity(cfg, s)
    logits = (x @ router.astype(x.dtype)).astype(jnp.float32)   # (b,s,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)             # (b,s,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)     # (b,s,K,E)
    slotmajor = onehot.transpose(0, 2, 1, 3).reshape(b, K * s, E)
    pos = jnp.cumsum(slotmajor, axis=1) - slotmajor
    pos = pos.reshape(b, K, s, E).transpose(0, 2, 1, 3)
    pos_in_expert = jnp.take_along_axis(
        pos, expert_idx[..., None], axis=-1)[..., 0]            # (b,s,K)
    keep = pos_in_expert < C
    density = jnp.mean(jax.nn.one_hot(expert_idx[..., 0], E,
                                      dtype=jnp.float32), axis=(0, 1))
    router_prob = jnp.mean(probs, axis=(0, 1))
    aux = {"lb_loss": E * jnp.sum(density * router_prob),
           "z_loss": jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))),
           "frac_dropped": 1.0 - jnp.mean(keep.astype(jnp.float32))}
    return gate_vals, expert_idx, pos_in_expert, keep, C, aux


def _moe_mlp_shardmap(params, x, cfg, mesh):
    """Expert parallelism with explicit all_to_all under jax.shard_map.

    Every model-axis member owns E/model experts.  Tokens are dispatched
    into member-local (b_loc, E, C, d) buffers, exchanged over the model
    axis (each member receives the slots destined for its experts from all
    peers), computed with the local expert weights, and exchanged back.
    All indexing is member-local — no cross-shard scatter/gather for GSPMD
    to replicate.
    """
    from jax.sharding import PartitionSpec as P
    from repro.sharding.specs import batch_axes

    b, s, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    M = mesh.shape["model"]
    e_per = E // M
    B = batch_axes(mesh)

    # routing + aux on the plain GSPMD path (cheap elementwise math)
    gate_vals, expert_idx, pos_in_expert, keep, C, aux = _routing(
        x, params["router"], cfg)
    dt = x.dtype

    def local(xb, gates_b, eidx_b, pos_b, keep_b, wg, wu, wd):
        bl, sl, _ = xb.shape
        # member-local dispatch buffer (bl, E, C, d)
        flat_e = jnp.where(keep_b, eidx_b, E)
        pos_c = jnp.where(keep_b, pos_b, 0)
        rows = jnp.broadcast_to(jnp.arange(bl)[:, None, None], (bl, sl, K))
        buf = jnp.zeros((bl, E + 1, C, d), dt)
        buf = buf.at[rows.reshape(bl, -1), flat_e.reshape(bl, -1),
                     pos_c.reshape(bl, -1)].set(
            jnp.repeat(xb[:, :, None], K, axis=2).reshape(bl, -1, d),
            mode="drop")
        buf = buf[:, :E]

        # exchange: dim0 = destination member (owner of the expert group)
        send = buf.reshape(bl, M, e_per, C, d).transpose(1, 0, 2, 3, 4)
        recv = jax.lax.all_to_all(send, "model", split_axis=0,
                                  concat_axis=0)   # (M_src, bl, e_per, C, d)

        # local expert compute (wg/wu: (e_per, d, f); wd: (e_per, f, d))
        g = jnp.einsum("mbjcd,jdf->mbjcf", recv, wg.astype(dt))
        u = jnp.einsum("mbjcd,jdf->mbjcf", recv, wu.astype(dt))
        yexp = jnp.einsum("mbjcf,jfd->mbjcd", jax.nn.silu(g) * u,
                          wd.astype(dt))

        # exchange back: dim0 returns to the source member
        back = jax.lax.all_to_all(yexp, "model", split_axis=0,
                                  concat_axis=0)   # (M, bl, e_per, C, d)
        yfull = back.transpose(1, 0, 2, 3, 4).reshape(bl, E, C, d)

        # member-local combine
        slot = flat_e.clip(0, E - 1) * C + pos_b.clip(0, C - 1)
        gathered = jax.vmap(lambda ye, ix: ye.reshape(E * C, d)[ix])(
            yfull, slot.reshape(bl, -1)).reshape(bl, sl, K, d)
        gathered = jnp.where(keep_b[..., None], gathered, 0)
        return jnp.sum(gathered * gates_b[..., None].astype(dt), axis=2)

    if hasattr(jax, "shard_map"):
        shard_map, check_kw = jax.shard_map, {"check_vma": False}
    else:   # pre-0.5 jax: experimental home, and the flag is check_rep
        from jax.experimental.shard_map import shard_map
        check_kw = {"check_rep": False}
    y = shard_map(
        local, mesh=mesh,
        in_specs=(P(B, None, None), P(B, None, None), P(B, None, None),
                  P(B, None, None), P(B, None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=P(B, None, None),
        **check_kw,
    )(x, gate_vals, expert_idx, pos_in_expert, keep,
      params["w_gate"], params["w_up"], params["w_down"])
    return y, aux


def _moe_mlp_inner(params, x, cfg):
    """x: (b, s, d) -> (y, aux) with aux = {"lb_loss", "z_loss", "frac_dropped"}.

    Group-local dispatch (GShard-style): each batch row is a routing group
    with its own capacity, so dispatch/combine indexing never crosses the
    batch (=data-axis) sharding — the expert dimension alone travels over
    the 'model' axis (expert parallelism, all-to-all class collectives).
    A single global capacity pool would need cross-data-shard gathers that
    GSPMD replicates (measured 210 GB/device on dbrx-132b prefill_32k).
    """
    b, s, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = expert_capacity(cfg, s)                    # capacity per group (row)

    logits = (x @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (b,s,E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)             # (b,s,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # position of each (token, slot) within its expert, slot-major within a
    # group so slot 0 wins capacity before slot 1 (standard switch ordering)
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)     # (b,s,K,E)
    slotmajor = onehot.transpose(0, 2, 1, 3).reshape(b, K * s, E)
    pos = jnp.cumsum(slotmajor, axis=1) - slotmajor             # (b,K*s,E)
    pos = pos.reshape(b, K, s, E).transpose(0, 2, 1, 3)         # (b,s,K,E)
    pos_in_expert = jnp.take_along_axis(
        pos, expert_idx[..., None], axis=-1)[..., 0]            # (b,s,K)
    keep = pos_in_expert < C
    frac_dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))

    # scatter tokens into the (b, E, C, d) dispatch buffer (group-local)
    flat_e = jnp.where(keep, expert_idx, E)     # dropped -> out-of-range row
    pos_c = jnp.where(keep, pos_in_expert, 0)
    rows = jnp.broadcast_to(jnp.arange(b)[:, None, None], (b, s, K))
    buf = jnp.zeros((b, E + 1, C, d), x.dtype)
    buf = buf.at[rows.reshape(b, -1),
                 flat_e.reshape(b, -1),
                 pos_c.reshape(b, -1)].set(
        jnp.repeat(x[:, :, None], K, axis=2).reshape(b, -1, d), mode="drop")
    buf = buf[:, :E]                             # (b, E, C, d)
    buf = constrain_expert(buf)                  # b@data, E@model

    # expert compute (E stays a shardable axis; group dim stays on data)
    dt = x.dtype
    g = jnp.einsum("becd,edf->becf", buf, params["w_gate"].astype(dt))
    u = jnp.einsum("becd,edf->becf", buf, params["w_up"].astype(dt))
    h = constrain_expert(jax.nn.silu(g) * u)     # (b, E, C, f)
    yexp = jnp.einsum("becf,efd->becd", h, params["w_down"].astype(dt))
    yexp = constrain_expert(yexp)                # (b, E, C, d)

    # combine: group-local gather — vmap over the group dim so the lowered
    # gather carries an operand batching dim GSPMD can keep on 'data'
    # (flat advanced indexing lowers to a batchless gather that SPMD
    # replicates: 103 GB/device on dbrx prefill)
    slot = flat_e.clip(0, E - 1) * C + pos_in_expert.clip(0, C - 1)
    gathered = jax.vmap(lambda ye, ix: ye.reshape(E * C, d)[ix])(
        yexp, slot.reshape(b, -1))                             # (b,s*K,d)
    gathered = gathered.reshape(b, s, K, d)
    gathered = constrain_batch(gathered, seq_parallel=False)
    gathered = jnp.where(keep[..., None], gathered, 0)
    y = jnp.sum(gathered * gate_vals[..., None].astype(dt), axis=2)

    # aux losses (Switch Transformer eq. 4-6)
    density = jnp.mean(jax.nn.one_hot(expert_idx[..., 0], E,
                                      dtype=jnp.float32), axis=(0, 1))
    router_prob = jnp.mean(probs, axis=(0, 1))
    lb_loss = E * jnp.sum(density * router_prob)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = {"lb_loss": lb_loss, "z_loss": z_loss, "frac_dropped": frac_dropped}
    return y, aux


# ---------------------------------------------------------------------------
# blocks / model
# ---------------------------------------------------------------------------

def init_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": nn.init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "attn": nn.init_attention(k1, cfg),
        "mlp_norm": nn.init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "moe": init_moe_mlp(k2, cfg),
    }


def init_params(cfg, key):
    ke, kl = jax.random.split(key)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    return {
        "embed": nn.init_embedding(ke, cfg.vocab_size, cfg.d_model,
                                   cfg.param_dtype),
        "layers": stacked,
        "final_norm": nn.init_rmsnorm(cfg.d_model, cfg.param_dtype),
    }


def apply_layer(cfg, lp, x, *, window=None):
    xn = constrain_batch(nn.rms_norm(lp["attn_norm"], x), seq_parallel=False)
    h, _ = nn.attention(lp["attn"], xn, cfg,
                        causal=cfg.causal,
                        window=window if window is not None else cfg.window,
                        impl=cfg.attn_impl)
    x = x + h
    xn = constrain_batch(nn.rms_norm(lp["mlp_norm"], x), seq_parallel=False)
    y, aux = moe_mlp(lp["moe"], xn, cfg)
    return x + y, aux


def apply_layer_range(cfg, stacked_slice, x, *, window=None, remat=None):
    remat = cfg.remat if remat is None else remat
    fn = partial(apply_layer, cfg, window=window)
    if remat:
        fn = jax.checkpoint(fn)

    def body(h, lp):
        h, aux = fn(lp, h)
        return constrain_batch(h), (aux["lb_loss"], aux["z_loss"])

    out, (lb, zl) = jax.lax.scan(body, x, stacked_slice)
    return out, {"lb_loss": jnp.mean(lb), "z_loss": jnp.mean(zl)}


def forward(cfg, params, batch, *, window=None, return_aux=False,
            last_only=False):
    x = tfm.embed_inputs(cfg, params, batch)
    x, aux = apply_layer_range(cfg, params["layers"], x, window=window)
    if last_only:
        x = x[:, -1:]
    x = nn.rms_norm(params["final_norm"], x)
    logits = nn.unembed(params["embed"], x)
    return (logits, aux) if return_aux else logits


def init_decode_state(cfg, batch: int, max_seq: int):
    return {"kv": nn.init_kv_cache(cfg, batch, max_seq)}


def decode_step(cfg, params, state, tokens, *, window=None):
    x = nn.embed(params["embed"], tokens, cfg.dtype)
    kv = state["kv"]

    def body(h, xs):
        lp, k_l, v_l = xs
        cache = {"k": k_l, "v": v_l, "index": kv["index"]}
        positions = cache["index"] + jnp.arange(h.shape[1])[None, :]
        positions = jnp.broadcast_to(positions, h.shape[:2])
        a, nc = nn.attention(lp["attn"], nn.rms_norm(lp["attn_norm"], h), cfg,
                             positions=positions, causal=True,
                             window=window if window is not None else cfg.window,
                             kv_cache=cache)
        h = h + a
        y, _ = moe_mlp(lp["moe"], nn.rms_norm(lp["mlp_norm"], h), cfg)
        return constrain_batch(h + y), (nc["k"], nc["v"])

    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], kv["k"], kv["v"]))
    x = nn.rms_norm(params["final_norm"], x)
    logits = nn.unembed(params["embed"], x)
    new_state = {"kv": {"k": nk, "v": nv,
                        "index": kv["index"] + tokens.shape[1]}}
    return logits, new_state


def _register():
    import sys

    from repro.models import registry
    registry.register(registry.FamilySpec(
        family="moe", module=sys.modules[__name__],
        batched_prefill=True, padded_prefill=False, paging=False,
        pure_kv_state=True, servable=True, token_stream_data=True,
        notes={
            "padded_prefill": "capacity-bounded expert routing couples "
                              "tokens: pad tokens consume expert capacity "
                              "and displace real tokens' routes",
            "paging": "expert capacity is a function of the token batch, "
                      "coupling decode lanes: a batched paged step would "
                      "not be token-identical to per-lane decode",
            "spec_draftable": "capacity-bounded routing couples the k "
                              "verified tokens: a multi-token verify would "
                              "route differently than token-by-token decode",
        }))


_register()
