"""State-space / recurrent families: Mamba2 (SSD) and xLSTM (mLSTM + sLSTM).

The workhorse is ``ssd_chunked`` — the Mamba2 "state-space duality" chunked
algorithm: quadratic attention *within* a chunk, linear recurrence *across*
chunks.  mLSTM is expressed through the same primitive (its matrix memory
S_t = f_t·S + i_t·k v^T is an SSD recurrence with per-head scalar decay),
so one well-tested kernel serves both families.  ``repro.kernels.ssd_scan``
provides the Pallas TPU kernel for the intra-chunk part; this file is also
its ``ref`` oracle.

Decode: both families carry O(1) state per layer (Mamba2: (h, p, N) matrix +
conv tail; mLSTM: (h, p, N) matrix + normalizer; sLSTM: (h, p) vectors), which
is what makes the ``long_500k`` shape natively tractable.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as nn
from repro.sharding.context import constrain_batch

SSM_HEAD_DIM = 64  # Mamba2 P (head dim)


# ---------------------------------------------------------------------------
# SSD: chunked selective-state-space computation
# ---------------------------------------------------------------------------

def ssd_chunked(x, log_a, b_coef, c_coef, chunk: int,
                initial_state: Optional[jnp.ndarray] = None,
                use_kernel: bool = False):
    """Chunked SSD scan.

    x:      (b, s, h, p)   inputs (already scaled by dt where applicable)
    log_a:  (b, s, h)      per-step log decay (<= 0)
    b_coef: (b, s, h, n)   input->state coefficients  ("B" / keys)
    c_coef: (b, s, h, n)   state->output coefficients ("C" / queries)
    Returns (y, final_state) with y: (b, s, h, p), state: (b, h, p, n).
    """
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.ssd_scan(x, log_a, b_coef, c_coef, chunk=chunk,
                             initial_state=initial_state)
    bsz, s, h, p = x.shape
    n = b_coef.shape[-1]
    if s % chunk != 0:
        # pad to a chunk multiple: zero x/B/C and zero log-decay leave the
        # recurrent state untouched; padded outputs are sliced away
        pad = chunk - s % chunk
        y, st = ssd_chunked(
            jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(log_a, ((0, 0), (0, pad), (0, 0))),
            jnp.pad(b_coef, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(c_coef, ((0, 0), (0, pad), (0, 0), (0, 0))),
            chunk, initial_state=initial_state, use_kernel=use_kernel)
        return y[:, :s], st
    nc = s // chunk

    f32 = jnp.float32
    xc = x.reshape(bsz, nc, chunk, h, p).astype(f32)
    ac = log_a.reshape(bsz, nc, chunk, h).astype(f32)
    bc = b_coef.reshape(bsz, nc, chunk, h, n).astype(f32)
    cc = c_coef.reshape(bsz, nc, chunk, h, n).astype(f32)

    a_cum = jnp.cumsum(ac, axis=2)                       # (b,nc,Q,h)
    a_tot = a_cum[:, :, -1]                              # (b,nc,h)

    # --- intra-chunk (quadratic in Q) -----------------------------------
    # L[i,j] = exp(a_cum[i] - a_cum[j]) for i >= j else 0
    li = a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :]   # (b,nc,Q,Q,h)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: the i<j region has li > 0 and exp overflows -> the VJP
    # of where(mask, exp(li), 0) yields inf*0 = NaN.
    li = jnp.where(mask[None, None, :, :, None], li, -1e30)
    decay = jnp.exp(li)
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", cc, bc) * decay
    y_diag = jnp.einsum("bcqkh,bckhp->bcqhp", scores, xc)

    # --- chunk-boundary states ------------------------------------------
    # state contribution of chunk c: sum_j exp(a_tot - a_cum[j]) B_j x_j^T
    w = jnp.exp(a_tot[:, :, None, :] - a_cum)            # (b,nc,Q,h)
    chunk_states = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", w, bc, xc)

    # --- inter-chunk linear recurrence (scan over chunks) ----------------
    if initial_state is None:
        initial_state = jnp.zeros((bsz, h, p, n), f32)
    else:
        initial_state = initial_state.astype(f32)

    decay_chunk = jnp.exp(a_tot)                          # (b,nc,h)

    def body(prev, inputs):
        s_c, d_c = inputs                                 # (b,h,p,n), (b,h)
        new = prev * d_c[:, :, None, None] + s_c
        return new, prev                                  # emit state *before* chunk

    final_state, prev_states = jax.lax.scan(
        body, initial_state,
        (chunk_states.transpose(1, 0, 2, 3, 4), decay_chunk.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)    # (b,nc,h,p,n)

    # --- inter-chunk output contribution ---------------------------------
    y_off = jnp.einsum("bcqh,bcqhn,bchpn->bcqhp",
                       jnp.exp(a_cum), cc, prev_states)

    y = (y_diag + y_off).reshape(bsz, s, h, p).astype(x.dtype)
    return y, final_state.astype(jnp.float32)


def ssd_step(state, x_t, log_a_t, b_t, c_t):
    """Single-token SSD recurrence (decode).

    state: (b,h,p,n); x_t: (b,h,p); log_a_t: (b,h); b_t/c_t: (b,h,n).
    """
    f32 = jnp.float32
    decay = jnp.exp(log_a_t.astype(f32))[:, :, None, None]
    upd = x_t.astype(f32)[..., None] * b_t.astype(f32)[:, :, None, :]
    new_state = state.astype(f32) * decay + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, c_t.astype(f32))
    return y.astype(x_t.dtype), new_state


# ---------------------------------------------------------------------------
# causal depthwise conv (Mamba front conv)
# ---------------------------------------------------------------------------

def causal_conv1d(x, w, b):
    """x: (b, s, c); w: (k, c); b: (c,). Depthwise causal conv."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32), w[:, None, :].astype(jnp.float32),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def causal_conv1d_step(conv_state, x_t, w, b):
    """conv_state: (b, k-1, c); x_t: (b, c). Returns (y_t, new_state)."""
    window = jnp.concatenate([conv_state, x_t[:, None]], axis=1)   # (b,k,c)
    y = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                   w.astype(jnp.float32)) + b.astype(jnp.float32)
    return y.astype(x_t.dtype), window[:, 1:]


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def mamba2_dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    h = d_in // SSM_HEAD_DIM
    return d_in, h, SSM_HEAD_DIM, cfg.ssm_state


def init_mamba2(key, cfg):
    d = cfg.d_model
    d_in, h, p, n = mamba2_dims(cfg)
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * n + h      # z, x, B, C, dt
    dt_bias = jnp.log(jnp.expm1(
        jnp.exp(jax.random.uniform(ks[2], (h,), minval=math.log(1e-3),
                                   maxval=math.log(1e-1)))))
    return {
        "in_proj": nn.dense_init(ks[0], (d, proj_out), d, cfg.param_dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_kernel, d_in + 2 * n))
                   * 0.1).astype(cfg.param_dtype),
        "conv_b": jnp.zeros((d_in + 2 * n,), cfg.param_dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(cfg.param_dtype),
        "d_skip": jnp.ones((h,), cfg.param_dtype),
        "dt_bias": dt_bias.astype(cfg.param_dtype),
        "out_norm": nn.init_rmsnorm(d_in, cfg.param_dtype),
        "out_proj": nn.dense_init(ks[3], (d_in, d), d_in, cfg.param_dtype),
    }


def _mamba2_split(params, x, cfg):
    d_in, h, p, n = mamba2_dims(cfg)
    dt_proj = x @ params["in_proj"].astype(x.dtype)
    z, xbc, dt = jnp.split(dt_proj, [d_in, 2 * d_in + 2 * n], axis=-1)
    return z, xbc, dt, (d_in, h, p, n)


def mamba2_forward(params, x, cfg, *, use_kernel: bool = False):
    """x: (b, s, d) -> (b, s, d). Training/prefill path (chunked scan)."""
    b, s, d = x.shape
    z, xbc, dt, (d_in, h, p, n) = _mamba2_split(params, x, cfg)
    xbc = jax.nn.silu(causal_conv1d(xbc, params["conv_w"], params["conv_b"]))
    xi, bc, cc = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    xi = xi.reshape(b, s, h, p)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (b,s,h)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))              # (h,)
    log_a = dt * a                                                  # (b,s,h)
    bch = jnp.broadcast_to(bc[:, :, None, :], (b, s, h, n))
    cch = jnp.broadcast_to(cc[:, :, None, :], (b, s, h, n))
    xdt = xi * dt[..., None].astype(xi.dtype)
    y, _ = ssd_chunked(xdt, log_a, bch, cch, cfg.ssm_chunk,
                       use_kernel=use_kernel)
    y = y + xi * params["d_skip"].astype(xi.dtype)[None, None, :, None]
    y = y.reshape(b, s, d_in)
    y = nn.rms_norm(params["out_norm"], y) * jax.nn.silu(z)
    return y @ params["out_proj"].astype(x.dtype)


def init_mamba2_state(cfg, batch: int):
    d_in, h, p, n = mamba2_dims(cfg)
    return {
        "ssm": jnp.zeros((batch, h, p, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, d_in + 2 * n),
                          jnp.float32),
    }


def mamba2_step(params, x_t, state, cfg):
    """x_t: (b, d) one token. Returns (y_t, new_state)."""
    b, d = x_t.shape
    z, xbc, dt, (d_in, h, p, n) = _mamba2_split(params, x_t, cfg)
    xbc, conv_state = causal_conv1d_step(
        state["conv"].astype(x_t.dtype), xbc,
        params["conv_w"], params["conv_b"])
    xbc = jax.nn.silu(xbc)
    xi, bc, cc = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    xi = xi.reshape(b, h, p)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (b,h)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    log_a = dt * a
    bch = jnp.broadcast_to(bc[:, None, :], (b, h, n))
    cch = jnp.broadcast_to(cc[:, None, :], (b, h, n))
    y, new_ssm = ssd_step(state["ssm"], xi * dt[..., None].astype(xi.dtype),
                          log_a, bch, cch)
    y = y + xi * params["d_skip"].astype(xi.dtype)[None, :, None]
    y = y.reshape(b, d_in)
    y = nn.rms_norm(params["out_norm"], y) * jax.nn.silu(z)
    y = y @ params["out_proj"].astype(x_t.dtype)
    return y, {"ssm": new_ssm, "conv": conv_state.astype(jnp.float32)}


# ---------------------------------------------------------------------------
# xLSTM: mLSTM block (matrix memory — expressed through SSD)
# ---------------------------------------------------------------------------

def mlstm_dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    h = cfg.n_heads
    p = d_in // h
    return d_in, h, p


def init_mlstm(key, cfg):
    d = cfg.d_model
    d_in, h, p = mlstm_dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "up_proj": nn.dense_init(ks[0], (d, 2 * d_in), d, cfg.param_dtype),
        "wq": nn.dense_init(ks[1], (d_in, d_in), d_in, cfg.param_dtype),
        "wk": nn.dense_init(ks[2], (d_in, d_in), d_in, cfg.param_dtype),
        "wv": nn.dense_init(ks[3], (d_in, d_in), d_in, cfg.param_dtype),
        "w_gates": nn.dense_init(ks[4], (d_in, 2 * h), d_in, cfg.param_dtype),
        "out_norm": nn.init_rmsnorm(d_in, cfg.param_dtype),
        "down_proj": nn.dense_init(ks[5], (d_in, d), d_in, cfg.param_dtype),
    }


def _mlstm_qkv_gates(params, xi, h, p):
    shp = xi.shape[:-1]
    dt = xi.dtype
    q = (xi @ params["wq"].astype(dt)).reshape(*shp, h, p)
    k = (xi @ params["wk"].astype(dt)).reshape(*shp, h, p) / math.sqrt(p)
    v = (xi @ params["wv"].astype(dt)).reshape(*shp, h, p)
    gates = (xi @ params["w_gates"].astype(dt)).astype(jnp.float32)
    logf, logi_raw = jnp.split(gates, 2, axis=-1)
    log_f = jax.nn.log_sigmoid(logf)          # (..., h) decay in (0,1)
    i_gate = jnp.exp(jax.nn.log_sigmoid(logi_raw))
    return q, k, v, log_f, i_gate


def mlstm_forward(params, x, cfg, *, use_kernel: bool = False):
    """mLSTM block: (b, s, d) -> (b, s, d)."""
    b, s, d = x.shape
    d_in, h, p = mlstm_dims(cfg)
    up = x @ params["up_proj"].astype(x.dtype)
    xi, z = jnp.split(up, 2, axis=-1)
    q, k, v, log_f, i_gate = _mlstm_qkv_gates(params, xi, h, p)
    # matrix memory: S_t = f_t S + i_t k v^T  ==  SSD(x=v*i, a=log f, B=k, C=q)
    y, _ = ssd_chunked(v * i_gate[..., None].astype(v.dtype), log_f, k, q,
                       cfg.ssm_chunk, use_kernel=use_kernel)
    # normalizer: n_t = f n + i k ; divide by max(|n·q|, 1)
    ones = jnp.ones((b, s, h, 1), v.dtype)
    nsum, _ = ssd_chunked(ones * i_gate[..., None].astype(v.dtype), log_f,
                          k, q, cfg.ssm_chunk)
    denom = jnp.maximum(jnp.abs(nsum[..., 0]), 1.0)[..., None]
    y = (y / denom).reshape(b, s, d_in)
    y = nn.rms_norm(params["out_norm"], y) * jax.nn.silu(z)
    return y @ params["down_proj"].astype(x.dtype)


def init_mlstm_state(cfg, batch: int):
    d_in, h, p = mlstm_dims(cfg)
    return {"s": jnp.zeros((batch, h, p, p), jnp.float32),
            "n": jnp.zeros((batch, h, 1, p), jnp.float32)}


def mlstm_step(params, x_t, state, cfg):
    b, d = x_t.shape
    d_in, h, p = mlstm_dims(cfg)
    up = x_t @ params["up_proj"].astype(x_t.dtype)
    xi, z = jnp.split(up, 2, axis=-1)
    q, k, v, log_f, i_gate = _mlstm_qkv_gates(params, xi, h, p)
    y, new_s = ssd_step(state["s"], v * i_gate[..., None].astype(v.dtype),
                        log_f, k, q)
    nsum, new_n = ssd_step(state["n"],
                           jnp.ones((b, h, 1), v.dtype)
                           * i_gate[..., None].astype(v.dtype),
                           log_f, k, q)
    denom = jnp.maximum(jnp.abs(nsum[..., 0]), 1.0)[..., None]
    y = (y / denom).reshape(b, d_in)
    y = nn.rms_norm(params["out_norm"], y) * jax.nn.silu(z)
    return y @ params["down_proj"].astype(x_t.dtype), {"s": new_s, "n": new_n}


# ---------------------------------------------------------------------------
# xLSTM: sLSTM block (true recurrence — lax.scan over time)
# ---------------------------------------------------------------------------

def slstm_dims(cfg):
    h = cfg.n_heads
    p = cfg.d_model // h
    return h, p


def init_slstm(key, cfg):
    d = cfg.d_model
    h, p = slstm_dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "w_in": nn.dense_init(ks[0], (d, 4 * d), d, cfg.param_dtype),
        "r": nn.dense_init(ks[1], (h, p, 4 * p), p, cfg.param_dtype),
        "b": jnp.zeros((4 * d,), cfg.param_dtype),
        "out_norm": nn.init_rmsnorm(d, cfg.param_dtype),
        "out_proj": nn.dense_init(ks[2], (d, d), d, cfg.param_dtype),
        "ffn": nn.init_swiglu(ks[3], cfg, d_ff=2 * d),
    }


def _slstm_cell(params, x_t, carry, cfg):
    """x_t: (b, d); carry: dict of (b, h, p)."""
    h, p = slstm_dims(cfg)
    b = x_t.shape[0]
    f32 = jnp.float32
    pre = (x_t @ params["w_in"].astype(x_t.dtype)).astype(f32)
    pre = pre + params["b"].astype(f32)
    rec = jnp.einsum("bhp,hpq->bhq", carry["h"],
                     params["r"].astype(f32)).reshape(b, 4 * h * p)
    pre = (pre.reshape(b, 4, h, p)
           + rec.reshape(b, h, 4, p).transpose(0, 2, 1, 3))
    ig, fg, zg, og = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    i_t = jnp.exp(jax.nn.log_sigmoid(ig))
    f_t = jax.nn.sigmoid(fg)
    z_t = jnp.tanh(zg)
    o_t = jax.nn.sigmoid(og)
    c_t = f_t * carry["c"] + i_t * z_t
    n_t = f_t * carry["n"] + i_t
    h_t = o_t * c_t / jnp.maximum(n_t, 1.0)
    return {"c": c_t, "n": n_t, "h": h_t}


def init_slstm_state(cfg, batch: int):
    h, p = slstm_dims(cfg)
    zero = jnp.zeros((batch, h, p), jnp.float32)
    return {"c": zero, "n": zero, "h": zero}


def slstm_forward(params, x, cfg):
    """sLSTM block: (b, s, d) -> (b, s, d) via scan over time."""
    b, s, d = x.shape
    h, p = slstm_dims(cfg)
    carry0 = init_slstm_state(cfg, b)

    def body(carry, x_t):
        new = _slstm_cell(params, x_t, carry, cfg)
        return new, new["h"]

    _, hs = jax.lax.scan(body, carry0, x.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    y = nn.rms_norm(params["out_norm"], y)
    y = y @ params["out_proj"].astype(x.dtype)
    return y + nn.swiglu(params["ffn"], y)


def slstm_step(params, x_t, carry, cfg):
    new = _slstm_cell(params, x_t, carry, cfg)
    y = new["h"].reshape(x_t.shape[0], -1).astype(x_t.dtype)
    y = nn.rms_norm(params["out_norm"], y)
    y = y @ params["out_proj"].astype(x_t.dtype)
    return y + nn.swiglu(params["ffn"], y), new


# ---------------------------------------------------------------------------
# xLSTM model (alternating mLSTM / sLSTM pattern groups)
# ---------------------------------------------------------------------------

def n_groups(cfg) -> int:
    assert cfg.slstm_ratio == 2, "xLSTM pattern implemented as [mLSTM, sLSTM]"
    assert cfg.n_layers % 2 == 0
    return cfg.n_layers // 2


def init_group(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "m_norm": nn.init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "mlstm": init_mlstm(k1, cfg),
        "s_norm": nn.init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "slstm": init_slstm(k2, cfg),
    }


def init_params(cfg, key):
    ke, kl = jax.random.split(key)
    keys = jax.random.split(kl, n_groups(cfg))
    stacked = jax.vmap(lambda k: init_group(k, cfg))(keys)
    return {
        "embed": nn.init_embedding(ke, cfg.vocab_size, cfg.d_model,
                                   cfg.param_dtype),
        "layers": stacked,
        "final_norm": nn.init_rmsnorm(cfg.d_model, cfg.param_dtype),
    }


def apply_layer(cfg, gp, x, **_):
    x = x + mlstm_forward(gp["mlstm"], nn.rms_norm(gp["m_norm"], x), cfg)
    x = x + slstm_forward(gp["slstm"], nn.rms_norm(gp["s_norm"], x), cfg)
    return x


def apply_layer_range(cfg, stacked_slice, x, *, remat=None, **_):
    remat = cfg.remat if remat is None else remat
    fn = partial(apply_layer, cfg)
    if remat:
        fn = jax.checkpoint(fn)

    def body(h, gp):
        return constrain_batch(fn(gp, h)), None

    out, _ = jax.lax.scan(body, x, stacked_slice)
    return out


def forward(cfg, params, batch, *, last_only=False, **_):
    x = nn.embed(params["embed"], batch["tokens"], cfg.dtype)
    x = apply_layer_range(cfg, params["layers"], x)
    if last_only:
        x = x[:, -1:]
    x = nn.rms_norm(params["final_norm"], x)
    return nn.unembed(params["embed"], x)


def init_decode_state(cfg, batch: int, max_seq: int):
    G = n_groups(cfg)

    def per_group(_):
        return {"mlstm": init_mlstm_state(cfg, batch),
                "slstm": init_slstm_state(cfg, batch)}

    return {"groups": jax.vmap(per_group)(jnp.arange(G)), "pos": jnp.zeros((), jnp.int32)}


def decode_step(cfg, params, state, tokens, **_):
    """tokens: (b, 1)."""
    x = nn.embed(params["embed"], tokens[:, 0], cfg.dtype)

    def body(h, xs):
        gp, gs = xs
        y, ms = mlstm_step(gp["mlstm"],
                           nn.rms_norm(gp["m_norm"], h), gs["mlstm"], cfg)
        h = h + y
        y, ss = slstm_step(gp["slstm"],
                           nn.rms_norm(gp["s_norm"], h), gs["slstm"], cfg)
        return h + y, {"mlstm": ms, "slstm": ss}

    x, new_groups = jax.lax.scan(body, x, (params["layers"], state["groups"]))
    x = nn.rms_norm(params["final_norm"], x)
    logits = nn.unembed(params["embed"], x[:, None, :])
    return logits, {"groups": new_groups, "pos": state["pos"] + 1}


def _register():
    import sys

    from repro.models import registry
    registry.register(registry.FamilySpec(
        family="ssm", module=sys.modules[__name__],
        batched_prefill=False, padded_prefill=False, paging=False,
        pure_kv_state=False, servable=True, token_stream_data=True,
        notes={
            "batched_prefill": "recurrent state advances strictly "
                               "token-by-token (prefill scans the prompt)",
            "padded_prefill": "recurrent state cannot be rewound past a "
                              "pad tail",
            "paging": "O(1) recurrent state — nothing to page",
            "pure_kv_state": "decode state is conv/ssd recurrences, not a "
                             "KV cache",
            "spec_draftable": "recurrent state cannot be rolled back past "
                              "rejected draft tokens",
        }))


_register()
