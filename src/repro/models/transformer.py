"""Dense decoder-only transformer (llama/qwen/yi/command-r class) and its
VLM/encoder variants (LLaVA backbone, BERT*/ViT* from the paper's workloads).

Param tree layout (Hydra shards over the leading ``layers`` axis):

    {"embed": {...}, "layers": stacked-per-layer tree, "final_norm": {...}}

``forward`` drives the stacked layers with ``jax.lax.scan`` so the lowered
HLO is O(1) in depth; ``apply_layer_range`` applies a contiguous slice of
layers — this is the primitive Hydra's shard units execute.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as nn
from repro.sharding.context import constrain_batch


def init_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    norm_init = nn.init_rmsnorm if cfg.norm == "rms" else nn.init_layernorm
    mlp_init = nn.init_swiglu if cfg.mlp == "swiglu" else nn.init_gelu_mlp
    return {
        "attn_norm": norm_init(cfg.d_model, cfg.param_dtype),
        "attn": nn.init_attention(k1, cfg),
        "mlp_norm": norm_init(cfg.d_model, cfg.param_dtype),
        "mlp": mlp_init(k2, cfg),
    }


def init_params(cfg, key):
    ke, kl = jax.random.split(key)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    norm_init = nn.init_rmsnorm if cfg.norm == "rms" else nn.init_layernorm
    return {
        "embed": nn.init_embedding(ke, cfg.vocab_size, cfg.d_model,
                                   cfg.param_dtype),
        "layers": stacked,
        "final_norm": norm_init(cfg.d_model, cfg.param_dtype),
    }


def _norm(cfg, p, x):
    return nn.rms_norm(p, x) if cfg.norm == "rms" else nn.layer_norm(p, x)


def apply_layer(cfg, lp, x, *, window: Optional[int] = None,
                positions=None, impl: Optional[str] = None):
    """One pre-norm transformer block. x: (b, s, d)."""
    impl = impl or cfg.attn_impl
    # Megatron-style sequence parallelism: the residual stream between
    # layers is seq-sharded over 'model'; norms run on it directly, and the
    # normed input is re-gathered (seq replicated) so tensor parallelism
    # owns the model axis inside attention/MLP.
    xn = constrain_batch(_norm(cfg, lp["attn_norm"], x), seq_parallel=False)
    h, _ = nn.attention(lp["attn"], xn, cfg,
                        positions=positions, causal=cfg.causal,
                        window=window if window is not None else cfg.window,
                        impl=impl)
    x = x + h
    hn = constrain_batch(_norm(cfg, lp["mlp_norm"], x), seq_parallel=False)
    h = (nn.swiglu(lp["mlp"], hn) if cfg.mlp == "swiglu"
         else nn.gelu_mlp(lp["mlp"], hn))
    return x + h


def apply_layer_decode(cfg, lp, x, cache, *, window=None):
    """One block in decode mode. cache: per-layer {"k","v","index"}."""
    positions = cache["index"] + jnp.arange(x.shape[1])[None, :]
    positions = jnp.broadcast_to(positions, (x.shape[0], x.shape[1]))
    h, new_cache = nn.attention(
        lp["attn"], _norm(cfg, lp["attn_norm"], x), cfg,
        positions=positions, causal=True,
        window=window if window is not None else cfg.window,
        kv_cache=cache)
    x = x + h
    hn = _norm(cfg, lp["mlp_norm"], x)
    h = (nn.swiglu(lp["mlp"], hn) if cfg.mlp == "swiglu"
         else nn.gelu_mlp(lp["mlp"], hn))
    return x + h, new_cache


def embed_inputs(cfg, params, batch):
    if cfg.takes_embeddings and "embeds" in batch:
        return batch["embeds"].astype(cfg.dtype)
    return nn.embed(params["embed"], batch["tokens"], cfg.dtype)


def apply_layer_range(cfg, stacked_slice, x, *, window=None, remat=None):
    """Apply a contiguous slice of stacked layer params (Hydra shard unit)."""
    remat = cfg.remat if remat is None else remat
    fn = partial(apply_layer, cfg, window=window)
    if remat:
        fn = jax.checkpoint(fn)

    def body(h, lp):
        return constrain_batch(fn(lp, h)), None

    out, _ = jax.lax.scan(body, constrain_batch(x), stacked_slice)
    return out


def forward(cfg, params, batch, *, window=None, last_only=False):
    """Full forward to logits. batch: {"tokens": (b,s)} or {"embeds": ...}.

    ``last_only``: unembed only the final position (prefill serving) — the
    (b, s, V) logits tensor is never materialized."""
    x = embed_inputs(cfg, params, batch)
    x = apply_layer_range(cfg, params["layers"], x, window=window)
    if last_only:
        x = x[:, -1:]
    x = _norm(cfg, params["final_norm"], x)
    return nn.unembed(params["embed"], x)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg, batch: int, max_seq: int):
    return {"kv": nn.init_kv_cache(cfg, batch, max_seq)}


def paged_decode_step(cfg, params, pages, tables, lengths, tokens, *,
                      window=None, impl="jnp"):
    """One decode step over a paged KV cache shared by all lanes.

    tokens: (n, 1); pages: {"k","v"} of (L, P, bs, nkv, hd) — plus per-row
    {"k_scale","v_scale"} planes of (L, P, bs, nkv) when the pool is
    int8-quantized; tables: (n, B) physical block ids per lane; lengths:
    (n,) rows already written (this token's row index).  Batched over
    lanes rather than vmapped — the pages are shared state, so the
    per-lane programs are not independent — with the per-layer page
    pytree scanned exactly like ``decode_step`` scans the contiguous
    cache.  ``impl='fused'``/``'fused_interpret'`` runs the whole block
    through ``kernels.fused_decode`` when the config qualifies (RMSNorm +
    SwiGLU, fp pool); other configs quietly take the equivalent unfused
    Pallas path.  Returns (logits (n, 1, V), new pages).
    """
    x = nn.embed(params["embed"], tokens, cfg.dtype)
    win = window if window is not None else cfg.window
    fused = (impl in ("fused", "fused_interpret") and cfg.norm == "rms"
             and cfg.mlp == "swiglu" and "k_scale" not in pages)

    def body(h, xs):
        lp, pg = xs
        if fused:
            return nn.paged_decode_layer_fused(
                lp, h, cfg, pages=pg, tables=tables, lengths=lengths,
                window=win, interpret=(impl == "fused_interpret"))
        a, npg = nn.paged_attention_decode(
            lp["attn"], _norm(cfg, lp["attn_norm"], h), cfg,
            pages=pg, tables=tables, lengths=lengths,
            window=win, impl=impl)
        h = h + a
        hn = _norm(cfg, lp["mlp_norm"], h)
        m = (nn.swiglu(lp["mlp"], hn) if cfg.mlp == "swiglu"
             else nn.gelu_mlp(lp["mlp"], hn))
        return h + m, npg

    x, new_pages = jax.lax.scan(body, x, (params["layers"], pages))
    x = _norm(cfg, params["final_norm"], x)
    return nn.unembed(params["embed"], x), new_pages


def decode_step(cfg, params, state, tokens, *, window=None):
    """One decode step: tokens (b, 1) -> logits (b, 1, V), new state."""
    x = nn.embed(params["embed"], tokens, cfg.dtype)
    kv = state["kv"]

    def body(h, xs):
        lp, k_l, v_l = xs
        cache = {"k": k_l, "v": v_l, "index": kv["index"]}
        h, nc = apply_layer_decode(cfg, lp, h, cache, window=window)
        return constrain_batch(h), (nc["k"], nc["v"])

    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], kv["k"], kv["v"]))
    x = _norm(cfg, params["final_norm"], x)
    logits = nn.unembed(params["embed"], x)
    new_state = {"kv": {"k": nk, "v": nv, "index": kv["index"] + tokens.shape[1]}}
    return logits, new_state


# ---------------------------------------------------------------------------
# speculative verify (k tokens scored against cached state in one forward)
# ---------------------------------------------------------------------------

def verify_step(cfg, params, state, tokens, *, window=None):
    """Score k draft positions against the contiguous KV cache in ONE
    forward: tokens ``(b, k)`` (last committed token + k-1 drafts) ->
    ``(logits (b, k, V), new state)`` with the cache index advanced by k.

    This is exactly the batched-prefill mechanism pointed at mid-decode:
    the causal chunk mask keeps intra-chunk attention correct, so position
    ``i``'s logits equal what i single-token decode steps would produce.
    The caller rolls the state back past the accept point with
    ``rollback_decode_state`` — rejected rows are never read again (decode
    masks keys at ``kvpos > qpos``) and are overwritten as decode resumes.
    """
    return decode_step(cfg, params, state, tokens, window=window)


def rollback_decode_state(cfg, state, delta):
    """Rewind the cache write index by ``delta`` rows (per-batch array or
    scalar).  Rows past the rewound index are stale but invisible: decode
    attention masks ``kvpos > qpos`` and later writes overwrite in place."""
    kv = state["kv"]
    return {"kv": {"k": kv["k"], "v": kv["v"],
                   "index": kv["index"] - delta}}


def paged_verify_step(cfg, params, pages, tables, lengths, tokens, *,
                      window=None, impl="jnp"):
    """The paged twin of ``verify_step``: score k positions per lane
    through per-lane block tables.  tokens ``(n, k)``; returns
    ``(logits (n, k, V), new pages)``.  The caller owns rollback: advance
    ``lengths`` by only the accepted rows and free/rewind tail blocks —
    rows past a lane's length are masked to zero weight, so rejected
    draft rows never perturb later decode.  ``impl`` routes the per-lane
    attention: 'jnp' is the historical gathered path, 'pallas' the Mosaic
    multi-query kernel (`kernels/paged_verify.py`)."""
    x = nn.embed(params["embed"], tokens, cfg.dtype)

    def body(h, xs):
        lp, pg = xs
        a, npg = nn.paged_attention_verify(
            lp["attn"], _norm(cfg, lp["attn_norm"], h), cfg,
            pages=pg, tables=tables, lengths=lengths,
            window=window if window is not None else cfg.window, impl=impl)
        h = h + a
        hn = _norm(cfg, lp["mlp_norm"], h)
        m = (nn.swiglu(lp["mlp"], hn) if cfg.mlp == "swiglu"
             else nn.gelu_mlp(lp["mlp"], hn))
        return h + m, npg

    x, new_pages = jax.lax.scan(body, x, (params["layers"], pages))
    x = _norm(cfg, params["final_norm"], x)
    return nn.unembed(params["embed"], x), new_pages


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def _kv_state_bytes(cfg, batch: int, max_seq: int) -> int:
    """Analytic residency of the pure KV decode state: K + V planes of
    (L, b, s, n_kv, hd) in ``cfg.kv_cache_dtype`` plus the int32 write
    index — must agree with ``jax.eval_shape`` over ``init_decode_state``
    (tests/test_registry.py cross-checks)."""
    item = jnp.dtype(cfg.kv_cache_dtype).itemsize
    kv = 2 * cfg.n_layers * batch * max_seq * cfg.n_kv_heads \
        * cfg.head_dim * item
    return kv + jnp.dtype(jnp.int32).itemsize


def _kv_block_bytes(cfg, block_size: int, kv_dtype=None) -> int:
    """Analytic residency of ONE physical KV block across all layers.

    ``kv_dtype='int8'`` prices the quantized pool: one int8 byte per
    cache element plus a 4-byte f32 scale per (row, kv head) — the page
    layout ``models.api.init_kv_pages`` allocates."""
    rows = 2 * cfg.n_layers * block_size * cfg.n_kv_heads
    if kv_dtype == "int8":
        return rows * (cfg.head_dim + jnp.dtype(jnp.float32).itemsize)
    item = jnp.dtype(cfg.kv_cache_dtype).itemsize
    return rows * cfg.head_dim * item


def _register():
    import sys

    from repro.models import registry
    mod = sys.modules[__name__]
    for family, tokens_only in (("dense", True), ("vlm", False)):
        registry.register(registry.FamilySpec(
            family=family, module=mod,
            batched_prefill=True, padded_prefill=True, paging=True,
            pure_kv_state=True, servable=True, spec_draftable=True,
            kv_quant=True,
            token_stream_data=tokens_only,
            notes={} if tokens_only else {
                "token_stream_data": "VLM batches carry fused patch+text "
                                     "embeddings, not raw token streams"},
            decode_state_cost=_kv_state_bytes,
            kv_block_cost=_kv_block_bytes))


_register()
