from repro.models.api import (decode_step, forward, init_decode_state,
                              init_params, input_specs, make_dummy_batch,
                              param_count)

__all__ = ["init_params", "forward", "decode_step", "init_decode_state",
           "input_specs", "make_dummy_batch", "param_count"]
