"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block.

The shared transformer block's parameters are reused at every invocation
(every ``cfg.attn_every`` Mamba2 layers).  For Hydra this is the one
structural extension over the paper's queue-of-shards model: shared params
are pinned resident (they are small relative to the backbone) rather than
spilled — see DESIGN.md §4.

Scan layout: we scan over the Mamba2 stack with a static per-layer boolean
``use_attn`` flag; the shared block's params are closed over (not scanned),
so they appear exactly once in the lowered HLO.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import layers as nn
from repro.sharding.context import constrain_batch
from repro.models import ssm


def init_shared_attn(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": nn.init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "attn": nn.init_attention(k1, cfg),
        "mlp_norm": nn.init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "mlp": nn.init_swiglu(k2, cfg),
    }


def init_layer(key, cfg):
    return {
        "norm": nn.init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "mamba": ssm.init_mamba2(key, cfg),
    }


def init_params(cfg, key):
    ke, ka, kl = jax.random.split(key, 3)
    keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_layer(k, cfg))(keys)
    return {
        "embed": nn.init_embedding(ke, cfg.vocab_size, cfg.d_model,
                                   cfg.param_dtype),
        "layers": stacked,
        "shared_attn": init_shared_attn(ka, cfg),
        "final_norm": nn.init_rmsnorm(cfg.d_model, cfg.param_dtype),
    }


import numpy as np


def attn_flags(cfg) -> np.ndarray:
    """use_attn[i] — apply the shared block after mamba layer i (static)."""
    idx = np.arange(cfg.n_layers)
    return (idx % cfg.attn_every) == (cfg.attn_every - 1)


def apply_shared_attn(cfg, sp, x, *, window=None, kv_cache=None, positions=None):
    h, nc = nn.attention(sp["attn"], nn.rms_norm(sp["attn_norm"], x), cfg,
                         positions=positions, causal=True,
                         window=window, kv_cache=kv_cache,
                         impl=cfg.attn_impl)
    x = x + h
    x = x + nn.swiglu(sp["mlp"], nn.rms_norm(sp["mlp_norm"], x))
    return x, nc


def apply_layer(cfg, lp, x, shared, use_attn, *, window=None):
    xn = constrain_batch(nn.rms_norm(lp["norm"], x), seq_parallel=False)
    x = x + ssm.mamba2_forward(lp["mamba"], xn, cfg)
    x = jax.lax.cond(
        use_attn,
        lambda h: apply_shared_attn(cfg, shared, h, window=window)[0],
        lambda h: h, x)
    return x


def apply_layer_range(cfg, stacked_slice, x, shared, flags_slice, *,
                      window=None, remat=None):
    remat = cfg.remat if remat is None else remat
    fn = partial(apply_layer, cfg, window=window)
    if remat:
        fn = jax.checkpoint(fn, static_argnums=())

    def body(h, xs):
        lp, flag = xs
        return constrain_batch(fn(lp, h, shared, flag)), None

    out, _ = jax.lax.scan(body, x, (stacked_slice, flags_slice))
    return out


def forward(cfg, params, batch, *, window=None, last_only=False):
    x = nn.embed(params["embed"], batch["tokens"], cfg.dtype)
    x = apply_layer_range(cfg, params["layers"], x, params["shared_attn"],
                          attn_flags(cfg), window=window)
    if last_only:
        x = x[:, -1:]
    x = nn.rms_norm(params["final_norm"], x)
    return nn.unembed(params["embed"], x)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def n_attn_invocations(cfg) -> int:
    return int(attn_flags(cfg).sum())


def init_decode_state(cfg, batch: int, max_seq: int):
    A = n_attn_invocations(cfg)

    def per_layer(_):
        return ssm.init_mamba2_state(cfg, batch)

    return {
        "mamba": jax.vmap(per_layer)(jnp.arange(cfg.n_layers)),
        "kv": nn.init_kv_cache(cfg, batch, max_seq, n_layers=A),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(cfg, params, state, tokens, *, window=None):
    """tokens: (b, 1). Shared attn keeps one KV cache per invocation site."""
    b = tokens.shape[0]
    x = nn.embed(params["embed"], tokens[:, 0], cfg.dtype)
    flags = attn_flags(cfg)
    # map layer index -> kv slot (prefix count of flags)
    slot_for_layer = jnp.cumsum(flags.astype(jnp.int32)) - 1
    kv = state["kv"]
    pos = state["pos"]

    def body(carry, xs):
        h, ck, cv = carry
        lp, ms, flag, slot = xs
        y, new_ms = ssm.mamba2_step(lp["mamba"],
                                    nn.rms_norm(lp["norm"], h), ms, cfg)
        h = h + y

        def with_attn(h):
            cache = {"k": ck[slot], "v": cv[slot], "index": pos}
            positions = jnp.broadcast_to(pos[None, None], (b, 1))
            h2, nc = apply_shared_attn(cfg, params["shared_attn"], h[:, None],
                                       window=window, kv_cache=cache,
                                       positions=positions)
            return h2[:, 0], ck.at[slot].set(nc["k"]), cv.at[slot].set(nc["v"])

        h, ck, cv = jax.lax.cond(flag, with_attn,
                                 lambda h: (h, ck, cv), h)
        return (h, ck, cv), new_ms

    (x, nk, nv), new_mamba = jax.lax.scan(
        body, (x, kv["k"], kv["v"]),
        (params["layers"], state["mamba"], flags, slot_for_layer))
    x = nn.rms_norm(params["final_norm"], x)
    logits = nn.unembed(params["embed"], x[:, None, :])
    new_state = {"mamba": new_mamba,
                 "kv": {"k": nk, "v": nv, "index": kv["index"] + 1},
                 "pos": pos + 1}
    return logits, new_state


def _register():
    import sys

    from repro.models import registry
    registry.register(registry.FamilySpec(
        family="hybrid", module=sys.modules[__name__],
        batched_prefill=False, padded_prefill=False, paging=False,
        pure_kv_state=False, servable=True, token_stream_data=True,
        notes={
            "batched_prefill": "mamba recurrences advance strictly "
                               "token-by-token (prefill scans the prompt)",
            "padded_prefill": "recurrent sub-states cannot be rewound past "
                              "a pad tail",
            "paging": "decode state mixes O(1) recurrences with the shared-"
                      "attention KV slots — not a pure pageable KV cache",
            "pure_kv_state": "decode state mixes mamba recurrences with a "
                             "KV cache",
            "spec_draftable": "mamba sub-states cannot be rolled back past "
                              "rejected draft tokens",
        }))


_register()
