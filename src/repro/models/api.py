"""Unified model API: dispatch on ``cfg.family`` through the FamilySpec
registry (``repro.models.registry``).

Every family module exposes the same surface:
    init_params(cfg, key) -> params
    forward(cfg, params, batch, *, window=None) -> logits [(b, s, V)]
    init_decode_state(cfg, batch, max_seq) -> state
    decode_step(cfg, params, state, tokens) -> (logits, state)
    apply_layer_range(cfg, stacked_slice, x, ...)   (Hydra shard primitive)

and registers a ``FamilySpec`` declaring its capabilities
(``batched_prefill`` / ``padded_prefill`` / ``paging`` / ...) and decode-
state cost fns.  This module is a thin lookup over that registry; callers
that need a capability decision read ``family_spec(cfg)`` instead of
testing family names.

``input_specs`` builds ShapeDtypeStruct stand-ins for the dry-run — weak-type
correct, shardable, zero allocation.
"""

from __future__ import annotations

import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import registry
from repro.models.registry import FamilySpec  # noqa: F401  (re-export)


def family_spec(cfg) -> registry.FamilySpec:
    """The registered FamilySpec for ``cfg`` (or a family name)."""
    return registry.spec(cfg)


def family_module(cfg):
    return registry.spec(cfg).module


def init_params(cfg, key):
    return family_module(cfg).init_params(cfg, key)


def forward(cfg, params, batch, *, window: Optional[int] = None,
            last_only: bool = False):
    return family_module(cfg).forward(cfg, params, batch, window=window,
                                      last_only=last_only)


def init_decode_state(cfg, batch: int, max_seq: int):
    return family_module(cfg).init_decode_state(cfg, batch, max_seq)


def decode_step(cfg, params, state, tokens, *, window: Optional[int] = None):
    return family_module(cfg).decode_step(cfg, params, state, tokens,
                                          window=window)


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# serving helpers (capability decisions live in the FamilySpec registry)
# ---------------------------------------------------------------------------

def init_kv_pages(cfg, n_blocks: int, block_size: int, kv_dtype=None):
    """Physical KV block pool: {"k","v"} of (L, n_blocks, block_size,
    n_kv_heads, head_dim) in ``cfg.kv_cache_dtype`` — the same layout as
    ``init_kv_cache`` with the block axis where batch was, so one page
    plane per layer scans exactly like the contiguous cache.

    ``kv_dtype='int8'`` allocates the quantized pool instead: int8 pages
    plus per-row f32 {"k_scale","v_scale"} planes of (L, n_blocks,
    block_size, n_kv_heads) — rows are quantized on write
    (``kernels.ref.quantize_kv``) and dequantized inside the attention
    kernel, so the f32 cache never exists."""
    from repro.models import layers as nn
    if kv_dtype not in (None, "fp", "int8"):
        raise ValueError(f"kv_dtype={kv_dtype!r}: expected None, 'fp', "
                         "or 'int8'")
    if kv_dtype == "int8":
        spec = registry.spec(cfg)
        if not spec.kv_quant:
            raise ValueError(f"{cfg.name} ({cfg.family}): "
                             f"{spec.why_not('kv_quant')}")
        shape = (cfg.n_layers, n_blocks, block_size, cfg.n_kv_heads,
                 cfg.head_dim)
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:-1], jnp.float32),
                "v_scale": jnp.zeros(shape[:-1], jnp.float32)}
    pages = nn.init_kv_cache(cfg, n_blocks, block_size)
    return {"k": pages["k"], "v": pages["v"]}


def kv_block_bytes(cfg, block_size: int, kv_dtype=None) -> int:
    """Residency cost of ONE physical block across all layers (K and V,
    plus scale planes for int8 pools) — the unit page-granular admission
    charges against the device ledger."""
    return registry.spec(cfg).kv_block_bytes(cfg, block_size, kv_dtype)


def paged_decode_step(cfg, params, pages, tables, lengths, tokens, *,
                      window: Optional[int] = None, impl: str = "jnp"):
    """One decode step reading K/V through per-lane block tables."""
    spec = registry.spec(cfg)
    if not spec.paging:
        raise ValueError(
            f"{cfg.name} ({cfg.family}): {spec.why_not('paging')}; serve "
            "this family through the slot backend instead")
    return spec.module.paged_decode_step(
        cfg, params, pages, tables, lengths, tokens,
        window=window, impl=impl)


def _require_spec_draftable(cfg) -> registry.FamilySpec:
    spec = registry.spec(cfg)
    if not spec.spec_draftable:
        raise ValueError(
            f"{cfg.name} ({cfg.family}): {spec.why_not('spec_draftable')}; "
            "serve this family without speculative decoding")
    return spec


def verify_step(cfg, params, state, tokens, *, window: Optional[int] = None):
    """Multi-token speculative verify: score k draft positions against the
    contiguous decode cache in ONE forward.  tokens ``(b, k)`` -> ``(logits
    (b, k, V), new state)`` with the cache advanced k rows; the caller
    rolls back past the accept point (``rollback_decode_state``)."""
    spec = _require_spec_draftable(cfg)
    return spec.module.verify_step(cfg, params, state, tokens,
                                   window=window)


def rollback_decode_state(cfg, state, delta):
    """Rewind a decode state's write index by ``delta`` rows (scalar or
    per-batch) — the KV-rollback half of speculative decoding."""
    spec = _require_spec_draftable(cfg)
    return spec.module.rollback_decode_state(cfg, state, delta)


def paged_verify_step(cfg, params, pages, tables, lengths, tokens, *,
                      window: Optional[int] = None, impl: str = "jnp"):
    """Speculative verify reading K/V through per-lane block tables:
    tokens ``(n, k)`` -> ``(logits (n, k, V), new pages)``."""
    spec = _require_spec_draftable(cfg)
    if not spec.paging:
        raise ValueError(
            f"{cfg.name} ({cfg.family}): {spec.why_not('paging')}; verify "
            "through the slot backend instead")
    return spec.module.paged_verify_step(
        cfg, params, pages, tables, lengths, tokens, window=window,
        impl=impl)


def decode_state_spec(cfg, batch: int, max_seq: int):
    """ShapeDtypeStruct tree of the decode state — zero allocation."""
    return jax.eval_shape(lambda: init_decode_state(cfg, batch, max_seq))


def decode_state_bytes(cfg, batch: int, max_seq: int) -> int:
    """Residency cost of one decode state (KV-budget admission control)."""
    return registry.spec(cfg).decode_state_bytes(cfg, batch, max_seq)


# ---------------------------------------------------------------------------
# deprecated predicate shims (the registry replaced the predicate zoo)
# ---------------------------------------------------------------------------

def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"repro.models.api.{old} is deprecated: capability decisions now "
        f"live in the FamilySpec registry; use {new} "
        "(see docs/api.md#backends--capabilities)",
        DeprecationWarning, stacklevel=3)


def is_attention_family(cfg) -> bool:
    """Deprecated: use ``family_spec(cfg).batched_prefill``."""
    _deprecated("is_attention_family", "family_spec(cfg).batched_prefill")
    return registry.spec(cfg).batched_prefill


def supports_padded_prefill(cfg) -> bool:
    """Deprecated: use ``family_spec(cfg).padded_prefill``."""
    _deprecated("supports_padded_prefill", "family_spec(cfg).padded_prefill")
    return registry.spec(cfg).padded_prefill


def supports_paging(cfg) -> bool:
    """Deprecated: use ``family_spec(cfg).paging``."""
    _deprecated("supports_paging", "family_spec(cfg).paging")
    return registry.spec(cfg).paging


def __getattr__(name: str):
    # PEP 562 shims: the old capability tuples are now registry queries
    if name == "ATTENTION_FAMILIES":
        _deprecated("ATTENTION_FAMILIES",
                    "registry.families_with('batched_prefill')")
        return registry.families_with("batched_prefill")
    if name == "PAGED_FAMILIES":
        _deprecated("PAGED_FAMILIES", "registry.families_with('paging')")
        return registry.families_with("paging")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg, shape, *, kind: Optional[str] = None) -> dict[str, Any]:
    """ShapeDtypeStruct inputs for (arch, input-shape).

    kind 'train'/'prefill' -> full-sequence batch; 'decode' -> one token.
    """
    kind = kind or shape.kind
    b, s = shape.global_batch, shape.seq_len
    if kind == "decode":
        return {"tokens": _sds((b, 1), jnp.int32)}
    batch: dict[str, Any] = {}
    if cfg.family == "audio":
        batch["enc_embeds"] = _sds((b, cfg.encoder_len, cfg.d_model),
                                   jnp.bfloat16)
        batch["tokens"] = _sds((b, s), jnp.int32)
        batch["labels"] = _sds((b, s), jnp.int32)
    elif cfg.takes_embeddings:
        # VLM: frontend stub emits fused patch+text embeddings
        batch["embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
        batch["labels"] = _sds((b, s), jnp.int32)
    else:
        batch["tokens"] = _sds((b, s), jnp.int32)
        batch["labels"] = _sds((b, s), jnp.int32)
    return batch


def make_dummy_batch(cfg, batch_size: int, seq_len: int, key=None):
    """Concrete random batch matching input_specs (smoke tests / examples)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    out: dict[str, Any] = {}
    if cfg.family == "audio":
        out["enc_embeds"] = jax.random.normal(
            k1, (batch_size, cfg.encoder_len, cfg.d_model), jnp.bfloat16)
        out["tokens"] = jax.random.randint(
            k2, (batch_size, seq_len), 0, cfg.vocab_size, jnp.int32)
        out["labels"] = jax.random.randint(
            k3, (batch_size, seq_len), 0, cfg.vocab_size, jnp.int32)
    elif cfg.takes_embeddings:
        out["embeds"] = jax.random.normal(
            k1, (batch_size, seq_len, cfg.d_model), jnp.bfloat16)
        out["labels"] = jax.random.randint(
            k3, (batch_size, seq_len), 0, cfg.vocab_size, jnp.int32)
    else:
        out["tokens"] = jax.random.randint(
            k2, (batch_size, seq_len), 0, cfg.vocab_size, jnp.int32)
        out["labels"] = jax.random.randint(
            k3, (batch_size, seq_len), 0, cfg.vocab_size, jnp.int32)
    return out
