"""Unified model API: dispatch on ``cfg.family``.

Every family module exposes the same surface:
    init_params(cfg, key) -> params
    forward(cfg, params, batch, *, window=None) -> logits [(b, s, V)]
    init_decode_state(cfg, batch, max_seq) -> state
    decode_step(cfg, params, state, tokens) -> (logits, state)
    apply_layer_range(cfg, stacked_slice, x, ...)   (Hydra shard primitive)

``input_specs`` builds ShapeDtypeStruct stand-ins for the dry-run — weak-type
correct, shardable, zero allocation.
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, moe, ssm, transformer


def family_module(cfg):
    return {
        "dense": transformer,
        "vlm": transformer,
        "moe": moe,
        "ssm": ssm,
        "hybrid": hybrid,
        "audio": encdec,
    }[cfg.family]


def init_params(cfg, key):
    return family_module(cfg).init_params(cfg, key)


def forward(cfg, params, batch, *, window: Optional[int] = None,
            last_only: bool = False):
    return family_module(cfg).forward(cfg, params, batch, window=window,
                                      last_only=last_only)


def init_decode_state(cfg, batch: int, max_seq: int):
    return family_module(cfg).init_decode_state(cfg, batch, max_seq)


def decode_step(cfg, params, state, tokens, *, window: Optional[int] = None):
    return family_module(cfg).decode_step(cfg, params, state, tokens,
                                          window=window)


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# serving helpers
# ---------------------------------------------------------------------------

ATTENTION_FAMILIES = ("dense", "vlm", "moe")


def is_attention_family(cfg) -> bool:
    """True when decode state is a pure KV cache that an entire prompt chunk
    can be written into in one ``decode_step`` call (batched prefill).
    Recurrent/hybrid/enc-dec states advance strictly token-by-token."""
    return cfg.family in ATTENTION_FAMILIES


def supports_padded_prefill(cfg) -> bool:
    """True when a right-padded prompt prefills token-identically to the
    exact-length one (length-bucketed admission).  Needs a rewindable KV
    cache AND per-token-independent mixing: capacity-bounded MoE routing
    couples tokens — pad tokens consume expert capacity and displace real
    tokens' routes — so only the non-MoE attention families qualify."""
    return is_attention_family(cfg) and cfg.family != "moe"


PAGED_FAMILIES = ("dense", "vlm")


def supports_paging(cfg) -> bool:
    """True when decode state can live in a block-granular paged KV cache.

    Needs (a) a pure KV-cache decode state — recurrent/hybrid states are
    O(1) in sequence length, so there is nothing to page — and (b) lanes
    that decode independently when batched: capacity-bounded MoE routing
    couples lanes (expert capacity is a function of the token batch), so
    a batched paged step would not be token-identical to per-lane decode.
    """
    return cfg.family in PAGED_FAMILIES


def init_kv_pages(cfg, n_blocks: int, block_size: int):
    """Physical KV block pool: {"k","v"} of (L, n_blocks, block_size,
    n_kv_heads, head_dim) in ``cfg.kv_cache_dtype`` — the same layout as
    ``init_kv_cache`` with the block axis where batch was, so one page
    plane per layer scans exactly like the contiguous cache."""
    from repro.models import layers as nn
    pages = nn.init_kv_cache(cfg, n_blocks, block_size)
    return {"k": pages["k"], "v": pages["v"]}


def kv_block_bytes(cfg, block_size: int) -> int:
    """Residency cost of ONE physical block across all layers (K and V) —
    the unit page-granular admission charges against the device ledger."""
    spec = jax.eval_shape(lambda: init_kv_pages(cfg, 1, block_size))
    return sum(math.prod(x.shape) * x.dtype.itemsize
               for x in jax.tree.leaves(spec))


def paged_decode_step(cfg, params, pages, tables, lengths, tokens, *,
                      window: Optional[int] = None, impl: str = "jnp"):
    """One decode step reading K/V through per-lane block tables."""
    if not supports_paging(cfg):
        raise ValueError(
            f"{cfg.name} ({cfg.family}): paging needs a pure KV-cache "
            "decode state and lane-independent mixing; serve this family "
            "through the slot pool instead")
    return family_module(cfg).paged_decode_step(
        cfg, params, pages, tables, lengths, tokens,
        window=window, impl=impl)


def decode_state_spec(cfg, batch: int, max_seq: int):
    """ShapeDtypeStruct tree of the decode state — zero allocation."""
    return jax.eval_shape(lambda: init_decode_state(cfg, batch, max_seq))


def decode_state_bytes(cfg, batch: int, max_seq: int) -> int:
    """Residency cost of one decode state (KV-budget admission control)."""
    spec = decode_state_spec(cfg, batch, max_seq)
    return sum(math.prod(x.shape) * x.dtype.itemsize
               for x in jax.tree.leaves(spec))


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg, shape, *, kind: Optional[str] = None) -> dict[str, Any]:
    """ShapeDtypeStruct inputs for (arch, input-shape).

    kind 'train'/'prefill' -> full-sequence batch; 'decode' -> one token.
    """
    kind = kind or shape.kind
    b, s = shape.global_batch, shape.seq_len
    if kind == "decode":
        return {"tokens": _sds((b, 1), jnp.int32)}
    batch: dict[str, Any] = {}
    if cfg.family == "audio":
        batch["enc_embeds"] = _sds((b, cfg.encoder_len, cfg.d_model),
                                   jnp.bfloat16)
        batch["tokens"] = _sds((b, s), jnp.int32)
        batch["labels"] = _sds((b, s), jnp.int32)
    elif cfg.takes_embeddings:
        # VLM: frontend stub emits fused patch+text embeddings
        batch["embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
        batch["labels"] = _sds((b, s), jnp.int32)
    else:
        batch["tokens"] = _sds((b, s), jnp.int32)
        batch["labels"] = _sds((b, s), jnp.int32)
    return batch


def make_dummy_batch(cfg, batch_size: int, seq_len: int, key=None):
    """Concrete random batch matching input_specs (smoke tests / examples)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    out: dict[str, Any] = {}
    if cfg.family == "audio":
        out["enc_embeds"] = jax.random.normal(
            k1, (batch_size, cfg.encoder_len, cfg.d_model), jnp.bfloat16)
        out["tokens"] = jax.random.randint(
            k2, (batch_size, seq_len), 0, cfg.vocab_size, jnp.int32)
        out["labels"] = jax.random.randint(
            k3, (batch_size, seq_len), 0, cfg.vocab_size, jnp.int32)
    elif cfg.takes_embeddings:
        out["embeds"] = jax.random.normal(
            k1, (batch_size, seq_len, cfg.d_model), jnp.bfloat16)
        out["labels"] = jax.random.randint(
            k3, (batch_size, seq_len), 0, cfg.vocab_size, jnp.int32)
    else:
        out["tokens"] = jax.random.randint(
            k2, (batch_size, seq_len), 0, cfg.vocab_size, jnp.int32)
        out["labels"] = jax.random.randint(
            k3, (batch_size, seq_len), 0, cfg.vocab_size, jnp.int32)
    return out
