"""Whisper-class encoder-decoder transformer.

The mel-spectrogram + conv frontend is a stub per the assignment carve-out:
``input_specs`` feeds precomputed frame embeddings ``(b, encoder_len, d)``.
Encoder: bidirectional self-attention; decoder: causal self-attention +
cross-attention to the encoder output.  LayerNorm + GELU (Whisper style),
learned positions, no RoPE.

For Hydra, the model is one queue: [embed, enc_0..enc_{E-1}, dec_0..dec_{D-1},
head] — the encoder output is a boundary intermediate checkpointed between
shard units like any other.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as nn
from repro.sharding.context import constrain_batch


def init_enc_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": nn.init_layernorm(cfg.d_model, cfg.param_dtype),
        "attn": nn.init_attention(k1, cfg),
        "mlp_norm": nn.init_layernorm(cfg.d_model, cfg.param_dtype),
        "mlp": nn.init_gelu_mlp(k2, cfg),
    }


def init_dec_layer(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "self_norm": nn.init_layernorm(cfg.d_model, cfg.param_dtype),
        "self_attn": nn.init_attention(k1, cfg),
        "cross_norm": nn.init_layernorm(cfg.d_model, cfg.param_dtype),
        "cross_attn": nn.init_attention(k2, cfg),
        "mlp_norm": nn.init_layernorm(cfg.d_model, cfg.param_dtype),
        "mlp": nn.init_gelu_mlp(k3, cfg),
    }


def init_params(cfg, key):
    ke, kp, kenc, kdec = jax.random.split(key, 4)
    enc_keys = jax.random.split(kenc, cfg.n_encoder_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": nn.init_embedding(ke, cfg.vocab_size, cfg.d_model,
                                   cfg.param_dtype),
        # learned decoder positions (Whisper trains 448; we cap the table at 8k
        # and clamp beyond — positions past the table reuse the last embedding)
        "dec_pos": nn.embed_init(kp, (8192, cfg.d_model), cfg.param_dtype),
        "encoder": jax.vmap(lambda k: init_enc_layer(k, cfg))(enc_keys),
        "enc_final_norm": nn.init_layernorm(cfg.d_model, cfg.param_dtype),
        "decoder": jax.vmap(lambda k: init_dec_layer(k, cfg))(dec_keys),
        "final_norm": nn.init_layernorm(cfg.d_model, cfg.param_dtype),
    }


def sinusoidal_positions(n: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(n)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    angle = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def apply_enc_layer(cfg, lp, x):
    xn = constrain_batch(nn.layer_norm(lp["attn_norm"], x),
                         seq_parallel=False)
    h, _ = nn.attention(lp["attn"], xn, cfg,
                        causal=False, rope=False, impl=cfg.attn_impl)
    x = x + h
    xn = constrain_batch(nn.layer_norm(lp["mlp_norm"], x),
                         seq_parallel=False)
    return x + nn.gelu_mlp(lp["mlp"], xn)


def apply_dec_layer(cfg, lp, x, enc_out, *, window=None):
    xn = constrain_batch(nn.layer_norm(lp["self_norm"], x),
                         seq_parallel=False)
    h, _ = nn.attention(lp["self_attn"], xn, cfg,
                        causal=True, rope=False, window=window,
                        impl=cfg.attn_impl)
    x = x + h
    xn = constrain_batch(nn.layer_norm(lp["cross_norm"], x),
                         seq_parallel=False)
    h, _ = nn.attention(lp["cross_attn"], xn, cfg,
                        xkv=enc_out, causal=False, rope=False)
    x = x + h
    xn = constrain_batch(nn.layer_norm(lp["mlp_norm"], x),
                         seq_parallel=False)
    return x + nn.gelu_mlp(lp["mlp"], xn)


def encode(cfg, params, frame_embeds):
    """frame_embeds: (b, encoder_len, d) from the (stubbed) conv frontend."""
    x = frame_embeds.astype(cfg.dtype)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(cfg.dtype)
    fn = jax.checkpoint(partial(apply_enc_layer, cfg)) if cfg.remat \
        else partial(apply_enc_layer, cfg)

    def body(h, lp):
        return constrain_batch(fn(lp, h)), None

    x, _ = jax.lax.scan(body, constrain_batch(x), params["encoder"])
    return nn.layer_norm(params["enc_final_norm"], x)


def decode_stack(cfg, params, tokens, enc_out, *, window=None, pos_offset=0):
    b, s = tokens.shape
    x = nn.embed(params["embed"], tokens, cfg.dtype)
    # positions beyond the learned table clamp to its last entry
    idx = jnp.clip(pos_offset + jnp.arange(s), 0,
                   params["dec_pos"].shape[0] - 1)
    x = x + params["dec_pos"][idx].astype(cfg.dtype)[None]
    fn = jax.checkpoint(partial(apply_dec_layer, cfg, window=window)) \
        if cfg.remat else partial(apply_dec_layer, cfg, window=window)

    def body(h, lp):
        return constrain_batch(fn(lp, h, enc_out)), None

    x, _ = jax.lax.scan(body, constrain_batch(x), params["decoder"])
    return nn.layer_norm(params["final_norm"], x)


def forward(cfg, params, batch, *, window=None, last_only=False):
    """batch: {"enc_embeds": (b, F, d), "tokens": (b, s)} -> logits."""
    enc_out = encode(cfg, params, batch["enc_embeds"])
    x = decode_stack(cfg, params, batch["tokens"], enc_out, window=window)
    if last_only:
        x = x[:, -1:]
    return nn.unembed(params["embed"], x)


# ---------------------------------------------------------------------------
# decode (serve): cached self-attn KV + precomputed cross-attn KV
# ---------------------------------------------------------------------------

def init_decode_state(cfg, batch: int, max_seq: int, enc_out=None, params=None):
    D = cfg.n_layers
    state = {"kv": nn.init_kv_cache(cfg, batch, max_seq, n_layers=D)}
    if enc_out is not None:
        state["cross"] = precompute_cross_kv(cfg, params, enc_out)
    else:
        F = cfg.encoder_len
        shape = (D, batch, F, cfg.n_kv_heads, cfg.head_dim)
        state["cross"] = {"k": jnp.zeros(shape, jnp.bfloat16),
                          "v": jnp.zeros(shape, jnp.bfloat16)}
    return state


def precompute_cross_kv(cfg, params, enc_out):
    def per_layer(lp):
        _, k, v = nn._project_qkv(lp["cross_attn"], enc_out, enc_out, cfg)
        return k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)

    k, v = jax.vmap(per_layer)(params["decoder"])
    return {"k": k, "v": v}


def decode_step(cfg, params, state, tokens, *, window=None):
    """One decoder token. tokens: (b, 1)."""
    kv = state["kv"]
    idx = kv["index"]
    b = tokens.shape[0]
    x = nn.embed(params["embed"], tokens, cfg.dtype)
    pos = params["dec_pos"][jnp.clip(idx, 0, params["dec_pos"].shape[0] - 1)]
    x = x + pos.astype(cfg.dtype)[None, None]

    def body(h, xs):
        lp, k_l, v_l, ck_l, cv_l = xs
        cache = {"k": k_l, "v": v_l, "index": idx}
        positions = jnp.broadcast_to(idx[None, None], (b, 1))
        a, nc = nn.attention(lp["self_attn"],
                             nn.layer_norm(lp["self_norm"], h), cfg,
                             positions=positions, causal=True, rope=False,
                             window=window, kv_cache=cache)
        h = h + a
        a, _ = nn.attention(lp["cross_attn"],
                            nn.layer_norm(lp["cross_norm"], h), cfg,
                            xkv=h,  # ignored: cache supplies enc K/V
                            causal=False, rope=False,
                            kv_cache={"k": ck_l, "v": cv_l, "index": idx})
        h = h + a
        return h + nn.gelu_mlp(lp["mlp"], nn.layer_norm(lp["mlp_norm"], h)), \
            (nc["k"], nc["v"])

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["decoder"], kv["k"], kv["v"],
                  state["cross"]["k"], state["cross"]["v"]))
    x = nn.layer_norm(params["final_norm"], x)
    logits = nn.unembed(params["embed"], x)
    new_state = {"kv": {"k": nk, "v": nv, "index": idx + 1},
                 "cross": state["cross"]}
    return logits, new_state


def _register():
    import sys

    from repro.models import registry
    registry.register(registry.FamilySpec(
        family="audio", module=sys.modules[__name__],
        batched_prefill=False, padded_prefill=False, paging=False,
        pure_kv_state=False, servable=False, token_stream_data=False,
        notes={
            "servable": "encoder-decoder decode states need real encoder "
                        "output; InferenceEngine has no encoder-output "
                        "path yet",
            "batched_prefill": "decoder states advance token-by-token "
                               "against the cross-attention cache",
            "padded_prefill": "decoder prefill cannot be rewound past a "
                              "pad tail",
            "paging": "cross-attention cache is request-constant — paging "
                      "the self-attention half alone buys nothing",
            "pure_kv_state": "decode state couples self- and cross-"
                             "attention caches",
            "token_stream_data": "audio batches carry encoder frame "
                                 "embeddings alongside tokens",
            "spec_draftable": "not servable through InferenceEngine, so "
                              "there is no decode path to speculate on",
        }))


_register()
