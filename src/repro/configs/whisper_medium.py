"""Whisper-medium — encoder-decoder; conv/mel frontend is a stub,
``input_specs`` feeds precomputed frame embeddings (1500 frames / 30 s).

[arXiv:2212.04356]
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=51865,
    is_encoder_decoder=True, n_encoder_layers=24, encoder_len=1500,
    norm="layer", mlp="gelu", mlp_bias=True, tie_embeddings=True,
    source="arXiv:2212.04356",
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, n_encoder_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
    head_dim=0, d_ff=512, vocab_size=512, encoder_len=64, max_seq_len=4096)

register(CONFIG, SMOKE_CONFIG)
