"""LLaVA-NeXT (Mistral-7B backbone) — VLM; anyres tiling frontend is a stub,
``input_specs`` feeds precomputed patch+text embeddings.

[hf:llava-hf/llava-v1.6-mistral-7b-hf]
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    takes_embeddings=True, rope_theta=1_000_000.0,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=0,
    d_ff=512, vocab_size=512, max_seq_len=4096)

register(CONFIG, SMOKE_CONFIG)
