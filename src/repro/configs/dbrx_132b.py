"""DBRX-132B — fine-grained MoE, 16 experts top-4, GQA.

[hf:databricks/dbrx-base]
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab_size=100352,
    n_experts=16, top_k=4, rope_theta=500_000.0,
    source="hf:databricks/dbrx-base",
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=0,
    d_ff=512, vocab_size=512, n_experts=4, top_k=2, max_seq_len=4096)

register(CONFIG, SMOKE_CONFIG)
