"""Command-R+ 104B — dense decoder, GQA, no biases.

[hf:CohereForAI/c4ai-command-r-v01]
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=33792, vocab_size=256000,
    rope_theta=75_000_000.0,
    source="hf:CohereForAI/c4ai-command-r-v01",
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=0,
    d_ff=512, vocab_size=512, max_seq_len=4096)

register(CONFIG, SMOKE_CONFIG)
