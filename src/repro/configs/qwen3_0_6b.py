"""Qwen3-0.6B — dense decoder, GQA (8 kv heads), QK-norm.

[hf:Qwen/Qwen3-8B family card, 0.6B variant per assignment]
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=3072, vocab_size=151936,
    qk_norm=True, head_dim=128, rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B",
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
    d_ff=512, vocab_size=512, max_seq_len=4096)

register(CONFIG, SMOKE_CONFIG)
