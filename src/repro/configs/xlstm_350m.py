"""xLSTM-350M — alternating sLSTM + mLSTM blocks (recurrent, O(1) decode state).

[arXiv:2405.04517]
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    slstm_ratio=2,           # 1 sLSTM per 2 blocks (alternating)
    ssm_expand=2, ssm_chunk=256,
    source="arXiv:2405.04517",
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=0,
    vocab_size=512, ssm_chunk=64, max_seq_len=4096)

register(CONFIG, SMOKE_CONFIG)
