"""Architecture config dataclass + input-shape registry.

Every assigned architecture gets one ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (full-scale, exercised via the dry-run only) and ``SMOKE_CONFIG``
(reduced: ≤2 layers, d_model ≤ 512, ≤4 experts — runs on CPU).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    source: str = ""                  # citation (paper / model card)

    # attention details
    causal: bool = True               # False for BERT/ViT-style encoders
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    window: Optional[int] = None      # native sliding window (Mixtral)
    long_context_window: int = 8192   # SWA fallback used only for long_500k
    attn_impl: str = "xla"            # xla | pallas | pallas_interpret

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM / recurrent
    ssm_state: int = 0                # Mamba2 state dim N
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_kernel: int = 4
    slstm_ratio: int = 0              # xLSTM: 1 sLSTM per this many blocks (0=off)

    # hybrid (zamba2-style)
    attn_every: int = 0               # shared attention block every k core layers

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_len: int = 1500           # 30 s of audio at 50 Hz after conv stub

    # modality frontends (stubs per spec)
    takes_embeddings: bool = False    # VLM: input_specs feeds patch+text embeds

    # norms / mlp family / misc
    norm: str = "rms"                 # rms | layer
    mlp: str = "swiglu"               # swiglu | gelu
    mlp_bias: bool = False
    tie_embeddings: bool = True
    max_seq_len: int = 524_288

    # precision
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    kv_cache_dtype: str = "bfloat16"   # bfloat16 | float8_e4m3fn (serving)

    # training
    remat: bool = True                # activation checkpoint each layer

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ---- derived quantities used by the partitioner / roofline ----------
    @property
    def attn_params(self) -> int:
        d, nh, nkv, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        return d * nh * hd + 2 * d * nkv * hd + nh * hd * d

    @property
    def mlp_params(self) -> int:
        mult = 3 if self.mlp == "swiglu" else 2
        return mult * self.d_model * self.d_ff

    @property
    def layer_params(self) -> int:
        if self.family == "moe":
            return self.attn_params + self.n_experts * self.mlp_params + \
                self.d_model * self.n_experts  # router
        if self.family == "ssm":
            d_in = self.d_model * self.ssm_expand
            return 2 * self.d_model * d_in + d_in * (2 * self.ssm_state + 2)
        return self.attn_params + self.mlp_params

    @property
    def n_params(self) -> int:
        emb = self.vocab_size * self.d_model
        body = self.n_layers * self.layer_params
        if self.is_encoder_decoder:
            body += self.n_encoder_layers * self.layer_params
        return emb * (1 if self.tie_embeddings else 2) + body

    @property
    def n_active_params(self) -> int:
        """Per-token active params (MoE counts top_k experts only)."""
        if self.family != "moe":
            return self.n_params
        dense_layer = self.attn_params + self.top_k * self.mlp_params
        return self.vocab_size * self.d_model + self.n_layers * dense_layer


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# registry filled in by repro.configs.__init__
ARCH_REGISTRY: dict[str, "ArchConfig"] = {}
SMOKE_REGISTRY: dict[str, "ArchConfig"] = {}


def register(cfg: ArchConfig, smoke: ArchConfig) -> None:
    ARCH_REGISTRY[cfg.name] = cfg
    SMOKE_REGISTRY[cfg.name] = smoke


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    import repro.configs  # noqa: F401  (triggers registration)
    reg = SMOKE_REGISTRY if smoke else ARCH_REGISTRY
    if name not in reg:
        raise KeyError(f"unknown arch {name!r}; have {sorted(reg)}")
    return reg[name]
