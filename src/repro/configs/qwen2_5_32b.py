"""Qwen2.5-32B — dense decoder, GQA (8 kv heads), QKV bias.

[hf:Qwen/Qwen2.5-0.5B family card, scaled per assignment]
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=27648, vocab_size=152064,
    qkv_bias=True, rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen2.5-0.5B",
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=0,
    d_ff=512, vocab_size=512, max_seq_len=4096)

register(CONFIG, SMOKE_CONFIG)
