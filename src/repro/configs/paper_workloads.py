"""The paper's own benchmark workloads (Table 2), expressed in this framework.

* BERT-Large*-1B on WikiText-2: hyper-parameter grid (batch × lr) = 12 models.
* ViT* 300M–2B on CIFAR-10: architecture grid × batch sizes = 12 models.

We model both as decoder-family configs of the right parameter count (the
paper itself uses "architectures similar to BERT-Large and ViT, scaled up").
Smoke variants are what the multi-model integration tests and benchmarks run
on CPU.
"""
from repro.configs.base import ArchConfig, register

# ~1B-param BERT-Large-like encoder (we train it with an MLM-style xent on
# full-sequence logits; attention non-causal).
BERT_LARGE_1B = ArchConfig(
    name="bert-large-1b", family="dense",
    n_layers=36, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab_size=30522,
    norm="layer", mlp="gelu", mlp_bias=True, qkv_bias=True, causal=False,
    source="paper Table 2 (BERT-Large*, 1B)",
)

BERT_SMOKE = BERT_LARGE_1B.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=0,
    d_ff=256, vocab_size=512, max_seq_len=512)

register(BERT_LARGE_1B, BERT_SMOKE)


def vit_like(n_params_m: int) -> ArchConfig:
    """ViT*-style config scaled to roughly n_params_m million params."""
    table = {
        300: (24, 1024, 16), 600: (32, 1280, 20), 800: (36, 1408, 22),
        1000: (40, 1536, 24), 1500: (48, 1664, 26), 2000: (48, 1920, 30),
    }
    L, d, h = table[n_params_m]
    return ArchConfig(
        name=f"vit-{n_params_m}m", family="vlm",
        n_layers=L, d_model=d, n_heads=h, n_kv_heads=h, head_dim=d // h,
        d_ff=4 * d, vocab_size=10,   # CIFAR-10 classes as a 10-way "vocab"
        takes_embeddings=True, causal=False,
        norm="layer", mlp="gelu", mlp_bias=True,
        source="paper Table 2 (ViT*, scaled)",
    )


VIT_SMOKE = ArchConfig(
    name="vit-smoke", family="vlm",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=10, takes_embeddings=True, causal=False,
    norm="layer", mlp="gelu", mlp_bias=True,
    source="paper Table 2 (ViT*, smoke)",
)
register(vit_like(300), VIT_SMOKE.replace(name="vit-300m"))
