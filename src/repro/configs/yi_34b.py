"""Yi-34B — llama-architecture dense decoder with GQA.

[arXiv:2403.04652]
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="yi-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab_size=64000,
    rope_theta=5_000_000.0,
    source="arXiv:2403.04652",
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=0,
    d_ff=512, vocab_size=512, max_seq_len=4096)

register(CONFIG, SMOKE_CONFIG)
