"""Mixtral-8x22B — MoE 8 experts top-2, GQA, sliding-window attention.

[arXiv:2401.04088]
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=32768,
    n_experts=8, top_k=2, window=4096, rope_theta=1_000_000.0,
    source="arXiv:2401.04088",
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=0,
    d_ff=512, vocab_size=512, n_experts=4, top_k=2, window=128,
    max_seq_len=4096)

register(CONFIG, SMOKE_CONFIG)
