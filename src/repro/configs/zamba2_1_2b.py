"""Zamba2-1.2B — hybrid: Mamba2 backbone + shared attention block.

[arXiv:2411.15242]
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_chunk=256,
    attn_every=6,            # shared attention block after every 6 Mamba2 layers
    source="arXiv:2411.15242",
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=4, d_model=256, n_heads=4, n_kv_heads=4, head_dim=0,
    d_ff=512, vocab_size=512, ssm_state=16, ssm_chunk=64, attn_every=2,
    max_seq_len=4096)

register(CONFIG, SMOKE_CONFIG)
