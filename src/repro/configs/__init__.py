"""Config registry — importing this package registers every architecture."""

from repro.configs.base import (ARCH_REGISTRY, INPUT_SHAPES, SMOKE_REGISTRY,
                                ArchConfig, InputShape, get_config)

# assigned architectures (registration side effects)
from repro.configs import qwen2_5_32b            # noqa: F401
from repro.configs import llava_next_mistral_7b  # noqa: F401
from repro.configs import qwen3_0_6b             # noqa: F401
from repro.configs import mixtral_8x22b          # noqa: F401
from repro.configs import dbrx_132b              # noqa: F401
from repro.configs import xlstm_350m             # noqa: F401
from repro.configs import yi_34b                 # noqa: F401
from repro.configs import command_r_plus_104b    # noqa: F401
from repro.configs import zamba2_1_2b            # noqa: F401
from repro.configs import whisper_medium         # noqa: F401
# the paper's own workloads
from repro.configs import paper_workloads        # noqa: F401

ASSIGNED_ARCHS = [
    "qwen2.5-32b", "llava-next-mistral-7b", "qwen3-0.6b", "mixtral-8x22b",
    "dbrx-132b", "xlstm-350m", "yi-34b", "command-r-plus-104b",
    "zamba2-1.2b", "whisper-medium",
]

__all__ = ["ArchConfig", "InputShape", "ARCH_REGISTRY", "SMOKE_REGISTRY",
           "INPUT_SHAPES", "ASSIGNED_ARCHS", "get_config"]
