"""Hydra's legacy user-facing API (paper Fig. 4):

    task_0 = ModelTask(cfg_0, dataloader_0, lr_0, epochs_0)
    task_1 = ModelTask(cfg_1, dataloader_1, lr_1, epochs_1)
    orchestra = ModelOrchestrator([task_0, task_1], hydra_cfg)
    report = orchestra.train_models()

Since the unified session API landed (``repro.api`` / docs/api.md), both
classes here are thin wrappers: ``ModelOrchestrator`` delegates to a
``Session`` holding one ``TrainJob`` per task, and ``SpilledInference``
mirrors what an ``EvalJob`` runs per batch.  The call signatures and
semantics are unchanged — partitioning (Algorithm 1), spilling, SHARP
scheduling (Sharded-LRTF), and double buffering all happen below the line.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import partitioner as pt
from repro.core import shard_graph as sg
from repro.core.sharp import HydraConfig, RunReport, ShardFunctions
from repro.core.spilling import HostModelStore
from repro.optim import optimizers as opt

_warned = False


def _deprecate_once(old: str, new: str) -> None:
    global _warned
    if _warned:
        return
    _warned = True
    warnings.warn(f"{old} is a compatibility shim over {new}; "
                  f"prefer {new} for new code (see docs/api.md)",
                  DeprecationWarning, stacklevel=3)


@dataclass
class ModelTask:
    """One model-selection candidate: architecture + data + hyperparams."""
    cfg: Any                                   # ArchConfig
    dataloader: Iterator[dict]
    lr: float = 1e-3
    epochs: int = 1
    steps_per_epoch: int = 4
    optimizer: str = "adamw"
    params: Optional[Any] = None               # init'd if None
    seed: int = 0
    batch: int = 2                              # partitioning pilot shape
    seq: int = 128
    # AutoML early stopping (Hyperband-class; paper §4.7.2's trigger for
    # LRTF's graceful case-1 -> case-2 degradation): called with the loss
    # history at each mini-batch boundary; return True to stop the model.
    early_stop: Optional[Callable[[list], bool]] = None

    def opt_config(self) -> opt.OptimizerConfig:
        # NOTE: per-shard stepping composes exactly with sequential training
        # only when gradient clipping is off (clipping needs the global norm,
        # which no single shard sees).  Hydra therefore disables it.
        return opt.OptimizerConfig(kind=self.optimizer, lr=self.lr,
                                   grad_clip=0.0)


class ModelOrchestrator:
    """Automated multi-model trainer — now a thin wrapper holding a
    ``repro.api.Session`` with one ``TrainJob`` per task.  ``models`` and
    the report shape are unchanged, so existing callers keep working."""

    def __init__(self, tasks: list[ModelTask],
                 hydra_cfg: Optional[HydraConfig] = None):
        from repro.api import Session, TrainJob
        _deprecate_once("ModelOrchestrator", "repro.api.Session")
        self.tasks = tasks
        self.session = Session(hydra_cfg)
        self.hc = self.session.hc
        for task in tasks:
            self.session.submit(TrainJob.from_task(task))
        # materialize eagerly: callers inspect .models before training
        self.models = self.session.train_execs

    def train_models(self, *, max_units: Optional[int] = None) -> RunReport:
        report = self.session.run(max_units=max_units)
        return report.train

    def model_params(self, model_id: int):
        return self.models[model_id].store.model_params()


# ---------------------------------------------------------------------------
# large-model inference via spilling (paper §6 "Large Model Inference":
# "model spilling, automated partitioning, and automated shard orchestration
# all suffice already for out-of-the-box large model inference")
# ---------------------------------------------------------------------------


def spilled_forward(store, fns, partition, batch, *, on_shard=None):
    """Forward-only shard queue: promote each shard, apply it, thread the
    boundary activation — shared by ``SpilledInference`` and the session
    API's ``EvalJob``.  Returns ``(logits, bytes_moved)``; ``on_shard``
    fires after each shard unit (the session ticks serve engines there)."""
    batch = jax.tree.map(jnp.asarray, batch)
    act: dict = {}
    moved = 0
    for shard in partition.shards:
        own, shared, _ = store.promote_shard(shard)
        moved += store.shard_transfer_bytes(shard, train=False)
        out, _ = fns.fwd(shard)(own, shared, act, batch)
        act = out
        if on_shard is not None:
            on_shard(shard)
    return act["logits"], moved


class SpilledInference:
    """Forward-only execution of a larger-than-device model through the
    shard queue: each shard's params are promoted, applied, and demoted —
    a model bounded only by host DRAM runs inference on one device.

        infer = SpilledInference(cfg, params, device_budget_bytes=...)
        logits = infer(batch)
    """

    def __init__(self, cfg, params, *, device_budget_bytes: int,
                 batch: int = 2, seq: int = 128,
                 buffer_frac: float = 0.05):
        from repro.models import api
        self.cfg = cfg
        self.plan = sg.build_plan(cfg)
        host = sg.prepare_host_params(cfg, jax.tree.map(np.array, params))
        self.partition = pt.partition(
            cfg, host, self.plan, budget_bytes=device_budget_bytes,
            batch=batch, seq=seq, buffer_frac=buffer_frac, train=False)
        # inference transfers exclude grads/optimizer state
        self.store = HostModelStore(cfg, self.plan, params,
                                    opt.OptimizerConfig(grad_clip=0.0),
                                    self.partition)
        self.fns = ShardFunctions(cfg, self.plan, self.partition,
                                  opt.OptimizerConfig(grad_clip=0.0))
        self.bytes_moved = 0

    @property
    def n_shards(self) -> int:
        return len(self.partition.shards)

    def __call__(self, batch):
        """batch -> logits, running the shard queue forward-only."""
        logits, moved = spilled_forward(self.store, self.fns,
                                        self.partition, batch)
        self.bytes_moved += moved
        return logits

    def loss(self, batch):
        logits = self(batch)
        from repro.training.losses import softmax_xent
        return softmax_xent(logits, batch["labels"])


# ---------------------------------------------------------------------------
# sequential reference (the "no effect on accuracy" oracle)
# ---------------------------------------------------------------------------

def train_sequential_reference(task: ModelTask) -> tuple[Any, list]:
    """Plain jit'd full-model training — Hydra must reproduce its losses."""
    from repro.models import api
    from repro.training import make_train_step
    cfg = task.cfg
    params = task.params if task.params is not None else \
        api.init_params(cfg, jax.random.PRNGKey(task.seed))
    ocfg = task.opt_config()
    state = opt.init_state(ocfg, params)
    step = jax.jit(make_train_step(cfg, ocfg))
    losses = []
    it = iter(task.dataloader)
    for _ in range(task.epochs * task.steps_per_epoch):
        batch = jax.tree.map(jnp.asarray, next(it))
        params, state, metrics = step(params, state, batch)
        losses.append(float(metrics["loss"]))
    return params, losses
