"""Baseline execution paradigms (paper §2.2, Fig 3, Fig 8 comparisons).

Each baseline consumes the same measured per-shard unit runtimes that SHARP
uses, and produces a virtual timeline (makespan + utilization).  This makes
the Fig-8-style comparisons *schedule* comparisons on identical compute —
exactly the quantity the paper varies — while real training still runs
through the Hydra executor.

* ``model_parallel``  — every model's shards statically placed across
  devices; sequential dependency means one active device at a time; models
  run one after another (PyTorch-Distributed MP baseline).
* ``pipeline``        — GPipe-style: mini-batch split into ``n_micro``
  micro-batches pipelined through the shard stages with a synchronous
  flush between forward and backward (fill/drain bubbles).
* ``task_parallel``   — whole models round-robin'd across devices; only
  valid when a model fits one device's memory (else raises, as the paper
  notes these systems crash).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class BaselineReport:
    makespan: float
    avg_utilization: float
    name: str


def _model_times(models) -> list[list[tuple[float, float]]]:
    """[(fwd, bwd)] per shard per model (from pilot measurements)."""
    return [[(s.fwd_runtime, s.bwd_runtime) for s in m.partition.shards]
            for m in models]


def model_parallel(models, n_devices: int, steps: list[int]) -> BaselineReport:
    """Strict inter-layer model parallelism, one model at a time."""
    total = 0.0
    busy = 0.0
    for m_idx, shards in enumerate(_model_times(models)):
        per_mb = sum(f + b for f, b in shards)
        total += per_mb * steps[m_idx]
        busy += per_mb * steps[m_idx]     # exactly one device active
    util = busy / (total * n_devices) if total else 0.0
    return BaselineReport(total, util, "model_parallel")


def pipeline(models, n_devices: int, steps: list[int],
             n_micro: int | None = None) -> BaselineReport:
    """GPipe-style synchronous pipeline, one model at a time.

    Stages = shards mapped round-robin onto devices; micro-batch count
    defaults to device count (the paper's GPipe configuration).  Bubble
    fraction per pass = (S-1)/(M+S-1) with S stages, M micro-batches.
    """
    total = 0.0
    busy = 0.0
    for m_idx, shards in enumerate(_model_times(models)):
        S = min(len(shards), n_devices)
        M = n_micro or n_devices
        fwd = sum(f for f, _ in shards)
        bwd = sum(b for _, b in shards)
        # standard GPipe fill-drain schedule: (M+S-1) stage slots per pass,
        # stage time = per-microbatch per-stage compute
        f_stage = fwd / S / M
        b_stage = bwd / S / M
        per_mb = (M + S - 1) * (f_stage + b_stage)
        total += per_mb * steps[m_idx]
        busy += (fwd + bwd) * steps[m_idx]
    util = busy / (total * n_devices) if total else 0.0
    return BaselineReport(total, util, "pipeline")


def task_parallel(models, n_devices: int, steps: list[int],
                  device_budget: int) -> BaselineReport:
    """Pure task parallelism (Cerebro-class). Crashes on big models."""
    from repro.core.partitioner import tree_bytes
    dev_loads = np.zeros(n_devices)
    for m_idx, m in enumerate(models):
        # whole model must fit: params + grads + Adam moments
        model_bytes = tree_bytes(m.store.params) * 4
        if model_bytes > device_budget:
            raise MemoryError(
                f"model {m_idx} ({model_bytes/1e9:.2f} GB with optimizer "
                f"state) exceeds a single device ({device_budget/1e9:.2f} GB)"
                " — task parallelism cannot train it (paper §2.2)")
        per_mb = sum(s.fwd_runtime + s.bwd_runtime
                     for s in m.partition.shards)
        dev_loads[np.argmin(dev_loads)] += per_mb * steps[m_idx]
    makespan = float(dev_loads.max())
    util = float(dev_loads.sum() / (makespan * n_devices)) if makespan else 0.0
    return BaselineReport(makespan, util, "task_parallel")
