"""Hydra core: the paper's primary contribution.

Spilling (§4.2) + automated partitioning (§4.3) + SHARP (§4.4) + shard
orchestration (§4.5) + double buffering (§4.6) + Sharded-LRTF (§4.7).
"""

from repro.core.orchestrator import (ModelOrchestrator, ModelTask,
                                     train_sequential_reference)
from repro.core.partitioner import PartitionResult, Shard, partition
from repro.core.scheduler import (ModelProgress, get_scheduler,
                                  greedy_list_makespan, optimal_makespan,
                                  sharded_lrtf)
from repro.core.shard_graph import Segment, ShardPlan, build_plan
from repro.core.sharp import (HydraConfig, RunReport, SharpExecutor,
                              UnitEvent)

__all__ = ["ModelTask", "ModelOrchestrator", "train_sequential_reference",
           "HydraConfig", "SharpExecutor", "RunReport", "UnitEvent",
           "partition", "PartitionResult", "Shard",
           "build_plan", "ShardPlan", "Segment",
           "sharded_lrtf", "get_scheduler", "optimal_makespan",
           "greedy_list_makespan", "ModelProgress"]
