"""Model spilling (paper §4.2): shard-granular promotion/demotion between
device memory and host DRAM, with byte accounting per virtual device.

On real TPU/GPU fleets promotion is ``jax.device_put`` into HBM and demotion
is a host fetch; on this CPU dev container the transfers are physically
host→host but the *mechanics* (buffer lifecycle, budget enforcement,
double-buffer reservations, byte/traffic accounting) are identical and fully
exercised.  The SHARP executor charges virtual transfer time =
bytes / ``link_bw`` against the device timeline.

Layout of the host store per model:
    params:      family host tree (numpy-backed, prepare_host_params applied)
    opt:         {shard_index: opt-state tree}  (own params)
    shared_opt:  {name: opt-state tree}         (shared params)
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import shard_graph as sg
from repro.core.partitioner import PartitionResult, Shard, tree_bytes


def to_host(tree):
    # np.array (copy) — np.asarray of a jax array is a read-only view
    return jax.tree.map(lambda a: np.array(a), tree)


def to_device(tree, device=None):
    if device is None:
        return jax.tree.map(jnp.asarray, tree)
    return jax.tree.map(lambda a: jax.device_put(a, device), tree)


@dataclass
class TransferStats:
    promoted_bytes: int = 0
    demoted_bytes: int = 0
    n_promotions: int = 0
    n_demotions: int = 0
    act_bytes_moved: int = 0

    def total_bytes(self) -> int:
        return self.promoted_bytes + self.demoted_bytes + self.act_bytes_moved


class HostModelStore:
    """DRAM-resident master copy of one model (params + optimizer state)."""

    def __init__(self, cfg, plan: sg.ShardPlan, params, opt_cfg,
                 partition: PartitionResult):
        from repro.optim import optimizers as opt
        self.cfg = cfg
        self.plan = plan
        self.partition = partition
        self.params = sg.prepare_host_params(cfg, to_host(params))
        self.opt_cfg = opt_cfg
        self.opt: dict[int, Any] = {}
        for shard in partition.shards:
            own = self._own_params(shard)
            self.opt[shard.index] = to_host(opt.init_state(opt_cfg, own))
        self.shared_opt = {
            name: to_host(opt.init_state(
                opt_cfg, sg.resolve_ref(self.params, ref)))
            for name, ref in plan.shared_refs.items()}
        # accumulated grads for shared params within the current mini-batch
        self.shared_grad_acc: dict[str, Any] = {}

    # -- own (spillable) ---------------------------------------------------
    def _own_params(self, shard: Shard):
        return tuple(sg.resolve_ref(self.params,
                                    self.plan.segments[i].param_ref)
                     for i in range(shard.seg_lo, shard.seg_hi))

    def promote_shard(self, shard: Shard):
        """Host -> device: (own_params, shared_params, opt_state)."""
        own = to_device(self._own_params(shard))
        shared = {n: to_device(sg.resolve_ref(self.params,
                                              self.plan.shared_refs[n]))
                  for n in self.shard_shared_names(shard)}
        opt_state = to_device(self.opt[shard.index])
        return own, shared, opt_state

    def demote_shard(self, shard: Shard, own, opt_state):
        """Device -> host: write back possibly-updated params + opt state."""
        for k, i in enumerate(range(shard.seg_lo, shard.seg_hi)):
            ref = self.plan.segments[i].param_ref
            if ref is not None and own[k] is not None:
                sg.update_with_ref(self.params, ref, to_host(own[k]))
        self.opt[shard.index] = to_host(opt_state)

    def shard_shared_names(self, shard: Shard) -> list[str]:
        names: list[str] = []
        for i in range(shard.seg_lo, shard.seg_hi):
            for n in self.plan.segments[i].shared:
                if n not in names:
                    names.append(n)
        return names

    # -- shared ------------------------------------------------------------
    def accumulate_shared_grads(self, grads: dict[str, Any]):
        for name, g in grads.items():
            if g is None:
                continue
            if name in self.shared_grad_acc:
                self.shared_grad_acc[name] = jax.tree.map(
                    lambda a, b: a + np.asarray(b),
                    self.shared_grad_acc[name], g)
            else:
                self.shared_grad_acc[name] = to_host(g)

    def step_shared(self):
        """Apply accumulated shared-param grads (mini-batch boundary)."""
        from repro.optim import optimizers as opt
        for name, g in self.shared_grad_acc.items():
            ref = self.plan.shared_refs[name]
            p = to_device(sg.resolve_ref(self.params, ref))
            s = to_device(self.shared_opt[name])
            new_p, new_s = opt.update(self.opt_cfg, p, to_device(g), s)
            sg.update_with_ref(self.params, ref, to_host(new_p))
            self.shared_opt[name] = to_host(new_s)
        self.shared_grad_acc = {}

    # -- sizes --------------------------------------------------------------
    def shard_transfer_bytes(self, shard: Shard, *, train: bool = True) -> int:
        own_b = sum(tree_bytes(p) for p in self._own_params(shard)
                    if p is not None)
        shared_b = sum(
            tree_bytes(sg.resolve_ref(self.params, self.plan.shared_refs[n]))
            for n in self.shard_shared_names(shard))
        opt_b = tree_bytes(self.opt[shard.index]) if train else 0
        return own_b + shared_b + opt_b

    def model_params(self):
        """Reassembled full param tree (reference comparisons/checkpoints)."""
        return sg.restore_model_params(self.cfg, self.params)


class DeviceMemory:
    """Budget + double-buffer + KV-page accounting for one virtual device.

    One ledger, three charges against the same byte budget: promoted shard
    residency (``resident_bytes``), the double-buffer loading zone
    (``buffered_bytes``), and serving KV-page reservations
    (``kv_reserved_bytes`` — charged by page-granular admission in
    ``repro.serving``, so mixed train+serve plans stay byte-accurate).
    """

    def __init__(self, device_id: int, budget_bytes: int,
                 buffer_frac: float = 0.05):
        self.device_id = device_id
        self.budget = budget_bytes
        self.buffer_budget = int(budget_bytes * buffer_frac)
        self.resident_bytes = 0
        self.buffered_bytes = 0
        self.kv_reserved_bytes = 0
        self.kv_peak_bytes = 0
        self.stats = TransferStats()

    def used_bytes(self) -> int:
        return self.resident_bytes + self.buffered_bytes \
            + self.kv_reserved_bytes

    def _check_budget(self) -> None:
        # a real error, not an assert: budget enforcement is a correctness
        # invariant that must survive `python -O`
        if self.used_bytes() > self.budget:
            raise RuntimeError(
                f"device {self.device_id} over budget: "
                f"{self.used_bytes()/1e9:.3f} GB > {self.budget/1e9:.3f} GB "
                f"(resident {self.resident_bytes/1e9:.3f} GB, double-buffer "
                f"{self.buffered_bytes/1e9:.3f} GB, kv pages "
                f"{self.kv_reserved_bytes/1e9:.3f} GB)")

    def charge_promotion(self, nbytes: int, *, into_buffer: bool):
        if into_buffer:
            self.buffered_bytes += nbytes
        else:
            self.resident_bytes += nbytes
        self.stats.promoted_bytes += nbytes
        self.stats.n_promotions += 1
        self._check_budget()

    # -- serving KV pages ----------------------------------------------------
    def can_reserve_kv(self, nbytes: int) -> bool:
        return self.used_bytes() + nbytes <= self.budget

    def reserve_kv(self, nbytes: int) -> bool:
        """Charge a KV-page reservation; False (not an error) when it does
        not fit — admission control degrades to queueing, not crashing."""
        if not self.can_reserve_kv(nbytes):
            return False
        self.kv_reserved_bytes += nbytes
        self.kv_peak_bytes = max(self.kv_peak_bytes, self.kv_reserved_bytes)
        return True

    def release_kv(self, nbytes: int) -> None:
        if nbytes > self.kv_reserved_bytes:
            raise RuntimeError(
                f"device {self.device_id}: release_kv({nbytes}) exceeds the "
                f"{self.kv_reserved_bytes} B reserved — release without a "
                "matching reserve")
        self.kv_reserved_bytes -= nbytes

    def activate_buffer(self):
        """Promote the double-buffered shard to the active region."""
        self.resident_bytes += self.buffered_bytes
        self.buffered_bytes = 0

    def charge_demotion(self, nbytes: int):
        self.resident_bytes = max(0, self.resident_bytes - nbytes)
        self.stats.demoted_bytes += nbytes
        self.stats.n_demotions += 1

    def charge_act(self, nbytes: int):
        self.stats.act_bytes_moved += nbytes
