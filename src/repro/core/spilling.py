"""Model spilling (paper §4.2): shard-granular promotion/demotion between
device memory and host DRAM, with byte accounting per virtual device.

On real TPU/GPU fleets promotion is ``jax.device_put`` into HBM and demotion
is a host fetch; on this CPU dev container the transfers are physically
host→host but the *mechanics* (buffer lifecycle, budget enforcement,
double-buffer reservations, byte/traffic accounting) are identical and fully
exercised.  The SHARP executor charges virtual transfer time =
bytes / ``link_bw`` against the device timeline.

Layout of the host store per model:
    params:      family host tree (numpy-backed, prepare_host_params applied)
    opt:         {shard_index: opt-state tree}  (own params)
    shared_opt:  {name: opt-state tree}         (shared params)
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import shard_graph as sg
from repro.core.partitioner import PartitionResult, Shard, tree_bytes


def to_host(tree):
    # np.array (copy) — np.asarray of a jax array is a read-only view
    return jax.tree.map(lambda a: np.array(a), tree)


def to_device(tree, device=None):
    if device is None:
        return jax.tree.map(jnp.asarray, tree)
    return jax.tree.map(lambda a: jax.device_put(a, device), tree)


@dataclass
class TransferStats:
    promoted_bytes: int = 0
    demoted_bytes: int = 0
    n_promotions: int = 0
    n_demotions: int = 0
    act_bytes_moved: int = 0
    # tiered KV (serving): pages moved between device pool and host pool
    kv_demoted_bytes: int = 0
    kv_prefetched_bytes: int = 0
    n_kv_demotions: int = 0
    n_kv_prefetches: int = 0

    def total_bytes(self) -> int:
        return (self.promoted_bytes + self.demoted_bytes
                + self.act_bytes_moved
                + self.kv_demoted_bytes + self.kv_prefetched_bytes)


class HostModelStore:
    """DRAM-resident master copy of one model (params + optimizer state)."""

    def __init__(self, cfg, plan: sg.ShardPlan, params, opt_cfg,
                 partition: PartitionResult):
        from repro.optim import optimizers as opt
        self.cfg = cfg
        self.plan = plan
        self.partition = partition
        self.params = sg.prepare_host_params(cfg, to_host(params))
        self.opt_cfg = opt_cfg
        self.opt: dict[int, Any] = {}
        for shard in partition.shards:
            own = self._own_params(shard)
            self.opt[shard.index] = to_host(opt.init_state(opt_cfg, own))
        self.shared_opt = {
            name: to_host(opt.init_state(
                opt_cfg, sg.resolve_ref(self.params, ref)))
            for name, ref in plan.shared_refs.items()}
        # accumulated grads for shared params within the current mini-batch
        self.shared_grad_acc: dict[str, Any] = {}

    # -- own (spillable) ---------------------------------------------------
    def _own_params(self, shard: Shard):
        return tuple(sg.resolve_ref(self.params,
                                    self.plan.segments[i].param_ref)
                     for i in range(shard.seg_lo, shard.seg_hi))

    def promote_shard(self, shard: Shard):
        """Host -> device: (own_params, shared_params, opt_state)."""
        own = to_device(self._own_params(shard))
        shared = {n: to_device(sg.resolve_ref(self.params,
                                              self.plan.shared_refs[n]))
                  for n in self.shard_shared_names(shard)}
        opt_state = to_device(self.opt[shard.index])
        return own, shared, opt_state

    def promote_shard_params(self, shard: Shard):
        """Host -> device, weights only (serving: no optimizer state)."""
        own = to_device(self._own_params(shard))
        shared = {n: to_device(sg.resolve_ref(self.params,
                                              self.plan.shared_refs[n]))
                  for n in self.shard_shared_names(shard)}
        return own, shared

    def demote_shard(self, shard: Shard, own, opt_state):
        """Device -> host: write back possibly-updated params + opt state."""
        for k, i in enumerate(range(shard.seg_lo, shard.seg_hi)):
            ref = self.plan.segments[i].param_ref
            if ref is not None and own[k] is not None:
                sg.update_with_ref(self.params, ref, to_host(own[k]))
        self.opt[shard.index] = to_host(opt_state)

    def shard_shared_names(self, shard: Shard) -> list[str]:
        names: list[str] = []
        for i in range(shard.seg_lo, shard.seg_hi):
            for n in self.plan.segments[i].shared:
                if n not in names:
                    names.append(n)
        return names

    # -- shared ------------------------------------------------------------
    def accumulate_shared_grads(self, grads: dict[str, Any]):
        for name, g in grads.items():
            if g is None:
                continue
            if name in self.shared_grad_acc:
                self.shared_grad_acc[name] = jax.tree.map(
                    lambda a, b: a + np.asarray(b),
                    self.shared_grad_acc[name], g)
            else:
                self.shared_grad_acc[name] = to_host(g)

    def step_shared(self):
        """Apply accumulated shared-param grads (mini-batch boundary)."""
        from repro.optim import optimizers as opt
        for name, g in self.shared_grad_acc.items():
            ref = self.plan.shared_refs[name]
            p = to_device(sg.resolve_ref(self.params, ref))
            s = to_device(self.shared_opt[name])
            new_p, new_s = opt.update(self.opt_cfg, p, to_device(g), s)
            sg.update_with_ref(self.params, ref, to_host(new_p))
            self.shared_opt[name] = to_host(new_s)
        self.shared_grad_acc = {}

    # -- sizes --------------------------------------------------------------
    def shard_transfer_bytes(self, shard: Shard, *, train: bool = True) -> int:
        own_b = sum(tree_bytes(p) for p in self._own_params(shard)
                    if p is not None)
        shared_b = sum(
            tree_bytes(sg.resolve_ref(self.params, self.plan.shared_refs[n]))
            for n in self.shard_shared_names(shard))
        opt_b = tree_bytes(self.opt[shard.index]) if train else 0
        return own_b + shared_b + opt_b

    def model_params(self):
        """Reassembled full param tree (reference comparisons/checkpoints)."""
        return sg.restore_model_params(self.cfg, self.params)


class DeviceMemory:
    """Budget + double-buffer + KV-page accounting for one virtual device.

    One ledger, four charges against the same byte budget: promoted shard
    residency (``resident_bytes`` — train units and shards streamed through
    the serve loop), the double-buffer loading zone (``buffered_bytes``),
    serving KV-page reservations (``kv_reserved_bytes`` — charged by
    page-granular admission in ``repro.serving``), and persistent serve-side
    weight residency (``weight_resident_bytes`` — hot shards held across
    serve ticks by shard-granular residency, ``serving/residency.py``).

    The tiered extension treats this device budget as a *cache* over host
    DRAM (ZeRO-Infinity, arXiv 2104.07857): KV pages of parked requests can
    be demoted into a host pool (``host_kv_bytes`` — tracked, but not
    charged against the device budget) and prefetched back later, and a
    failing reservation first consults registered *pressure handlers*
    (LRU demotion of idle models' weight shards or parked KV pages) before
    giving up.
    """

    def __init__(self, device_id: int, budget_bytes: int,
                 buffer_frac: float = 0.05):
        self.device_id = device_id
        self.budget = budget_bytes
        self.buffer_budget = int(budget_bytes * buffer_frac)
        self.resident_bytes = 0
        self.buffered_bytes = 0
        self.kv_reserved_bytes = 0
        self.kv_peak_bytes = 0
        # tiered terms: persistent serve-weight residency on device, and
        # demoted KV pages parked in the host-DRAM pool
        self.weight_resident_bytes = 0
        self.host_kv_bytes = 0
        self.host_kv_peak_bytes = 0
        self.stats = TransferStats()
        self._pressure_handlers: list = []
        self._in_pressure = False

    def used_bytes(self) -> int:
        return (self.resident_bytes + self.buffered_bytes
                + self.kv_reserved_bytes + self.weight_resident_bytes)

    def _check_budget(self) -> None:
        # a real error, not an assert: budget enforcement is a correctness
        # invariant that must survive `python -O`
        if self.used_bytes() > self.budget:
            raise RuntimeError(
                f"device {self.device_id} over budget: "
                f"{self.used_bytes()/1e9:.3f} GB > {self.budget/1e9:.3f} GB "
                f"(resident {self.resident_bytes/1e9:.3f} GB, double-buffer "
                f"{self.buffered_bytes/1e9:.3f} GB, kv pages "
                f"{self.kv_reserved_bytes/1e9:.3f} GB, serve weights "
                f"{self.weight_resident_bytes/1e9:.3f} GB)")

    def charge_promotion(self, nbytes: int, *, into_buffer: bool):
        if into_buffer:
            self.buffered_bytes += nbytes
        else:
            self.resident_bytes += nbytes
        self.stats.promoted_bytes += nbytes
        self.stats.n_promotions += 1
        self._check_budget()

    def promote_through_buffer(self, nbytes: int, *,
                               double_buffer: bool = True) -> None:
        """The SHARP promotion pattern: land the shard in the loading zone,
        then flip it into the active region.  Shared by the train executor
        (``core/sharp.py``) and serve-side shard streaming
        (``serving/residency.py``) so both charge the budget at the same
        buffered peak."""
        self.charge_promotion(nbytes, into_buffer=double_buffer)
        if double_buffer:
            self.activate_buffer()

    # -- pressure (tiered demotion) -----------------------------------------
    def on_pressure(self, handler) -> None:
        """Register ``handler(need_bytes) -> freed_bytes``, consulted when a
        reservation does not fit.  Handlers demote tiered residents (idle
        models' weight shards, parked KV pages) to host DRAM."""
        if handler not in self._pressure_handlers:
            self._pressure_handlers.append(handler)

    def _relieve(self, need_bytes: int) -> None:
        if self._in_pressure or need_bytes <= 0:
            return
        self._in_pressure = True
        try:
            freed = 0
            for handler in list(self._pressure_handlers):
                if freed >= need_bytes:
                    break
                freed += int(handler(need_bytes - freed))
        finally:
            self._in_pressure = False

    # -- serve weights (shard-granular residency) ---------------------------
    def reserve_weights(self, nbytes: int) -> bool:
        """Charge persistent hot-shard residency for a served model; False
        when it does not fit even after pressure-driven demotion — the
        caller streams the shard per tick instead of pinning it."""
        over = self.used_bytes() + nbytes - self.budget
        if over > 0:
            self._relieve(over)
        if self.used_bytes() + nbytes > self.budget:
            return False
        self.weight_resident_bytes += nbytes
        self.stats.promoted_bytes += nbytes
        self.stats.n_promotions += 1
        return True

    def release_weights(self, nbytes: int) -> None:
        """Demote hot serve shards back to the host store."""
        if nbytes > self.weight_resident_bytes:
            raise RuntimeError(
                f"device {self.device_id}: release_weights({nbytes}) exceeds "
                f"the {self.weight_resident_bytes} B of serve-weight "
                "residency — release without a matching reserve")
        self.weight_resident_bytes -= nbytes
        self.stats.demoted_bytes += nbytes
        self.stats.n_demotions += 1

    # -- serving KV pages ----------------------------------------------------
    def can_reserve_kv(self, nbytes: int) -> bool:
        return self.used_bytes() + nbytes <= self.budget

    def reserve_kv(self, nbytes: int) -> bool:
        """Charge a KV-page reservation; False (not an error) when it does
        not fit — admission control degrades to queueing, not crashing.
        Under pressure, registered handlers may demote tiered residents to
        make the reservation fit."""
        if not self.can_reserve_kv(nbytes):
            self._relieve(self.used_bytes() + nbytes - self.budget)
        if not self.can_reserve_kv(nbytes):
            return False
        self.kv_reserved_bytes += nbytes
        self.kv_peak_bytes = max(self.kv_peak_bytes, self.kv_reserved_bytes)
        return True

    # -- tiered KV: device pool <-> host pool -------------------------------
    def demote_kv(self, nbytes: int) -> None:
        """Move a live KV reservation device -> host pool: the device bytes
        are released (schedulable by others) while the pages stay accounted
        in ``host_kv_bytes`` until prefetched back or dropped."""
        self.release_kv(nbytes)
        self.host_kv_bytes += nbytes
        self.host_kv_peak_bytes = max(self.host_kv_peak_bytes,
                                      self.host_kv_bytes)
        self.stats.kv_demoted_bytes += nbytes
        self.stats.n_kv_demotions += 1

    def prefetch_kv(self, nbytes: int) -> bool:
        """Host pool -> device: re-reserve device bytes for demoted pages.
        False when the device side does not fit yet — the pages stay in the
        host pool and the owner retries once bytes drain."""
        if nbytes > self.host_kv_bytes:
            raise RuntimeError(
                f"device {self.device_id}: prefetch_kv({nbytes}) exceeds the "
                f"{self.host_kv_bytes} B parked in the host pool")
        if not self.reserve_kv(nbytes):
            return False
        self.host_kv_bytes -= nbytes
        self.stats.kv_prefetched_bytes += nbytes
        self.stats.n_kv_prefetches += 1
        return True

    def drop_host_kv(self, nbytes: int) -> None:
        """Discard demoted pages parked in the host pool (cancel/shed of a
        demoted request) without re-reserving device bytes."""
        if nbytes > self.host_kv_bytes:
            raise RuntimeError(
                f"device {self.device_id}: drop_host_kv({nbytes}) exceeds "
                f"the {self.host_kv_bytes} B parked in the host pool")
        self.host_kv_bytes -= nbytes

    def release_kv(self, nbytes: int) -> None:
        if nbytes > self.kv_reserved_bytes:
            raise RuntimeError(
                f"device {self.device_id}: release_kv({nbytes}) exceeds the "
                f"{self.kv_reserved_bytes} B reserved — release without a "
                "matching reserve")
        self.kv_reserved_bytes -= nbytes

    def activate_buffer(self):
        """Promote the double-buffered shard to the active region."""
        self.resident_bytes += self.buffered_bytes
        self.buffered_bytes = 0

    def charge_demotion(self, nbytes: int):
        self.resident_bytes = max(0, self.resident_bytes - nbytes)
        self.stats.demoted_bytes += nbytes
        self.stats.n_demotions += 1

    def charge_act(self, nbytes: int):
        self.stats.act_bytes_moved += nbytes
