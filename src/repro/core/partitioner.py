"""Automated model partitioning (paper §4.3, Algorithm 1).

Greedy, dynamic: pack the longest prefix of remaining segments that fits the
device memory budget.  Two fitting oracles:

* ``analytic`` (default) — a memory cost model over the segment's actual
  param trees: params + grads + optimizer state + boundary activations +
  recompute workspace.  Zero compile cost.
* ``probe`` — the paper's "pilot run", adapted to JAX AOT: lower + compile
  the shard's forward+backward on ShapeDtypeStructs and read
  ``memory_analysis()`` (no allocation, honest peak).  Used when the cost
  model would be too coarse (validated against it in tests).

The partitioner also records per-shard pilot *runtimes* (real measurements
when ``measure=True``) — these feed Sharded-LRTF exactly as in the paper.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import shard_graph as sg


@dataclass
class Shard:
    index: int
    seg_lo: int                    # [seg_lo, seg_hi) into plan.segments
    seg_hi: int
    param_bytes: int = 0
    act_bytes: int = 0
    est_runtime: float = 0.0       # seconds, fwd+bwd (pilot)
    fwd_runtime: float = 0.0
    bwd_runtime: float = 0.0

    @property
    def n_segments(self) -> int:
        return self.seg_hi - self.seg_lo


@dataclass
class PartitionResult:
    shards: list[Shard]
    shared_bytes: int
    budget_bytes: int
    oracle: str

    def __iter__(self):
        return iter(self.shards)

    def __len__(self):
        return len(self.shards)


# ---------------------------------------------------------------------------
# memory accounting
# ---------------------------------------------------------------------------

def tree_bytes(tree) -> int:
    return sum(np.asarray(x).nbytes if not hasattr(x, "nbytes") else x.nbytes
               for x in jax.tree.leaves(tree))


def _act_width(cfg) -> int:
    """Bytes per (batch·seq) element of the inter-segment activation."""
    w = cfg.d_model * jnp.dtype(cfg.dtype).itemsize
    if cfg.family == "audio":
        w *= 2     # decoder segments also carry enc passthrough
    return w


def segment_cost(cfg, params, seg: sg.Segment, batch: int, seq: int,
                 *, train: bool = True) -> tuple[int, int]:
    """Returns (param_bytes, peak_act_bytes) for one segment."""
    own = sg.resolve_ref(params, seg.param_ref)
    pbytes = tree_bytes(own) if own is not None else 0
    opt_mult = 4 if train else 1        # params + grads + adam(mu, nu)
    act = batch * seq * _act_width(cfg)
    if seg.name in ("embed", "head", "frontend"):
        # head materializes logits in f32
        act = max(act, batch * seq * cfg.vocab_size * 4 // 8)  # sharded est.
    # remat inside segments: workspace ~ 4 live activation copies
    return pbytes * opt_mult, act * 4


def shared_cost(cfg, params, plan: sg.ShardPlan, *, train: bool = True) -> int:
    total = 0
    for name, ref in plan.shared_refs.items():
        total += tree_bytes(sg.resolve_ref(params, ref))
    return total * (4 if train else 1)


# ---------------------------------------------------------------------------
# fitting oracles
# ---------------------------------------------------------------------------

def analytic_fits(cfg, params, plan, lo, hi, batch, seq, budget, shared_bytes,
                  buffer_frac: float, train: bool = True) -> bool:
    total = shared_bytes
    for i in range(lo, hi):
        p, a = segment_cost(cfg, params, plan.segments[i], batch, seq,
                            train=train)
        total += p
        peak_act = a
    total += peak_act
    return total <= budget * (1.0 - buffer_frac)


def probe_fits(cfg, params, plan, lo, hi, batch, seq, budget, shared_bytes,
               buffer_frac: float, train: bool = True) -> bool:
    """AOT pilot-run: compile the shard's fwd+bwd, read memory_analysis.

    The JAX analogue of the paper's Algorithm-1 toy run: no allocation, but
    the honest compiled peak for this candidate shard."""
    own_spec, shared_spec = _shard_param_specs(cfg, params, plan, lo, hi)
    act_spec = _entry_act_spec(cfg, plan, lo, batch, seq)
    batch_spec = _batch_spec(cfg, batch, seq)

    def chain(own, shared, act, b):
        for k, i in enumerate(range(lo, hi)):
            seg = plan.segments[i]
            seg_shared = {n: shared[n] for n in seg.shared}
            act = seg.apply(cfg, own[k], seg_shared, act, b)
        return act

    def fwd_bwd(own, shared, act, b):
        out, vjp = jax.vjp(lambda o, s, a: chain(o, s, a, b),
                           own, shared, act)
        cots = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), out)
        return vjp(cots)

    try:
        compiled = jax.jit(fwd_bwd).lower(
            own_spec, shared_spec, act_spec, batch_spec).compile()
        ma = compiled.memory_analysis()
        peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes)
        # optimizer state for the shard also lives on device at step time
        opt_bytes = 2 * sum(
            tree_bytes(p) for p in
            (sg.resolve_ref(params, plan.segments[i].param_ref)
             for i in range(lo, hi)) if p is not None)
        return peak + opt_bytes + shared_bytes // 2 <= \
            budget * (1.0 - buffer_frac)
    except Exception:
        return False


def _shard_param_specs(cfg, params, plan, lo, hi):
    own = tuple(sg.resolve_ref(params, plan.segments[i].param_ref)
                for i in range(lo, hi))
    shared_names = sorted({n for i in range(lo, hi)
                           for n in plan.segments[i].shared})
    shared = {n: sg.resolve_ref(params, plan.shared_refs[n])
              for n in shared_names}
    to_spec = lambda t: jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype), t)
    return to_spec(own), to_spec(shared)


def _batch_spec(cfg, batch, seq):
    d = cfg.d_model
    out = {"labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if cfg.family == "audio":
        out["enc_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_len, d), jnp.bfloat16)
        out["tokens"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    elif cfg.takes_embeddings:
        out["embeds"] = jax.ShapeDtypeStruct((batch, seq, d), jnp.bfloat16)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    return out


def _entry_act_spec(cfg, plan, lo, batch, seq):
    d = cfg.d_model
    if lo == 0:
        return {}
    spec = {"x": jax.ShapeDtypeStruct((batch, seq, d), cfg.dtype)}
    if cfg.family == "moe":
        spec["aux"] = {"lb": jax.ShapeDtypeStruct((), jnp.float32),
                       "z": jax.ShapeDtypeStruct((), jnp.float32)}
    if cfg.family == "audio":
        spec = {"enc_x": jax.ShapeDtypeStruct(
            (batch, cfg.encoder_len, d), cfg.dtype)}
    return spec


# ---------------------------------------------------------------------------
# Algorithm 1 (greedy dynamic partitioning)
# ---------------------------------------------------------------------------

def partition(cfg, params, plan: sg.ShardPlan, *,
              budget_bytes: int,
              batch: int, seq: int,
              oracle: str = "analytic",
              buffer_frac: float = 0.05,
              train: bool = True,
              measure: bool = False,
              measure_batch=None,
              cost_model=None) -> PartitionResult:
    """Greedy prefix packing of segments into shards under ``budget_bytes``.

    ``buffer_frac`` reserves the double-buffer loading zone (paper §4.6:
    ~5% of device memory suffices since intermediates dominate and are not
    double-buffered).
    """
    fits = analytic_fits if oracle == "analytic" else probe_fits
    shared_bytes = shared_cost(cfg, params, plan, train=train)
    n = len(plan.segments)
    shards: list[Shard] = []
    lo = 0
    while lo < n:
        hi = lo + 1
        if not fits(cfg, params, plan, lo, hi, batch, seq, budget_bytes,
                    shared_bytes, buffer_frac, train):
            raise MemoryError(
                f"segment {plan.segments[lo].name} alone exceeds the device "
                f"budget ({budget_bytes/1e9:.2f} GB) — model unpartitionable")
        while hi < n and fits(cfg, params, plan, lo, hi + 1, batch, seq,
                              budget_bytes, shared_bytes, buffer_frac,
                              train):
            hi += 1
        pbytes = sum(segment_cost(cfg, params, plan.segments[i],
                                  batch, seq)[0] for i in range(lo, hi))
        abytes = max(segment_cost(cfg, params, plan.segments[i],
                                  batch, seq)[1] for i in range(lo, hi))
        shards.append(Shard(len(shards), lo, hi,
                            param_bytes=pbytes, act_bytes=abytes))
        lo = hi

    result = PartitionResult(shards, shared_bytes, budget_bytes, oracle)
    _assign_runtimes(cfg, params, plan, result,
                     cost_model=cost_model, batch=batch, seq=seq)
    return result


def _assign_runtimes(cfg, params, plan, result, *, cost_model=None,
                     batch: int = 2, seq: int = 128):
    """Initial runtime estimates ∝ flops_weight × param bytes.

    The SHARP executor's pilot pass (first mini-batch) overwrites these with
    *measured* per-shard times — a dynamic refinement of the paper's static
    pilot run; Sharded-LRTF reads whichever is current.

    With a ``repro.profiler.CostModel`` the same per-shard weights price
    against a *measured* whole-model forward instead of the analytic
    1e-12 s/weighted-byte prior; the unprofiled CostModel reproduces the
    analytic numbers byte-identically (and records either way in its
    provenance).
    """
    weights = [
        sum(plan.segments[i].flops_weight
            * max(1, sg_param_bytes(params, plan.segments[i]))
            for i in range(shard.seg_lo, shard.seg_hi))
        for shard in result.shards]
    if cost_model is not None:
        runtimes = cost_model.shard_runtimes(cfg, weights,
                                             batch=batch, seq=seq)
    else:
        runtimes = [(w * 1e-12, 2 * (w * 1e-12)) for w in weights]
    for shard, (fwd, bwd) in zip(result.shards, runtimes):
        shard.fwd_runtime = fwd
        shard.bwd_runtime = bwd
        shard.est_runtime = shard.fwd_runtime + shard.bwd_runtime


def sg_param_bytes(params, seg) -> int:
    own = sg.resolve_ref(params, seg.param_ref)
    return tree_bytes(own) if own is not None else 0
