"""SHARP scheduling (paper §4.7): the MILP formalization's greedy solver —
Sharded-LRTF (Algorithm 2) — plus baselines (random, FIFO, SRTF) and an
exact branch-and-bound for small instances (the Gurobi stand-in used by the
Fig 7 simulation study).

A *unit* here is opaque: the scheduler only sees per-model remaining-time
structure, exactly the Struct of Algorithm 2:
    e   remaining epochs
    b   mini-batches per epoch
    ce  remaining mini-batches in current epoch
    t   mini-batch train time (sum of the model's unit times)
    cm  remaining train time in current mini-batch
"""

from __future__ import annotations

import heapq
import itertools
import random as _random
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence


@dataclass
class ModelProgress:
    """Sharded-LRTF's per-model struct (paper Algorithm 2)."""
    model_id: int
    remaining_epochs: int            # e  (includes current)
    minibatches_per_epoch: int       # b
    remaining_in_epoch: int          # ce (includes current)
    minibatch_time: float            # t
    remaining_in_minibatch: float    # cm

    def remaining_time(self) -> float:
        e, b, ce = self.remaining_epochs, self.minibatches_per_epoch, \
            self.remaining_in_epoch
        return ((e - 1) * b + ce - 1) * self.minibatch_time \
            + self.remaining_in_minibatch

    @classmethod
    def from_remaining(cls, model_id: int,
                       remaining_seconds: float) -> "ModelProgress":
        """Degenerate single-minibatch struct whose ``remaining_time()`` is
        exactly ``remaining_seconds`` — how serving maps a model's remaining
        decode work onto the training-centric LRTF struct
        (see repro.serving.multi)."""
        return cls(model_id, remaining_epochs=1, minibatches_per_epoch=1,
                   remaining_in_epoch=1, minibatch_time=remaining_seconds,
                   remaining_in_minibatch=remaining_seconds)


SchedulerFn = Callable[[Sequence[ModelProgress]], int]
"""Given the *eligible* models, return the chosen index into the sequence."""


def sharded_lrtf(eligible: Sequence[ModelProgress]) -> int:
    """Pick the model with the Longest Remaining Train Time (Algorithm 2)."""
    best, best_t = 0, -1.0
    for i, m in enumerate(eligible):
        t = m.remaining_time()
        if t > best_t:
            best, best_t = i, t
    return best


def sharded_srtf(eligible: Sequence[ModelProgress]) -> int:
    """Shortest-remaining-time-first (anti-LRTF control)."""
    best, best_t = 0, float("inf")
    for i, m in enumerate(eligible):
        t = m.remaining_time()
        if t < best_t:
            best, best_t = i, t
    return best


def fifo(eligible: Sequence[ModelProgress]) -> int:
    return min(range(len(eligible)), key=lambda i: eligible[i].model_id)


def make_random_scheduler(seed: int = 0) -> SchedulerFn:
    rng = _random.Random(seed)

    def random_sched(eligible: Sequence[ModelProgress]) -> int:
        return rng.randrange(len(eligible))

    return random_sched


SCHEDULERS: dict[str, Callable[..., SchedulerFn]] = {
    "lrtf": lambda **_: sharded_lrtf,
    "srtf": lambda **_: sharded_srtf,
    "fifo": lambda **_: fifo,
    "random": lambda seed=0, **_: make_random_scheduler(seed),
    # "slo": deadline-aware serving routing.  The deadline logic needs
    # live engine state (per-request slack — repro.serving.slo), which
    # this ModelProgress-only signature cannot see; MultiModelServer
    # special-cases the name and uses this LRTF fn as its no-deadline
    # fallback, so training/config surfaces accept "slo" uniformly.
    "slo": lambda **_: sharded_lrtf,
}


def get_scheduler(name: str, **kw) -> SchedulerFn:
    return SCHEDULERS[name](**kw)


# ---------------------------------------------------------------------------
# Exact branch-and-bound (small instances) — the paper's MILP stand-in.
#
# Problem: T models, model i is a chain of M_i units with runtimes S_i[j];
# P identical devices; a unit may start when its predecessor finished and
# some device is free; objective = makespan.  This is the paper's MILP
# (constraints a–e) solved exactly by DFS with pruning.
# ---------------------------------------------------------------------------

def optimal_makespan(unit_times: list[list[float]], n_devices: int,
                     node_limit: int = 200_000) -> float:
    """Exact (within node_limit) chain-job-shop makespan via branch & bound."""
    T = len(unit_times)
    totals = [sum(u) for u in unit_times]
    best = [greedy_list_makespan(unit_times, n_devices)]   # incumbent
    nodes = [0]

    def lower_bound(next_unit, model_free, dev_heap):
        # LB1: longest remaining chain from its earliest feasible start
        lb1 = max((model_free[i] + sum(unit_times[i][next_unit[i]:])
                   for i in range(T) if next_unit[i] < len(unit_times[i])),
                  default=0.0)
        # LB2: total remaining work / devices, from earliest device time
        rem = sum(sum(unit_times[i][next_unit[i]:]) for i in range(T))
        lb2 = min(dev_heap) + rem / n_devices if rem else 0.0
        return max(lb1, lb2)

    def dfs(next_unit, model_free, dev_heap, t_now):
        if nodes[0] > node_limit:
            return
        nodes[0] += 1
        if all(next_unit[i] >= len(unit_times[i]) for i in range(T)):
            best[0] = min(best[0], max(model_free))
            return
        if lower_bound(next_unit, model_free, dev_heap) >= best[0]:
            return
        # branching: assign the earliest-free device to any eligible model
        heap = sorted(dev_heap)
        dev_t = heap[0]
        rest = heap[1:]
        cands = [i for i in range(T) if next_unit[i] < len(unit_times[i])]
        # heuristic order: longest remaining first (matches LRTF intuition)
        cands.sort(key=lambda i: -(model_free[i]
                                   + sum(unit_times[i][next_unit[i]:])))
        for i in cands:
            start = max(dev_t, model_free[i])
            end = start + unit_times[i][next_unit[i]]
            if end >= best[0]:
                continue
            nu = list(next_unit)
            nu[i] += 1
            mf = list(model_free)
            mf[i] = end
            dfs(tuple(nu), tuple(mf), tuple(rest + [end]), end)
        # also allow the device to idle past the next model-free event
        future = sorted(set(m for m in model_free if m > dev_t))
        if future:
            dfs(next_unit, model_free, tuple(rest + [future[0]]), t_now)

    dfs(tuple([0] * T), tuple([0.0] * T), tuple([0.0] * n_devices), 0.0)
    return best[0]


def greedy_list_makespan(unit_times: list[list[float]], n_devices: int,
                         scheduler: Optional[SchedulerFn] = None,
                         seed: int = 0) -> float:
    """Event-driven makespan under a unit-level scheduler (default LRTF)."""
    scheduler = scheduler or sharded_lrtf
    T = len(unit_times)
    next_unit = [0] * T
    model_free = [0.0] * T
    running = [False] * T
    dev_heap = [(0.0, d) for d in range(n_devices)]
    heapq.heapify(dev_heap)
    finish_events: list[tuple[float, int]] = []
    makespan = 0.0

    while True:
        if all(next_unit[i] >= len(unit_times[i]) for i in range(T)):
            break
        t, d = heapq.heappop(dev_heap)
        # release models whose units finished by t
        for ft, mi in list(finish_events):
            if ft <= t:
                running[mi] = False
                finish_events.remove((ft, mi))
        eligible = [i for i in range(T)
                    if not running[i] and next_unit[i] < len(unit_times[i])]
        if not eligible:
            # advance this device to the next finish event
            nxt = min(ft for ft, _ in finish_events)
            heapq.heappush(dev_heap, (nxt, d))
            continue
        progress = [_as_progress(i, unit_times, next_unit, model_free)
                    for i in eligible]
        pick = eligible[scheduler(progress)]
        start = max(t, model_free[pick])
        end = start + unit_times[pick][next_unit[pick]]
        next_unit[pick] += 1
        model_free[pick] = end
        running[pick] = True
        finish_events.append((end, pick))
        makespan = max(makespan, end)
        heapq.heappush(dev_heap, (end, d))
    return makespan


def _as_progress(i, unit_times, next_unit, model_free) -> ModelProgress:
    remaining = unit_times[i][next_unit[i]:]
    return ModelProgress(
        model_id=i, remaining_epochs=1, minibatches_per_epoch=1,
        remaining_in_epoch=1, minibatch_time=sum(unit_times[i]),
        remaining_in_minibatch=sum(remaining))
