"""SHARP — Shard Alternator Parallelism (paper §4.4–4.6).

The executor interleaves *shard units* (forward or backward of one shard of
one model on one mini-batch) from many models across devices, subject to each
model's sequential dependency.  Real JAX compute runs for every unit; device
parallelism is *virtualized*: each device owns a clock, and unit/transfer
durations (measured compute + modeled host-link transfers) advance it.  On a
real multi-accelerator fleet the same event loop dispatches to concurrent
device streams; on this 1-CPU container the timeline is exact but serialized.

Double buffering (§4.6): when a device *starts* a unit, the scheduler
immediately picks that device's next unit and begins promoting its shard into
the reserved buffer region — the transfer overlaps compute and is hidden iff
transfer_time <= compute_time.  The serendipitous bonus: if the next unit is
the same model's successor on the same device, the boundary activation never
moves.
"""

from __future__ import annotations

import heapq
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scheduler as sched
from repro.core import shard_graph as sg
from repro.core.partitioner import PartitionResult, Shard, tree_bytes
from repro.core.spilling import (DeviceMemory, HostModelStore, to_device,
                                 to_host)
from repro.optim import optimizers as opt


@dataclass
class HydraConfig:
    n_devices: int = 8
    device_budget_bytes: int = 11 * 10**9      # paper's RTX 2080 Ti
    buffer_frac: float = 0.05                  # double-buffer loading zone
    link_bw: float = 16e9                      # host<->device B/s (PCIe3 x16)
    enable_sharp: bool = True                  # False -> one model at a time
    enable_double_buffer: bool = True
    scheduler: str = "lrtf"
    seed: int = 0
    partition_oracle: str = "analytic"
    pilot: bool = True                         # measured pilot pass
    # deterministic simulation: pin every unit's fwd/bwd runtime to this
    # value after the pilot (compiled programs still warm up and real
    # compute still runs).  Makespan comparisons then depend only on the
    # scheduling/transfer model, not on pilot-measurement noise — the
    # double-buffer regression test needs this on shared CPU runners.
    fixed_unit_runtime: Optional[float] = None
    # elasticity (paper §4.7: devices may disappear due to faults or get
    # added due to elasticity): device_id -> (available_from, available_until)
    # in virtual seconds; None = always available
    device_windows: Optional[dict] = None

    def validate(self) -> "HydraConfig":
        """Fail fast on configs that would otherwise die deep inside the
        partitioner or event loop.  repro.api.Session calls this on entry."""
        if self.n_devices < 1:
            raise ValueError(
                f"n_devices={self.n_devices}: need at least one device")
        if self.device_budget_bytes <= 0:
            raise ValueError(
                f"device_budget_bytes={self.device_budget_bytes}: must be a "
                "positive byte count (e.g. 11*10**9 for an RTX 2080 Ti)")
        if not 0.0 < self.buffer_frac <= 0.5:
            raise ValueError(
                f"buffer_frac={self.buffer_frac}: the double-buffer loading "
                "zone must be in (0, 0.5] — the paper finds ~0.05 suffices; "
                "above 0.5 the buffer would outsize the active region")
        if self.link_bw <= 0:
            raise ValueError(
                f"link_bw={self.link_bw}: host<->device bandwidth must be "
                "positive B/s (e.g. 16e9 for PCIe3 x16)")
        if self.scheduler not in sched.SCHEDULERS:
            raise ValueError(
                f"unknown scheduler {self.scheduler!r}: choose one of "
                f"{sorted(sched.SCHEDULERS)}")
        if self.partition_oracle not in ("analytic", "probe"):
            raise ValueError(
                f"unknown partition_oracle {self.partition_oracle!r}: "
                "choose 'analytic' or 'probe'")
        return self


@dataclass
class Unit:
    model_id: int
    shard: Shard
    direction: str        # "fwd" | "bwd"
    minibatch: int
    epoch: int


# compiled shard programs shared across ModelExecs with identical
# (cfg, optimizer, shard-range) — model-selection jobs train many clones of
# one architecture, and recompiling per clone dominated benchmark wall time
_FN_CACHE: dict = {}


class ShardFunctions:
    """Compiled fwd/bwd/step programs per shard of one model."""

    def __init__(self, cfg, plan: sg.ShardPlan, partition: PartitionResult,
                 opt_cfg: opt.OptimizerConfig):
        self.cfg = cfg
        self.plan = plan
        self.partition = partition
        self.opt_cfg = opt_cfg
        self._fwd = {}
        self._bwd = {}
        step_key = (cfg, opt_cfg, "step")
        if step_key not in _FN_CACHE:
            _FN_CACHE[step_key] = jax.jit(self._step_impl)
        self._step = _FN_CACHE[step_key]

    def _chain(self, shard: Shard, own, shared, act, batch):
        for k, i in enumerate(range(shard.seg_lo, shard.seg_hi)):
            seg = self.plan.segments[i]
            seg_shared = {n: shared[n] for n in seg.shared}
            act = seg.apply(self.cfg, own[k], seg_shared, act, batch)
        return act

    def fwd(self, shard: Shard):
        if shard.index not in self._fwd:
            key = (self.cfg, self.opt_cfg, shard.seg_lo, shard.seg_hi,
                   "fwd", shard.index == len(self.partition.shards) - 1)
            if key not in _FN_CACHE:
                _FN_CACHE[key] = jax.jit(partial(self._fwd_impl, shard))
            self._fwd[shard.index] = _FN_CACHE[key]
        return self._fwd[shard.index]

    def _fwd_impl(self, shard, own, shared, act, batch):
        out = self._chain(shard, own, shared, act, batch)
        if shard.index == len(self.partition.shards) - 1:
            loss = self.plan.loss(self.cfg, out, batch)
            return out, loss
        return out, None

    def bwd(self, shard: Shard):
        if shard.index not in self._bwd:
            last = shard.index == len(self.partition.shards) - 1
            key = (self.cfg, self.opt_cfg, shard.seg_lo, shard.seg_hi,
                   "bwd", last)
            if key not in _FN_CACHE:
                _FN_CACHE[key] = jax.jit(partial(
                    self._bwd_last_impl if last else self._bwd_impl, shard))
            self._bwd[shard.index] = _FN_CACHE[key]
        return self._bwd[shard.index]

    def _bwd_last_impl(self, shard, own, shared, act_in, batch):
        def f(o, s, a):
            out = self._chain(shard, o, s, a, batch)
            return self.plan.loss(self.cfg, out, batch)

        loss, vjp = jax.vjp(f, own, shared, act_in)
        g_own, g_shared, g_act = vjp(jnp.ones_like(loss))
        return loss, g_own, g_shared, g_act

    def _bwd_impl(self, shard, own, shared, act_in, cot_out, batch):
        def f(o, s, a):
            return self._chain(shard, o, s, a, batch)

        _, vjp = jax.vjp(f, own, shared, act_in)
        g_own, g_shared, g_act = vjp(cot_out)
        return g_own, g_shared, g_act

    def _step_impl(self, own, g_own, opt_state):
        return opt.update(self.opt_cfg, own, g_own, opt_state)


@dataclass
class ModelExec:
    """Execution state of one ModelTask inside the SHARP loop."""
    model_id: int
    cfg: Any
    plan: sg.ShardPlan
    partition: PartitionResult
    store: HostModelStore
    fns: ShardFunctions
    data_iter: Any
    epochs: int
    steps_per_epoch: int
    early_stop: Optional[Callable[[list], bool]] = None
    stopped_early: bool = False
    # dynamic state
    queue: list[Unit] = field(default_factory=list)
    cursor: int = 0
    epoch: int = 0
    minibatch: int = 0
    ready_at: float = 0.0
    reserved: bool = False
    act_location: Optional[int] = None     # device holding current activation
    current_batch: Any = None
    saved_acts: dict = field(default_factory=dict)   # shard_idx -> entry act
    saved_cot: Any = None                  # cotangent flowing backward
    losses: list = field(default_factory=list)
    done: bool = False

    def build_minibatch_queue(self):
        shards = self.partition.shards
        units = [Unit(self.model_id, s, "fwd", self.minibatch, self.epoch)
                 for s in shards]
        units += [Unit(self.model_id, s, "bwd", self.minibatch, self.epoch)
                  for s in reversed(shards)]
        self.queue = units
        self.cursor = 0
        self.current_batch = jax.tree.map(jnp.asarray, next(self.data_iter))

    def next_unit(self) -> Optional[Unit]:
        if self.done:
            return None
        if self.cursor >= len(self.queue):
            return None
        return self.queue[self.cursor]

    def minibatch_time(self) -> float:
        return sum(s.fwd_runtime + s.bwd_runtime for s in self.partition.shards)

    def progress(self) -> sched.ModelProgress:
        rem_units = self.queue[self.cursor:]
        rem_t = sum(u.shard.fwd_runtime if u.direction == "fwd"
                    else u.shard.bwd_runtime for u in rem_units)
        return sched.ModelProgress(
            model_id=self.model_id,
            remaining_epochs=self.epochs - self.epoch,
            minibatches_per_epoch=self.steps_per_epoch,
            remaining_in_epoch=self.steps_per_epoch - self.minibatch,
            minibatch_time=self.minibatch_time(),
            remaining_in_minibatch=rem_t)


@dataclass(frozen=True)
class UnitEvent:
    """One executed shard unit, reported through ``SharpExecutor.run``'s
    ``on_unit`` hook — the seam where a Session ticks serve engines between
    train shard-units and where plan/execute equivalence is audited."""
    model_id: int
    shard_index: int
    direction: str
    minibatch: int
    epoch: int
    device: int
    start: float
    end: float

    def key(self) -> tuple:
        """Schedule identity (virtual timestamps excluded: they shift with
        measured runtimes, the discrete assignment is the schedule)."""
        return (self.model_id, self.shard_index, self.direction,
                self.minibatch, self.epoch, self.device)


@dataclass
class RunReport:
    makespan: float
    utilization: dict[int, float]
    avg_utilization: float
    losses: dict[int, list]
    transfer: dict[int, Any]
    exposed_transfer_time: float
    hidden_transfer_time: float
    units_executed: int
    wall_time: float


class SharpExecutor:
    """Event-driven SHARP loop over virtual devices with real JAX compute."""

    def __init__(self, hydra_cfg: HydraConfig, models: list[ModelExec],
                 devices: Optional[list[DeviceMemory]] = None):
        self.hc = hydra_cfg
        self.models = models
        # caller-owned ledgers (repro.api.Session) let serving KV pages and
        # train double-buffers charge the SAME byte budget; standalone use
        # keeps private per-device ledgers
        self.devices = devices if devices is not None else [
            DeviceMemory(d, hydra_cfg.device_budget_bytes,
                         hydra_cfg.buffer_frac)
            for d in range(hydra_cfg.n_devices)]
        if len(self.devices) != hydra_cfg.n_devices:
            raise ValueError(
                f"{len(self.devices)} DeviceMemory ledgers for "
                f"{hydra_cfg.n_devices} devices")
        self.pick = sched.get_scheduler(hydra_cfg.scheduler,
                                        seed=hydra_cfg.seed)
        self.exposed_transfer = 0.0
        self.hidden_transfer = 0.0
        self.units_executed = 0
        # without SHARP, models run one-at-a-time (spilling-only mode)
        self.active_model: Optional[int] = None

    # -- pilot measurement --------------------------------------------------
    def pilot_pass(self):
        """Warm up all compiled programs and record measured unit runtimes.

        Runs one mini-batch per model on *cloned* params (training state is
        untouched) — the JAX-native analogue of the paper's pilot runs, which
        also dynamically refreshes Sharded-LRTF's runtime table.
        """
        for m in self.models:
            batch = m.pilot_batch
            acts = {}
            act = {}
            cot = None
            for shard in m.partition.shards:
                own, shared, _ = m.store.promote_shard(shard)
                fwd = m.fns.fwd(shard)
                acts[shard.index] = act
                out, _ = fwd(own, shared, act, batch)       # compile run
                jax.block_until_ready(out)
                t0 = time.perf_counter()
                out, _ = fwd(own, shared, act, batch)
                jax.block_until_ready(out)
                shard.fwd_runtime = max(time.perf_counter() - t0, 1e-7)
                act = out
            for shard in reversed(m.partition.shards):
                own, shared, _ = m.store.promote_shard(shard)
                bwd = m.fns.bwd(shard)
                ain = acts[shard.index]
                if shard.index == len(m.partition.shards) - 1:
                    res = bwd(own, shared, ain, batch)
                    jax.block_until_ready(res)
                    t0 = time.perf_counter()
                    res = bwd(own, shared, ain, batch)
                    jax.block_until_ready(res)
                    cot = res[-1]
                else:
                    res = bwd(own, shared, ain, cot, batch)
                    jax.block_until_ready(res)
                    t0 = time.perf_counter()
                    res = bwd(own, shared, ain, cot, batch)
                    jax.block_until_ready(res)
                    cot = res[-1]
                shard.bwd_runtime = max(time.perf_counter() - t0, 1e-7)
            for shard in m.partition.shards:
                shard.est_runtime = shard.fwd_runtime + shard.bwd_runtime

    # -- real unit execution -------------------------------------------------
    def _execute_unit(self, m: ModelExec, unit: Unit) -> None:
        shard = unit.shard
        batch = m.current_batch
        own, shared, opt_state = m.store.promote_shard(shard)
        if unit.direction == "fwd":
            act_in = {} if shard.index == 0 \
                else m.saved_acts[("exit", shard.index - 1)]
            # entry activation is the checkpoint this shard's backward reuses
            m.saved_acts[("entry", shard.index)] = act_in
            out, loss = m.fns.fwd(shard)(own, shared, act_in, batch)
            if shard.index == len(m.partition.shards) - 1:
                m.losses.append(float(loss))
            m.saved_acts[("exit", shard.index)] = out
        else:
            act_in = m.saved_acts[("entry", shard.index)]
            last = shard.index == len(m.partition.shards) - 1
            if last:
                loss, g_own, g_shared, g_act = m.fns.bwd(shard)(
                    own, shared, act_in, batch)
            else:
                g_own, g_shared, g_act = m.fns.bwd(shard)(
                    own, shared, act_in, m.saved_cot, batch)
            m.saved_cot = g_act
            shared_names = m.store.shard_shared_names(shard)
            if shared_names:
                m.store.accumulate_shared_grads(
                    {n: g_shared.get(n) for n in shared_names})
            new_own, new_opt = m.fns._step(own, g_own, opt_state)
            m.store.demote_shard(shard, new_own, new_opt)
            # free this shard's saved activations
            m.saved_acts.pop(("entry", shard.index), None)
            m.saved_acts.pop(("exit", shard.index), None)

    # -- event loop -----------------------------------------------------------
    def run(self, *, max_units: Optional[int] = None,
            on_unit: Optional[Callable[[UnitEvent], None]] = None
            ) -> RunReport:
        wall0 = time.perf_counter()
        for m in self.models:
            m.build_minibatch_queue()
        if self.hc.pilot:
            for m in self.models:
                m.pilot_batch = m.current_batch
            self.pilot_pass()
        if self.hc.fixed_unit_runtime is not None:
            # applied independently of the pilot so the pin also holds with
            # pilot=False (analytic runtime estimates)
            rt = self.hc.fixed_unit_runtime
            for m in self.models:
                for shard in m.partition.shards:
                    shard.fwd_runtime = shard.bwd_runtime = rt
                    shard.est_runtime = 2 * rt

        windows = self.hc.device_windows or {}
        dev_heap = [(max(0.0, windows.get(d, (0.0, None))[0]), d)
                    for d in range(self.hc.n_devices)]
        heapq.heapify(dev_heap)
        dev_busy = {d: 0.0 for d in range(self.hc.n_devices)}
        dev_prev_start = {d: 0.0 for d in range(self.hc.n_devices)}
        makespan = 0.0

        while True:
            live = [m for m in self.models if not m.done]
            if not live:
                break
            if not dev_heap:
                raise RuntimeError(
                    "all devices retired with models unfinished "
                    f"({len(live)} remaining) — widen device_windows")
            t, d = heapq.heappop(dev_heap)
            until = windows.get(d, (0.0, None))[1]
            if until is not None and t >= until:
                continue    # device retired (fault / elasticity shrink)
            eligible = self._eligible()
            if not eligible:
                future = [m.ready_at for m in live if m.next_unit() is not None]
                if not future:
                    break
                heapq.heappush(dev_heap, (max(min(future), t + 1e-9), d))
                continue
            progress = [m.progress() for m in eligible]
            m = eligible[self.pick(progress)]
            unit = m.next_unit()
            m.reserved = True

            # ---- timing model -------------------------------------------
            shard_bytes = m.store.shard_transfer_bytes(unit.shard)
            act_bytes = unit.shard.act_bytes // 4   # boundary act only
            move_act = m.act_location is not None and m.act_location != d
            tx_bytes = shard_bytes + (act_bytes if move_act else 0)
            tx_time = tx_bytes / self.hc.link_bw
            if self.hc.enable_double_buffer:
                # transfer began when this device started its previous unit
                tx_start = max(dev_prev_start[d], m.ready_at)
                tx_end = tx_start + tx_time
                start = max(t, m.ready_at, tx_end)
                self.hidden_transfer += min(tx_time, max(0.0, t - tx_start))
                self.exposed_transfer += max(0.0, tx_end - max(t, m.ready_at))
            else:
                tx_start = max(t, m.ready_at)
                tx_end = tx_start + tx_time
                start = tx_end
                self.exposed_transfer += tx_time
            duration = unit.shard.fwd_runtime if unit.direction == "fwd" \
                else unit.shard.bwd_runtime
            end = start + duration

            # ---- memory accounting --------------------------------------
            dev = self.devices[d]
            dev.promote_through_buffer(
                shard_bytes, double_buffer=self.hc.enable_double_buffer)
            if move_act:
                dev.charge_act(act_bytes)

            # ---- real compute --------------------------------------------
            self._execute_unit(m, unit)
            self.units_executed += 1
            dev.charge_demotion(shard_bytes)
            if on_unit is not None:
                on_unit(UnitEvent(
                    model_id=m.model_id, shard_index=unit.shard.index,
                    direction=unit.direction, minibatch=unit.minibatch,
                    epoch=unit.epoch, device=d, start=start, end=end))

            # ---- advance model state -------------------------------------
            m.cursor += 1
            m.ready_at = end
            m.reserved = False
            m.act_location = d
            if m.cursor >= len(m.queue):
                self._finish_minibatch(m)
            if not self.hc.enable_sharp and m.done and \
                    self.active_model == m.model_id:
                self.active_model = None

            dev_busy[d] += duration
            dev_prev_start[d] = start
            makespan = max(makespan, end)
            heapq.heappush(dev_heap, (end, d))
            if max_units is not None and self.units_executed >= max_units:
                break

        util = {d: (dev_busy[d] / makespan if makespan > 0 else 0.0)
                for d in dev_busy}
        return RunReport(
            makespan=makespan,
            utilization=util,
            avg_utilization=float(np.mean(list(util.values()))),
            losses={m.model_id: m.losses for m in self.models},
            transfer={dv.device_id: dv.stats for dv in self.devices},
            exposed_transfer_time=self.exposed_transfer,
            hidden_transfer_time=self.hidden_transfer,
            units_executed=self.units_executed,
            wall_time=time.perf_counter() - wall0)

    def _eligible(self) -> list[ModelExec]:
        live = [m for m in self.models
                if not m.done and not m.reserved and m.next_unit() is not None]
        if self.hc.enable_sharp:
            return live
        # spilling-only: one model at a time (paper Table 3 top row)
        if self.active_model is None and live:
            self.active_model = min(m.model_id for m in live)
        return [m for m in live if m.model_id == self.active_model]

    def _finish_minibatch(self, m: ModelExec):
        m.store.step_shared()
        m.saved_acts.clear()
        m.saved_cot = None
        m.act_location = None
        m.minibatch += 1
        if m.minibatch >= m.steps_per_epoch:
            m.minibatch = 0
            m.epoch += 1
        # AutoML early stopping (Hyperband-class): underperformers leave the
        # workload — this is exactly the case-1 -> case-2 degradation
        # Sharded-LRTF is designed to handle gracefully (paper §4.7.2)
        if m.early_stop is not None and m.early_stop(m.losses):
            m.stopped_early = True
            m.done = True
        if m.epoch >= m.epochs:
            m.done = True
        if m.done:
            if not self.hc.enable_sharp and self.active_model == m.model_id:
                self.active_model = None
            return
        m.build_minibatch_queue()
