"""Model-as-a-queue-of-segments: the structural substrate for Hydra.

A *segment* is the finest cut-point granularity (one layer / layer-group, or
the embed / bridge / head ends).  The partitioner groups contiguous segments
into *shards*; SHARP schedules *shard units* (forward or backward of one
shard on one mini-batch).

Two parameter classes:

* **own** params — spillable; live host-side, promoted with their shard,
  optimizer-stepped right after the shard's backward unit (paper semantics).
* **shared** params — referenced by more than one segment (tied embedding
  table; zamba2's shared attention block).  One host copy; promoted alongside
  any shard that references them; gradients accumulate across backward units
  and step once when the model's mini-batch completes.  This is the one
  structural extension over the paper's queue model (DESIGN.md §4).

Segments pass a pytree ``act``.  Non-chain data flow lives inside ``act``:
encoder-decoder segments carry ``{"x", "enc"}`` (identity passthrough of
``enc`` makes vjp accumulate cross-attention gradients); MoE segments carry
running aux-loss scalars whose loss cotangent is constant.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import encdec, hybrid, moe, ssm, transformer
from repro.models import layers as nn
from repro.training.losses import softmax_xent

Act = Any
ParamTree = Any


@dataclass(frozen=True)
class Segment:
    """One cut-point unit of a model.

    apply(cfg, own_params, shared_params: dict, act, batch) -> act
    """
    name: str
    param_ref: Optional[tuple]        # ref for own params (None = stateless)
    shared: tuple                      # names of shared param groups used
    apply: Callable[..., Act]
    flops_weight: float = 1.0          # relative cost hint (pilot fallback)


@dataclass
class ShardPlan:
    cfg: Any
    segments: list[Segment]
    shared_refs: dict[str, tuple]      # name -> ref into the full param tree
    loss: Callable[..., jnp.ndarray]   # loss(cfg, act, batch)


# ---------------------------------------------------------------------------
# param_ref resolution (host trees are dicts of numpy/jnp stacked arrays)
# ---------------------------------------------------------------------------

def resolve_ref(params: ParamTree, ref: Optional[tuple]):
    if ref is None:
        return None
    if len(ref) == 4 and ref[0] == "stack_slice":
        _, key, lo, hi = ref
        return jax.tree.map(lambda a: a[lo:hi], params[key])
    node = params
    for k in ref:
        node = node[k]
    return node


def update_with_ref(params: ParamTree, ref: tuple, new_val) -> ParamTree:
    """Write ``new_val`` back at ``ref`` into the host tree (in place)."""
    if ref is None:
        return params
    if len(ref) == 4 and ref[0] == "stack_slice":
        _, key, lo, hi = ref

        def write(dst, src):
            dst = np.asarray(dst)
            if not dst.flags.writeable:
                dst = dst.copy()
            dst[lo:hi] = np.asarray(src)
            return dst

        params[key] = jax.tree.map(write, params[key], new_val)
        return params
    node = params
    for k in ref[:-1]:
        node = node[k]
    node[ref[-1]] = jax.tree.map(np.asarray, new_val)
    return params


# ---------------------------------------------------------------------------
# family shard plans
# ---------------------------------------------------------------------------

def _xent_loss(cfg, act, batch):
    loss = softmax_xent(act["logits"], batch["labels"])
    if "aux" in act:
        # act carries per-layer sums; the reference loss uses layer means
        loss = loss + (0.01 * act["aux"]["lb"]
                       + 1e-3 * act["aux"]["z"]) / cfg.n_layers
    return loss


def _slice1(lp):
    return jax.tree.map(lambda a: a[0], lp)


def _dense_plan(cfg) -> ShardPlan:
    def embed_apply(cfg, own, shared, act, batch):
        x = transformer.embed_inputs(cfg, {"embed": shared["embed"]}, batch)
        return {"x": x}

    def layer_apply(cfg, own, shared, act, batch):
        return {"x": transformer.apply_layer_range(cfg, own, act["x"])}

    def head_apply(cfg, own, shared, act, batch):
        x = transformer._norm(cfg, own, act["x"])
        return {"logits": nn.unembed(shared["embed"], x)}

    segs = [Segment("embed", None, ("embed",), embed_apply, 0.1)]
    for i in range(cfg.n_layers):
        segs.append(Segment(f"layer{i}", ("stack_slice", "layers", i, i + 1),
                            (), layer_apply))
    segs.append(Segment("head", ("final_norm",), ("embed",), head_apply, 0.5))
    return ShardPlan(cfg, segs, {"embed": ("embed",)}, _xent_loss)


def _moe_plan(cfg) -> ShardPlan:
    def embed_apply(cfg, own, shared, act, batch):
        x = transformer.embed_inputs(cfg, {"embed": shared["embed"]}, batch)
        zero = jnp.zeros((), jnp.float32)
        return {"x": x, "aux": {"lb": zero, "z": zero}}

    def layer_apply(cfg, own, shared, act, batch):
        x, aux = moe.apply_layer_range(cfg, own, act["x"])
        return {"x": x, "aux": {"lb": act["aux"]["lb"] + aux["lb_loss"],
                                "z": act["aux"]["z"] + aux["z_loss"]}}

    def head_apply(cfg, own, shared, act, batch):
        x = nn.rms_norm(own, act["x"])
        return {"logits": nn.unembed(shared["embed"], x), "aux": act["aux"]}

    segs = [Segment("embed", None, ("embed",), embed_apply, 0.1)]
    for i in range(cfg.n_layers):
        segs.append(Segment(f"layer{i}", ("stack_slice", "layers", i, i + 1),
                            (), layer_apply))
    segs.append(Segment("head", ("final_norm",), ("embed",), head_apply, 0.5))
    return ShardPlan(cfg, segs, {"embed": ("embed",)}, _xent_loss)


def _ssm_plan(cfg) -> ShardPlan:
    def embed_apply(cfg, own, shared, act, batch):
        return {"x": nn.embed(shared["embed"], batch["tokens"], cfg.dtype)}

    def group_apply(cfg, own, shared, act, batch):
        return {"x": ssm.apply_layer_range(cfg, own, act["x"])}

    def head_apply(cfg, own, shared, act, batch):
        x = nn.rms_norm(own, act["x"])
        return {"logits": nn.unembed(shared["embed"], x)}

    segs = [Segment("embed", None, ("embed",), embed_apply, 0.1)]
    for i in range(ssm.n_groups(cfg)):
        segs.append(Segment(f"group{i}", ("stack_slice", "layers", i, i + 1),
                            (), group_apply, 2.0))
    segs.append(Segment("head", ("final_norm",), ("embed",), head_apply, 0.5))
    return ShardPlan(cfg, segs, {"embed": ("embed",)}, _xent_loss)


def _hybrid_plan(cfg) -> ShardPlan:
    flags = np.asarray(hybrid.attn_flags(cfg))

    def embed_apply(cfg, own, shared, act, batch):
        return {"x": nn.embed(shared["embed"], batch["tokens"], cfg.dtype)}

    def make_layer_apply(i):
        use_attn = bool(flags[i])

        def layer_apply(cfg, own, shared, act, batch):
            lp = _slice1(own)
            x = act["x"]
            x = x + ssm.mamba2_forward(lp["mamba"],
                                       nn.rms_norm(lp["norm"], x), cfg)
            if use_attn:
                x, _ = hybrid.apply_shared_attn(cfg, shared["attn"], x)
            return {"x": x}

        return layer_apply

    def head_apply(cfg, own, shared, act, batch):
        x = nn.rms_norm(own, act["x"])
        return {"logits": nn.unembed(shared["embed"], x)}

    segs = [Segment("embed", None, ("embed",), embed_apply, 0.1)]
    for i in range(cfg.n_layers):
        shared_names = ("attn",) if flags[i] else ()
        segs.append(Segment(f"mamba{i}", ("stack_slice", "layers", i, i + 1),
                            shared_names, make_layer_apply(i),
                            2.0 if flags[i] else 1.0))
    segs.append(Segment("head", ("final_norm",), ("embed",), head_apply, 0.5))
    return ShardPlan(cfg, segs,
                     {"embed": ("embed",), "attn": ("shared_attn",)},
                     _xent_loss)


def _audio_plan(cfg) -> ShardPlan:
    def front_apply(cfg, own, shared, act, batch):
        x = batch["enc_embeds"].astype(cfg.dtype)
        x = x + encdec.sinusoidal_positions(
            x.shape[1], cfg.d_model).astype(cfg.dtype)
        return {"enc_x": x}

    def enc_layer_apply(cfg, own, shared, act, batch):
        lp = _slice1(own)
        return {"enc_x": encdec.apply_enc_layer(cfg, lp, act["enc_x"])}

    def bridge_apply(cfg, own, shared, act, batch):
        enc = nn.layer_norm(own["enc_final_norm"], act["enc_x"])
        tokens = batch["tokens"]
        x = nn.embed(shared["embed"], tokens, cfg.dtype)
        x = x + own["dec_pos"][:tokens.shape[1]].astype(cfg.dtype)[None]
        return {"x": x, "enc": enc}

    def dec_layer_apply(cfg, own, shared, act, batch):
        lp = _slice1(own)
        x = encdec.apply_dec_layer(cfg, lp, act["x"], act["enc"])
        return {"x": x, "enc": act["enc"]}   # passthrough accumulates grads

    def head_apply(cfg, own, shared, act, batch):
        x = nn.layer_norm(own, act["x"])
        return {"logits": nn.unembed(shared["embed"], x)}

    class _BridgeRef(dict):
        pass

    segs = [Segment("frontend", None, (), front_apply, 0.1)]
    for i in range(cfg.n_encoder_layers):
        segs.append(Segment(f"enc{i}", ("stack_slice", "encoder", i, i + 1),
                            (), enc_layer_apply))
    segs.append(Segment("bridge", ("bridge_group",), ("embed",),
                        bridge_apply, 0.1))
    for i in range(cfg.n_layers):
        segs.append(Segment(f"dec{i}", ("stack_slice", "decoder", i, i + 1),
                            (), dec_layer_apply, 1.5))
    segs.append(Segment("head", ("final_norm",), ("embed",), head_apply, 0.5))
    return ShardPlan(cfg, segs, {"embed": ("embed",)}, _xent_loss)


def prepare_host_params(cfg, params) -> ParamTree:
    """Family-specific host-tree tweaks (adds grouped views where needed)."""
    params = dict(params)
    if cfg.family == "audio" and "bridge_group" not in params:
        params["bridge_group"] = {
            "enc_final_norm": params.pop("enc_final_norm"),
            "dec_pos": params.pop("dec_pos"),
        }
    return params


def restore_model_params(cfg, host_params) -> ParamTree:
    """Inverse of prepare_host_params (for checkpoint / reference compare)."""
    params = dict(host_params)
    if cfg.family == "audio" and "bridge_group" in params:
        grp = params.pop("bridge_group")
        params["enc_final_norm"] = grp["enc_final_norm"]
        params["dec_pos"] = grp["dec_pos"]
    return params


import functools


@functools.lru_cache(maxsize=None)
def build_plan(cfg) -> ShardPlan:
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return _dense_plan(cfg)
    if fam == "moe":
        return _moe_plan(cfg)
    if fam == "ssm":
        return _ssm_plan(cfg)
    if fam == "hybrid":
        return _hybrid_plan(cfg)
    if fam == "audio":
        return _audio_plan(cfg)
    raise ValueError(fam)
