"""train_step / serve_step factories used by the launcher, the dry-run, and
the Hydra orchestrator's per-model reference path.

``make_train_step(cfg, opt_cfg)`` returns a pure
``step(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable
for jit/pjit.  ``make_prefill_step`` and ``make_decode_step`` build the
serving-side programs the inference shapes lower.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import api, registry
from repro.models import moe as moe_mod
from repro.models import layers as nn
from repro.optim import optimizers as opt
from repro.training.losses import moe_total_loss, softmax_xent


def make_loss_fn(cfg, *, window: Optional[int] = None,
                 cast_layer_weights: bool = False):
    """``cast_layer_weights``: cast the stacked layer matrices to the compute
    dtype before use, so FSDP all-gathers move bf16 instead of f32 (the cast
    is identical math — layer code casts per-use anyway — but GSPMD otherwise
    gathers the f32 master copy first: ~2× transient weight memory).  Norm
    scales (1D) and the embedding table (f32 unembed) are left in f32."""

    def maybe_cast(params):
        if not cast_layer_weights:
            return params
        out = dict(params)
        for k in ("layers", "encoder", "decoder", "shared_attn"):
            if k in out:
                out[k] = jax.tree.map(
                    lambda p: p.astype(cfg.dtype) if p.ndim >= 2 else p,
                    out[k])
        return out

    def loss_fn(params, batch):
        params = maybe_cast(params)
        if cfg.family == "moe":
            logits, aux = moe_mod.forward(cfg, params, batch, window=window,
                                          return_aux=True)
            xent = softmax_xent(logits, batch["labels"])
            loss = moe_total_loss(xent, aux)
            return loss, {"loss": loss, "xent": xent,
                          "lb_loss": aux["lb_loss"], "z_loss": aux["z_loss"]}
        logits = api.forward(cfg, params, batch, window=window)
        loss = softmax_xent(logits, batch["labels"])
        return loss, {"loss": loss, "xent": loss}

    return loss_fn


def make_train_step(cfg, opt_cfg: opt.OptimizerConfig, *,
                    window: Optional[int] = None,
                    accum_steps: int = 1,
                    mesh=None):
    """Full train step; with ``accum_steps > 1`` the global batch is split
    into micro-batches scanned inside the jitted program (gradient
    accumulation) — the standard way a 256×4k global batch fits activation
    memory on a pod.

    ``mesh``: when given, the micro-batch axis is pinned to the mesh's data
    axes with an explicit sharding constraint — without it GSPMD loses the
    batch sharding through the (accum, micro, ...) reshape and replicates
    every activation (measured: 40 GB/device -> ~3 GB on yi-34b train_4k).
    """
    loss_fn = make_loss_fn(cfg, window=window,
                           cast_layer_weights=mesh is not None)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (_, metrics), grads = grads_of(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % accum_steps == 0
                r = x.reshape(accum_steps, b // accum_steps, *x.shape[1:])
                if mesh is not None:
                    from jax.sharding import NamedSharding, PartitionSpec as P
                    from repro.sharding.specs import batch_axes
                    spec = P(None, batch_axes(mesh),
                             *([None] * (r.ndim - 2)))
                    r = jax.lax.with_sharding_constraint(
                        r, NamedSharding(mesh, spec))
                return r

            micro = jax.tree.map(split, batch)

            def constrain_mb(mb):
                if mesh is None:
                    return mb
                from jax.sharding import NamedSharding, PartitionSpec as P
                from repro.sharding.specs import batch_axes
                B = batch_axes(mesh)
                return jax.tree.map(
                    lambda x: jax.lax.with_sharding_constraint(
                        x, NamedSharding(
                            mesh, P(B, *([None] * (x.ndim - 1))))), mb)

            def body(acc, mb):
                (_, m), g = grads_of(params, constrain_mb(mb))
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, m

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            gsum, ms = jax.lax.scan(body, zero, micro)
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), ms)
        gnorm = opt.global_norm(grads)
        new_params, new_state = opt.update(opt_cfg, params, grads, opt_state,
                                           grad_norm=gnorm)
        metrics = dict(metrics, grad_norm=gnorm)
        return new_params, new_state, metrics

    return train_step


def make_grad_step(cfg, *, window: Optional[int] = None):
    """Gradient-only step (Hydra's shard executor owns the optimizer)."""
    loss_fn = make_loss_fn(cfg, window=window)

    def grad_step(params, batch):
        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, metrics

    return grad_step


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def make_prefill_step(cfg, *, window: Optional[int] = None):
    """Prefill: full-sequence forward to logits (batch of requests)."""

    def prefill_step(params, batch):
        # unembed only the last position: serving samples from it and the
        # (b, s, V) logits tensor is never materialized
        logits = api.forward(cfg, params, batch, last_only=True,
                             window=window)
        return logits[:, -1, :]

    return prefill_step


def make_prefill_into_cache(cfg, *, window: Optional[int] = None):
    """Fill the decode cache/state with a whole prompt, returning the logits
    the first generated token is sampled from.

    Attention families (dense/vlm/moe) consume the full ``(b, plen)`` prompt
    in ONE ``decode_step`` call: the KV write is a single dynamic-update of
    ``plen`` rows and the causal chunk mask keeps intra-prompt attention
    correct, so prefill costs one jitted dispatch instead of ``plen``.
    Recurrent/hybrid/enc-dec states advance strictly token-by-token, so they
    fall back to a ``lax.scan`` over prompt positions — same signature,
    still one jitted program.

    Returns ``prefill(params, state, tokens) -> (last_logits (b, V), state)``.
    """
    if registry.spec(cfg).batched_prefill:
        def prefill(params, state, tokens):
            logits, state = api.decode_step(cfg, params, state, tokens,
                                            window=window)
            return logits[:, -1, :], state

        return prefill

    def prefill_scan(params, state, tokens):
        def body(st, tok):
            logits, st = api.decode_step(cfg, params, st, tok[:, None],
                                         window=window)
            return st, logits[:, -1, :]

        state, logits = jax.lax.scan(body, state, tokens.T)
        return logits[-1], state

    return prefill_scan


def make_padded_prefill_into_cache(cfg, *, window: Optional[int] = None):
    """Length-bucketed prefill: consume a right-padded ``(b, bucket)`` prompt
    whose true length is ``length``, returning the logits at position
    ``length - 1`` and a state whose cache index is rewound to ``length``.

    Correctness relies on two properties of the attention decode path:
    the causal chunk mask means positions ``< length`` never attend to the
    pad tail (padded key scores hit the -1e30 mask and underflow to exactly
    zero weight, so the returned logits match an exact-length prefill); and
    decode attention masks keys at ``kvpos > qpos``, so the garbage KV rows
    the pad tail wrote at ``[length, bucket)`` are never read before the
    decode loop overwrites them one row per step.  Serving engines therefore
    retrace once per ``(n, bucket)`` instead of per ``(n, plen)``, with
    token-identical outputs (tests/test_serving.py).

    Dense/vlm attention families only: recurrent/hybrid states advance
    through every consumed token and cannot be rewound past the pad tail,
    and capacity-bounded MoE routing couples tokens — pad tokens consume
    expert capacity and displace real tokens' routes, changing logits.
    """
    if not registry.spec(cfg).padded_prefill:
        raise ValueError(
            f"{cfg.name} ({cfg.family}): padded prefill needs a rewindable "
            "KV cache and per-token-independent mixing "
            f"({registry.spec(cfg).why_not('padded_prefill')}); this "
            "family must prefill at exact length")

    def rewind(path, leaf, delta):
        key = getattr(path[-1], "key", None) if path else None
        return leaf - delta if key == "index" else leaf

    def prefill(params, state, tokens, length):
        bucket = tokens.shape[1]
        logits, state = api.decode_step(cfg, params, state, tokens,
                                        window=window)
        last = jax.lax.dynamic_index_in_dim(logits, length - 1, axis=1,
                                            keepdims=False)
        state = jax.tree_util.tree_map_with_path(
            partial(rewind, delta=bucket - length), state)
        return last, state

    return prefill


def make_decode_step(cfg, *, window: Optional[int] = None):
    """One-token decode against a KV cache / recurrent state."""

    def decode_step(params, state, tokens):
        logits, new_state = api.decode_step(cfg, params, state, tokens,
                                            window=window)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        return next_tok.astype(jnp.int32), new_state

    return decode_step


def make_paged_decode_step(cfg, *, window: Optional[int] = None,
                           impl: str = "jnp"):
    """One-token greedy decode through per-lane KV block tables.

    Returns ``step(params, pages, tables, lengths, tokens) ->
    (next_tokens (n, 1) int32, new pages)`` — the paged twin of
    ``make_decode_step``, batched over lanes (the pages are shared state,
    so the lanes cannot be vmapped as independent programs)."""

    def paged_step(params, pages, tables, lengths, tokens):
        logits, pages = api.paged_decode_step(
            cfg, params, pages, tables, lengths, tokens,
            window=window, impl=impl)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        return next_tok.astype(jnp.int32), pages

    return paged_step


def make_verify_step(cfg, *, window: Optional[int] = None):
    """Speculative verify over the contiguous cache: tokens ``(b, k)`` (the
    last committed token + k-1 drafts) -> ``(greedy (b, k) int32, state)``,
    the target's greedy continuation at every draft position in ONE
    forward.  The cache advances k rows; the caller rewinds past the
    accept point (``api.rollback_decode_state``)."""

    def verify(params, state, tokens):
        logits, state = api.verify_step(cfg, params, state, tokens,
                                        window=window)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), state

    return verify


def make_paged_verify_step(cfg, *, window: Optional[int] = None,
                           impl: str = "jnp"):
    """The paged twin of ``make_verify_step``: k positions per lane scored
    through block tables.  Returns ``step(params, pages, tables, lengths,
    tokens (n, k)) -> (greedy (n, k) int32, new pages)``."""

    def verify(params, pages, tables, lengths, tokens):
        logits, pages = api.paged_verify_step(
            cfg, params, pages, tables, lengths, tokens,
            window=window, impl=impl)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), pages

    return verify


def decode_window_for(cfg, shape) -> Optional[int]:
    """Policy: long_500k on full-attention archs uses the SWA fallback."""
    if shape.name != "long_500k":
        return None
    if cfg.family in ("ssm", "hybrid"):
        return None          # recurrent state — no attention window needed
    if cfg.window is not None:
        return cfg.window    # native SWA (Mixtral)
    return cfg.long_context_window
