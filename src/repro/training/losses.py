"""Loss functions."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 mask=None) -> jnp.ndarray:
    """Mean next-token cross-entropy. logits: (b, s, V) f32; labels: (b, s)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def moe_total_loss(xent: jnp.ndarray, aux: dict, *,
                   lb_coef: float = 0.01, z_coef: float = 1e-3) -> jnp.ndarray:
    return xent + lb_coef * aux["lb_loss"] + z_coef * aux["z_loss"]
