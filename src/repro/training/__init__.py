from repro.training.losses import moe_total_loss, softmax_xent
from repro.training.train_loop import (decode_window_for, make_decode_step,
                                       make_grad_step, make_loss_fn,
                                       make_padded_prefill_into_cache,
                                       make_prefill_into_cache,
                                       make_prefill_step, make_train_step)

__all__ = ["softmax_xent", "moe_total_loss", "make_loss_fn",
           "make_train_step", "make_grad_step", "make_prefill_step",
           "make_prefill_into_cache", "make_padded_prefill_into_cache",
           "make_decode_step", "decode_window_for"]
