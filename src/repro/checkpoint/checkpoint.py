"""Sharded, spill-aware checkpointing.

Format: one directory per step containing
  * ``manifest.json`` — pytree structure, shapes, dtypes, step metadata
  * ``arrays.npz``    — flattened leaves keyed by tree path

Works on host-resident (spilled) shards without forcing promotion: leaves may
be numpy arrays (host) or jax arrays (device) — both serialize; restore
returns numpy so Hydra's memory manager decides placement.
"""

from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(directory: str, tree, *, step: int = 0,
         metadata: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays = {}
    manifest = {"step": step, "metadata": metadata or {}, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(leaf)
        arrays[key] = arr if arr.dtype != jnp.bfloat16 else \
            arr.view(np.uint16)
        manifest["leaves"][key] = {
            "shape": list(arr.shape),
            "dtype": "bfloat16" if arr.dtype == jnp.bfloat16 else str(arr.dtype),
        }
    np.savez(os.path.join(directory, "arrays.npz"), **arrays)
    with open(os.path.join(directory, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return directory


def restore(directory: str, like=None) -> tuple[Any, dict]:
    """Returns (tree, manifest). If ``like`` given, reshapes into its pytree
    structure; otherwise returns the flat {path: array} dict."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(directory, "arrays.npz")) as z:
        flat = {}
        for key, meta in manifest["leaves"].items():
            arr = z[key]
            if meta["dtype"] == "bfloat16":
                arr = arr.view(jnp.bfloat16)
            flat[key] = arr
    if like is None:
        return flat, manifest
    like_flat = _flatten_with_paths(like)
    missing = set(like_flat) - set(flat)
    if missing:
        raise ValueError(f"checkpoint missing leaves: {sorted(missing)[:5]}")
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, _ in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


def latest_step(root: str) -> Optional[str]:
    if not os.path.isdir(root):
        return None
    steps = [d for d in os.listdir(root) if d.startswith("step_")]
    if not steps:
        return None
    return os.path.join(root, max(steps, key=lambda s: int(s.split("_")[1])))
