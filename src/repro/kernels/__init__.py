"""Pallas TPU kernels for the compute hot spots.

Each kernel ships three parts: ``<name>.py`` (pl.pallas_call + BlockSpec
tiling), wrappers in ``ops.py`` (jit'd public entry points), and oracles in
``ref.py`` (pure-jnp ground truth for the allclose tests).
"""
