"""jit'd public wrappers around the Pallas kernels.

On the CPU dev container kernels run with ``interpret=True`` (the Pallas
interpreter executes the kernel body faithfully); on TPU the same call sites
compile to Mosaic.  ``repro.models.layers`` routes here when
``cfg.attn_impl`` selects the kernel path.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.rmsnorm import rms_norm_2d
from repro.kernels.ssd_scan import ssd_scan_bshpn
from repro.kernels.swiglu import swiglu_2d

_ON_TPU = jax.default_backend() == "tpu"


def _interp(explicit):
    return (not _ON_TPU) if explicit is None else explicit


@partial(jax.jit, static_argnames=("causal", "window", "interpret",
                                   "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    interpret=None, block_q: int = 128, block_k: int = 128):
    """q: (b, sq, nh, hd); k/v: (b, sk, nkv, hd) — layer-layout entry point."""
    out = flash_attention_bhsd(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interp(interpret))
    return out.transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, log_a, b_coef, c_coef, *, chunk: int = 256,
             initial_state=None, interpret=None):
    y = ssd_scan_bshpn(x, log_a, b_coef, c_coef, chunk=chunk,
                       interpret=_interp(interpret))
    return y, None   # kernel path does not export final state (training)


@partial(jax.jit, static_argnames=("eps", "interpret"))
def rms_norm(x, w, *, eps: float = 1e-6, interpret=None):
    shape = x.shape
    y = rms_norm_2d(x.reshape(-1, shape[-1]), w, eps=eps,
                    interpret=_interp(interpret))
    return y.reshape(shape)


@partial(jax.jit, static_argnames=("interpret",))
def swiglu(x, w_gate, w_up, w_down, *, interpret=None):
    shape = x.shape
    y = swiglu_2d(x.reshape(-1, shape[-1]), w_gate, w_up, w_down,
                  interpret=_interp(interpret))
    return y.reshape(*shape[:-1], w_down.shape[-1])
