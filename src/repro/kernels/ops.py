"""jit'd public wrappers around the Pallas kernels.

On the CPU dev container kernels run with ``interpret=True`` (the Pallas
interpreter executes the kernel body faithfully); on TPU the same call sites
compile to Mosaic.  ``repro.models.layers`` routes here when
``cfg.attn_impl`` selects the kernel path.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.fused_decode import fused_decode_layer as _fused_layer
from repro.kernels.paged_attention import (paged_attention_lanes,
                                           paged_attention_quant_lanes)
from repro.kernels.paged_verify import paged_verify_lanes
from repro.kernels.rmsnorm import rms_norm_2d
from repro.kernels.ssd_scan import ssd_scan_bshpn
from repro.kernels.swiglu import swiglu_2d

_ON_TPU = jax.default_backend() == "tpu"


def _interp(explicit):
    return (not _ON_TPU) if explicit is None else explicit


@partial(jax.jit, static_argnames=("causal", "window", "interpret",
                                   "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    interpret=None, block_q: int = 128, block_k: int = 128):
    """q: (b, sq, nh, hd); k/v: (b, sk, nkv, hd) — layer-layout entry point."""
    out = flash_attention_bhsd(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interp(interpret))
    return out.transpose(0, 2, 1, 3)


def default_paged_impl() -> str:
    """Engine-facing policy: the Mosaic kernel on TPU, the pure-jnp gather
    fallback elsewhere (the Pallas interpreter is faithful but far too slow
    to decode through; it is exercised by tests/test_kernels.py)."""
    return "pallas" if _ON_TPU else "jnp"


@partial(jax.jit, static_argnames=("window", "impl"))
def paged_attention(q, k_pages, v_pages, tables, lengths, *,
                    window=None, impl: str = "jnp"):
    """Single-token attention through a block table.

    q: (n, nh, hd); k/v_pages: (P, bs, nkv, hd); tables: (n, B) physical
    block ids (pad unused entries with a valid block — they are masked);
    lengths: (n,) valid rows per lane including the current token.
    ``impl``: 'jnp' | 'pallas' | 'pallas_interpret'.
    """
    if impl == "jnp":
        return ref.paged_attention_ref(q, k_pages, v_pages, tables, lengths,
                                       window=window)
    if impl not in ("pallas", "pallas_interpret"):
        raise ValueError(f"paged_attention impl={impl!r}: expected "
                         "'jnp', 'pallas', or 'pallas_interpret'")
    return paged_attention_lanes(q, k_pages, v_pages, tables, lengths,
                                 window=window,
                                 interpret=(impl == "pallas_interpret"))


@partial(jax.jit, static_argnames=("window", "impl"))
def paged_verify(q, k_pages, v_pages, tables, lengths, *,
                 window=None, impl: str = "jnp"):
    """Multi-query (speculative verify) attention through a block table.

    q: (n, k, nh, hd) — all k draft positions per lane, already scattered
    into the pages; tables/lengths as `paged_attention` except ``lengths``
    counts rows committed BEFORE the round (query ``i`` attends through
    logical row ``lengths + i``).  ``impl``: 'jnp' (gathered fallback,
    the historical path) | 'pallas' | 'pallas_interpret'.
    """
    if impl == "jnp":
        return ref.paged_verify_ref(q, k_pages, v_pages, tables, lengths,
                                    window=window)
    if impl not in ("pallas", "pallas_interpret"):
        raise ValueError(f"paged_verify impl={impl!r}: expected "
                         "'jnp', 'pallas', or 'pallas_interpret'")
    return paged_verify_lanes(q, k_pages, v_pages, tables, lengths,
                              window=window,
                              interpret=(impl == "pallas_interpret"))


@partial(jax.jit, static_argnames=("window", "impl"))
def paged_attention_quant(q, k_pages, v_pages, k_scales, v_scales,
                          tables, lengths, *, window=None,
                          impl: str = "jnp"):
    """int8-KV single-token attention: pages are int8 with per-row f32
    scales (`ref.quantize_kv` layout); dequantization happens inside the
    kernel (or on the gathered rows for the jnp fallback)."""
    if impl == "jnp":
        return ref.paged_attention_quant_ref(
            q, k_pages, v_pages, k_scales, v_scales, tables, lengths,
            window=window)
    if impl not in ("pallas", "pallas_interpret"):
        raise ValueError(f"paged_attention_quant impl={impl!r}: expected "
                         "'jnp', 'pallas', or 'pallas_interpret'")
    return paged_attention_quant_lanes(
        q, k_pages, v_pages, k_scales, v_scales, tables, lengths,
        window=window, interpret=(impl == "pallas_interpret"))


@partial(jax.jit, static_argnames=("window", "eps", "impl"))
def fused_decode_layer(h, q, k_pages, v_pages, tables, lengths, wo,
                       mlp_scale, w_gate, w_up, w_down, *, window=None,
                       eps: float = 1e-6, impl: str = "jnp"):
    """Fused paged decode layer: attention through the block table + wo
    projection + residual + RMSNorm + SwiGLU + residual, one launch per
    layer (see `fused_decode.fused_decode_layer`).  The jnp fallback
    composes the same epilogue from the oracles."""
    if impl == "jnp":
        return ref.fused_decode_layer_ref(
            h, q, k_pages, v_pages, tables, lengths, wo, mlp_scale,
            w_gate, w_up, w_down, window=window, eps=eps)
    if impl not in ("pallas", "pallas_interpret"):
        raise ValueError(f"fused_decode_layer impl={impl!r}: expected "
                         "'jnp', 'pallas', or 'pallas_interpret'")
    return _fused_layer(h, q, k_pages, v_pages, tables, lengths, wo,
                        mlp_scale, w_gate, w_up, w_down, window=window,
                        eps=eps, interpret=(impl == "pallas_interpret"))


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, log_a, b_coef, c_coef, *, chunk: int = 256,
             initial_state=None, interpret=None):
    y = ssd_scan_bshpn(x, log_a, b_coef, c_coef, chunk=chunk,
                       interpret=_interp(interpret))
    return y, None   # kernel path does not export final state (training)


@partial(jax.jit, static_argnames=("eps", "interpret"))
def rms_norm(x, w, *, eps: float = 1e-6, interpret=None):
    shape = x.shape
    y = rms_norm_2d(x.reshape(-1, shape[-1]), w, eps=eps,
                    interpret=_interp(interpret))
    return y.reshape(shape)


@partial(jax.jit, static_argnames=("interpret",))
def swiglu(x, w_gate, w_up, w_down, *, interpret=None):
    shape = x.shape
    y = swiglu_2d(x.reshape(-1, shape[-1]), w_gate, w_up, w_down,
                  interpret=_interp(interpret))
    return y.reshape(*shape[:-1], w_down.shape[-1])
