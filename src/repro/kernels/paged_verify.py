"""Pallas TPU multi-query paged attention for speculative-decode verify.

`SpecDecodeBackend` verifies all k draft tokens in one batched forward;
historically that forward gathered each lane's pages into a contiguous
``(n, B*bs)`` copy (`models/layers.paged_attention_verify`'s inline jnp
path, now `ref.paged_verify_ref`).  This kernel reads K/V straight
through the block table instead — same grid and scalar-prefetch layout
as `paged_attention.paged_attention_lanes`, but the q block carries all
k query positions at once and the causal mask is per-position: query
``i`` of a lane sits at logical row ``lengths[lane] + i`` (its own K/V
row is already scattered) and attends to ``[0, lengths + i]``.

The k query rows and the GQA groups are flattened into one
``(k * groups)`` row axis so the online-softmax scratch carries across
the block dimension exactly like the single-token kernel.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _verify_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   scale: float, block_size: int, n_queries: int, window):
    lane = pl.program_id(0)
    b = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(b == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kk = n_queries
    groups = q_ref.shape[2]
    q = q_ref[0].astype(jnp.float32).reshape(kk * groups, -1)
    k = k_ref[0, :, 0].astype(jnp.float32)       # (block_size, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale

    length = lengths_ref[lane]                   # rows committed pre-round
    rows = kk * groups
    k_pos = b * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (rows, block_size), 1)
    # flattened row r holds query position r // groups, at logical row
    # lengths[lane] + (r // groups)
    q_pos = length + jax.lax.broadcasted_iota(
        jnp.int32, (rows, block_size), 0) // groups
    mask = k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_cur[:, None])
    alpha = jnp.exp(m_prev - m_cur)
    l_scr[...] = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
    m_scr[...] = m_cur

    @pl.when(b == nb - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        out = acc_scr[...] / denom
        o_ref[0] = out.reshape(kk, groups, -1).astype(o_ref.dtype)


def paged_verify_lanes(q, k_pages, v_pages, tables, lengths, *,
                       window=None, interpret: bool = False):
    """q: (n, k, nh, hd) roped queries, already scattered into the pages;
    k/v_pages: (P, bs, nkv, hd); tables: (n, B) physical block ids (pad
    with the garbage block); lengths: (n,) rows committed BEFORE this
    verify round (query ``i`` attends through row ``lengths + i``).
    Returns (n, k, nh, hd) in q's dtype."""
    n, kk, nh, hd = q.shape
    _, block_size, nkv, _ = k_pages.shape
    n_blocks = tables.shape[1]
    assert nh % nkv == 0
    groups = nh // nkv
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(_verify_kernel, scale=scale,
                               block_size=block_size, n_queries=kk,
                               window=window)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # tables, lengths
        grid=(n, nkv, n_blocks),
        in_specs=[
            pl.BlockSpec((1, kk, groups, hd),
                         lambda i, kv, b, t, le: (i, 0, kv, 0)),
            pl.BlockSpec((1, block_size, 1, hd),
                         lambda i, kv, b, t, le: (t[i, b], 0, kv, 0)),
            pl.BlockSpec((1, block_size, 1, hd),
                         lambda i, kv, b, t, le: (t[i, b], 0, kv, 0)),
        ],
        out_specs=pl.BlockSpec((1, kk, groups, hd),
                               lambda i, kv, b, t, le: (i, 0, kv, 0)),
        scratch_shapes=[
            pltpu.VMEM((kk * groups,), jnp.float32),     # running max m
            pltpu.VMEM((kk * groups,), jnp.float32),     # running denom l
            pltpu.VMEM((kk * groups, hd), jnp.float32),  # output accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, kk, nh, hd), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32), q,
      k_pages, v_pages)
